//! The typed, versioned middleware API (v2/v3).
//!
//! The wire surface used to be a single stringly-typed `match` in
//! [`super::server`]: every handler fished fields out of raw
//! [`Json`] params and every failure collapsed into an opaque error
//! string, so clients could not tell a retryable `no capacity` from a
//! terminal `quota budget exhausted` without substring matching. This
//! module is the typed boundary the multi-tenant literature asks the
//! management API to be:
//!
//! * [`Method`] — the closed set of RPC methods; the server
//!   dispatches through a table keyed on it.
//! * One request + one response struct per method, each with
//!   `to_json` / `from_json` over the in-repo [`Json`] value. Request
//!   parsing is the *only* place wire fields are read; handlers and
//!   the typed client work on structs.
//! * [`ApiError`] — structured errors: a machine-readable
//!   [`ErrorCode`] (mapped from [`SchedError`] / [`HypervisorError`]),
//!   a human message, a `retryable` bit and an optional
//!   `retry_after_s` hint, so clients can react programmatically
//!   (retry on `quota_exceeded`, fail fast on `bad_lease`).
//! * Protocol version negotiation: `hello` advertises the server's
//!   `[PROTO_MIN, PROTO_MAX]` window and rejects clients whose range
//!   does not overlap with [`ErrorCode::ProtocolMismatch`].
//! * Protocol 3: the **event-stream surface** — `subscribe` opens a
//!   multi-frame response delivering typed [`Event`]s matched by a
//!   [`SubscriptionFilter`], so clients react to job progress,
//!   placement changes and region lifecycle transitions by
//!   server push instead of polling.
//!
//! Protocol 1 (the untyped surface: string errors, bare-array
//! catalogues, synchronous long operations, honor-system `user`
//! auth) was kept readable for exactly one version behind and is now
//! **retired**: proto-less requests are rejected with
//! `protocol_mismatch` before dispatch.

use crate::config::ServiceModel;
use crate::hypervisor::HypervisorError;
use crate::rc2f::stream::StreamOutcome;
use crate::sched::{RequestClass, SchedError};
use crate::metrics::{HistogramSnapshot, RegistrySnapshot};
use crate::util::ids::{
    AllocationId, FpgaId, JobId, LeaseToken, NodeId, ReservationId,
    SpanId, TraceId, UserId, VfpgaId,
};
use crate::util::json::Json;
use crate::util::trace::{SpanRecord, TraceSnapshot};

/// Oldest protocol this server/client still speaks (the typed v2
/// surface; the untyped protocol 1 is retired).
pub const PROTO_MIN: u32 = 2;
/// Newest protocol this server/client speaks (v4: out-of-band binary
/// data frames for bulk stream payloads).
pub const PROTO_MAX: u32 = 4;
/// First protocol carrying out-of-band binary data frames. Peers
/// negotiating v3 get the same payloads base64-packed in JSON
/// stream frames.
pub const PROTO_DATA_FRAMES: u32 = 4;

// ====================================================== error codes

/// Machine-readable error category carried on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ErrorCode {
    /// Malformed or missing request fields / unparsable ids.
    BadRequest,
    /// Method name not in [`Method`] (or not served by this peer).
    UnknownMethod,
    /// Client and server protocol windows do not overlap.
    ProtocolMismatch,
    /// No free capacity for the request right now (retryable).
    NoCapacity,
    /// Tenant at its concurrency quota (retryable after a release).
    QuotaExceeded,
    /// Tenant's device-second budget exhausted (terminal).
    QuotaBudget,
    /// Allocation unknown, not yours, or of the wrong kind.
    BadLease,
    /// Lease token missing, forged, or stale — protocol-2 mutating
    /// RPCs authorize by capability token, not the `user` field.
    BadToken,
    UnknownDevice,
    UnknownService,
    UnknownCore,
    UnknownJob,
    UnknownReservation,
    /// The request (or job) was cancelled before completion.
    Cancelled,
    /// Reserved: a lease preempted out from under an in-flight
    /// operation. Not emitted yet — today that window surfaces as a
    /// sanity/device failure; the scheduler's quiesce/pin follow-up
    /// (ROADMAP) will report it with this code.
    Preempted,
    /// A wait ran out of time; the job keeps running (retryable).
    Timeout,
    /// Bitstream failed the sanity checker.
    SanityRejected,
    /// Bitstream refused admission into the cluster cache (bad CRC
    /// or a frame window escaping the target region).
    CacheRejected,
    /// Simulated hardware / device-layer fault.
    DeviceFault,
    /// Anything the server cannot classify further.
    Internal,
}

impl ErrorCode {
    /// Every code, for exhaustive tests and the protocol doc.
    pub const ALL: [ErrorCode; 20] = [
        ErrorCode::BadRequest,
        ErrorCode::UnknownMethod,
        ErrorCode::ProtocolMismatch,
        ErrorCode::NoCapacity,
        ErrorCode::QuotaExceeded,
        ErrorCode::QuotaBudget,
        ErrorCode::BadLease,
        ErrorCode::BadToken,
        ErrorCode::UnknownDevice,
        ErrorCode::UnknownService,
        ErrorCode::UnknownCore,
        ErrorCode::UnknownJob,
        ErrorCode::UnknownReservation,
        ErrorCode::Cancelled,
        ErrorCode::Preempted,
        ErrorCode::Timeout,
        ErrorCode::SanityRejected,
        ErrorCode::CacheRejected,
        ErrorCode::DeviceFault,
        ErrorCode::Internal,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownMethod => "unknown_method",
            ErrorCode::ProtocolMismatch => "protocol_mismatch",
            ErrorCode::NoCapacity => "no_capacity",
            ErrorCode::QuotaExceeded => "quota_exceeded",
            ErrorCode::QuotaBudget => "quota_budget",
            ErrorCode::BadLease => "bad_lease",
            ErrorCode::BadToken => "bad_token",
            ErrorCode::UnknownDevice => "unknown_device",
            ErrorCode::UnknownService => "unknown_service",
            ErrorCode::UnknownCore => "unknown_core",
            ErrorCode::UnknownJob => "unknown_job",
            ErrorCode::UnknownReservation => "unknown_reservation",
            ErrorCode::Cancelled => "cancelled",
            ErrorCode::Preempted => "preempted",
            ErrorCode::Timeout => "timeout",
            ErrorCode::SanityRejected => "sanity_rejected",
            ErrorCode::CacheRejected => "cache_rejected",
            ErrorCode::DeviceFault => "device_fault",
            ErrorCode::Internal => "internal",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.iter().copied().find(|c| c.name() == s)
    }

    /// Whether a client may retry the same request and reasonably
    /// expect a different outcome.
    pub fn retryable(self) -> bool {
        matches!(
            self,
            ErrorCode::NoCapacity
                | ErrorCode::QuotaExceeded
                | ErrorCode::Timeout
        )
    }

    /// Suggested client backoff before retrying, where one applies.
    fn default_retry_after_s(self) -> Option<f64> {
        match self {
            ErrorCode::NoCapacity => Some(1.0),
            ErrorCode::QuotaExceeded => Some(5.0),
            _ => None,
        }
    }
}

/// A structured API error: what went wrong, whether retrying can
/// help, and how long to back off.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
    pub retryable: bool,
    pub retry_after_s: Option<f64>,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError {
            code,
            message: message.into(),
            retryable: code.retryable(),
            retry_after_s: code.default_retry_after_s(),
        }
    }

    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, message)
    }

    pub fn internal(message: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::Internal, message)
    }

    pub fn unknown_method(method: &str) -> ApiError {
        ApiError::new(
            ErrorCode::UnknownMethod,
            format!("unknown method '{method}'"),
        )
    }

    pub fn protocol_mismatch(
        client_min: u32,
        client_max: u32,
    ) -> ApiError {
        ApiError::new(
            ErrorCode::ProtocolMismatch,
            format!(
                "client speaks protocols [{client_min}, {client_max}], \
                 server speaks [{PROTO_MIN}, {PROTO_MAX}]"
            ),
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", Json::from(self.code.name())),
            ("message", Json::from(self.message.as_str())),
            ("retryable", Json::from(self.retryable)),
            (
                "retry_after_s",
                match self.retry_after_s {
                    Some(s) => Json::from(s),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ApiError, String> {
        let code = v
            .str_field("code")
            .ok()
            .and_then(ErrorCode::parse)
            .ok_or("error object missing/unknown 'code'")?;
        Ok(ApiError {
            code,
            message: v.str_field("message").unwrap_or("").to_string(),
            retryable: v
                .get("retryable")
                .as_bool()
                .unwrap_or_else(|| code.retryable()),
            retry_after_s: v.get("retry_after_s").as_f64(),
        })
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

impl From<&SchedError> for ApiError {
    fn from(e: &SchedError) -> ApiError {
        let code = match e {
            SchedError::NoCapacity => ErrorCode::NoCapacity,
            SchedError::QuotaBudget(_) => ErrorCode::QuotaBudget,
            SchedError::QuotaConcurrency(_) => ErrorCode::QuotaExceeded,
            SchedError::Hypervisor(_) => ErrorCode::Internal,
            SchedError::UnknownGrant(_) => ErrorCode::BadLease,
            SchedError::UnknownLease => ErrorCode::BadToken,
            SchedError::Unsatisfiable(_) => ErrorCode::BadRequest,
            SchedError::Cancelled => ErrorCode::Cancelled,
            SchedError::UnknownReservation(_) => {
                ErrorCode::UnknownReservation
            }
        };
        ApiError::new(code, e.to_string())
    }
}

impl From<SchedError> for ApiError {
    fn from(e: SchedError) -> ApiError {
        ApiError::from(&e)
    }
}

impl From<&HypervisorError> for ApiError {
    fn from(e: &HypervisorError) -> ApiError {
        let code = match e {
            HypervisorError::NoCapacity => ErrorCode::NoCapacity,
            HypervisorError::Db(_) => ErrorCode::Internal,
            HypervisorError::Device(_) => ErrorCode::DeviceFault,
            HypervisorError::Sanity(_) => ErrorCode::SanityRejected,
            HypervisorError::BadAllocation(_) => ErrorCode::BadLease,
            HypervisorError::WrongKind(_) => ErrorCode::BadLease,
            HypervisorError::UnknownDevice(_) => ErrorCode::UnknownDevice,
            HypervisorError::UnknownService(_) => {
                ErrorCode::UnknownService
            }
            HypervisorError::Sched(_) => ErrorCode::Internal,
        };
        ApiError::new(code, e.to_string())
    }
}

impl From<HypervisorError> for ApiError {
    fn from(e: HypervisorError) -> ApiError {
        ApiError::from(&e)
    }
}

// ========================================================== methods

/// The closed set of RPC methods across the management server and the
/// node agents.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Method {
    Hello,
    AddUser,
    Status,
    AllocVfpga,
    AllocPhysical,
    Release,
    ProgramCore,
    Stream,
    ProgramFull,
    Migrate,
    Services,
    InvokeService,
    Monitor,
    Workload,
    SchedStatus,
    QuotaSet,
    QuotaGet,
    UsageReport,
    Reserve,
    CancelReservation,
    Energy,
    DbDump,
    Cores,
    JobStatus,
    JobWait,
    JobCancel,
    /// Protocol 3: open a server-push event subscription (the only
    /// multi-frame-response method).
    Subscribe,
    /// The per-device region lifecycle transition log.
    LifecycleLog,
    SchedPolicyGet,
    SchedPolicySet,
    /// Dump every registered instrument (counters, gauges,
    /// histograms with bucket boundaries) as typed JSON.
    MetricsExport,
    /// Fetch a span tree from the flight recorder, by trace id or by
    /// the job that carried it.
    TraceGet,
    /// Ahead-of-time compile of a core for a part into the cluster
    /// bitstream cache; answers immediately with a digest + async
    /// flow job (concurrent submits for one digest coalesce).
    CompileSubmit,
    /// Poll a cache digest: cached / running / unknown.
    CompileStatus,
    AgentHello,
    AgentStatus,
    /// Registered nodes with health, capacity and heartbeat age.
    NodeList,
    /// A node daemon dialing in (or rejoining after a crash): it
    /// reports its address, capacity and the leases its local WAL
    /// re-adopted; the response lists tokens re-homed elsewhere in
    /// the meantime, which the node must release (reconciliation).
    ClusterRegister,
    /// Heartbeat probe: capacity, queue depth and journal cursor.
    AgentPing,
    /// Cross-node admission: the placement layer asks one node's
    /// local scheduler for a lease (optionally re-minting it under a
    /// pre-existing token — failure-driven re-admission).
    AgentAdmit,
    /// Release a node-local lease by capability token.
    AgentRelease,
    /// Program a prebuilt core onto a node-local lease member.
    AgentProgram,
    /// Synchronous streaming session on a node-local lease member.
    AgentStream,
    /// Multi-frame replay/follow of the node's local event journal
    /// (the federation feed; frames carry node-local cursors).
    AgentEvents,
    /// A node daemon pulling a cached artifact it is missing from
    /// the management cache (multi-frame reply; protocol-4 `BIN`
    /// payload frames, base64 fallback on v3). `agent.`-prefixed
    /// because it belongs to the agent↔management protocol — but the
    /// *agent is the caller*, so the management server serves it.
    AgentFetchBitstream,
}

impl Method {
    /// Every method, for dispatch-completeness tests and the docs.
    pub const ALL: [Method; 45] = [
        Method::Hello,
        Method::AddUser,
        Method::Status,
        Method::AllocVfpga,
        Method::AllocPhysical,
        Method::Release,
        Method::ProgramCore,
        Method::Stream,
        Method::ProgramFull,
        Method::Migrate,
        Method::Services,
        Method::InvokeService,
        Method::Monitor,
        Method::Workload,
        Method::SchedStatus,
        Method::QuotaSet,
        Method::QuotaGet,
        Method::UsageReport,
        Method::Reserve,
        Method::CancelReservation,
        Method::Energy,
        Method::DbDump,
        Method::Cores,
        Method::JobStatus,
        Method::JobWait,
        Method::JobCancel,
        Method::Subscribe,
        Method::LifecycleLog,
        Method::SchedPolicyGet,
        Method::SchedPolicySet,
        Method::MetricsExport,
        Method::TraceGet,
        Method::CompileSubmit,
        Method::CompileStatus,
        Method::AgentHello,
        Method::AgentStatus,
        Method::NodeList,
        Method::ClusterRegister,
        Method::AgentPing,
        Method::AgentAdmit,
        Method::AgentRelease,
        Method::AgentProgram,
        Method::AgentStream,
        Method::AgentEvents,
        Method::AgentFetchBitstream,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Method::Hello => "hello",
            Method::AddUser => "add_user",
            Method::Status => "status",
            Method::AllocVfpga => "alloc_vfpga",
            Method::AllocPhysical => "alloc_physical",
            Method::Release => "release",
            Method::ProgramCore => "program_core",
            Method::Stream => "stream",
            Method::ProgramFull => "program_full",
            Method::Migrate => "migrate",
            Method::Services => "services",
            Method::InvokeService => "invoke_service",
            Method::Monitor => "monitor",
            Method::Workload => "workload",
            Method::SchedStatus => "sched_status",
            Method::QuotaSet => "quota_set",
            Method::QuotaGet => "quota_get",
            Method::UsageReport => "usage_report",
            Method::Reserve => "reserve",
            Method::CancelReservation => "cancel_reservation",
            Method::Energy => "energy",
            Method::DbDump => "db_dump",
            Method::Cores => "cores",
            Method::JobStatus => "job_status",
            Method::JobWait => "job_wait",
            Method::JobCancel => "job_cancel",
            Method::Subscribe => "subscribe",
            Method::LifecycleLog => "lifecycle_log",
            Method::SchedPolicyGet => "sched_policy_get",
            Method::SchedPolicySet => "sched_policy_set",
            Method::MetricsExport => "metrics_export",
            Method::TraceGet => "trace_get",
            Method::CompileSubmit => "compile_submit",
            Method::CompileStatus => "compile_status",
            Method::AgentHello => "agent.hello",
            Method::AgentStatus => "agent.status",
            Method::NodeList => "node_list",
            Method::ClusterRegister => "cluster.register",
            Method::AgentPing => "agent.ping",
            Method::AgentAdmit => "agent.admit",
            Method::AgentRelease => "agent.release",
            Method::AgentProgram => "agent.program",
            Method::AgentStream => "agent.stream",
            Method::AgentEvents => "agent.events",
            Method::AgentFetchBitstream => "agent.fetch_bitstream",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Method::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Methods served by the node agent / node daemon (the rest
    /// belong to the management server).
    pub fn is_agent(self) -> bool {
        matches!(
            self,
            Method::AgentHello
                | Method::AgentStatus
                | Method::AgentPing
                | Method::AgentAdmit
                | Method::AgentRelease
                | Method::AgentProgram
                | Method::AgentStream
                | Method::AgentEvents
        )
    }
}

// ================================================== field accessors
//
// The only place wire params are read. Request `from_json` methods
// use these; everything downstream is typed.

fn want_str(p: &Json, key: &str) -> Result<String, ApiError> {
    p.get(key)
        .as_str()
        .map(String::from)
        .ok_or_else(|| {
            ApiError::bad_request(format!(
                "missing/invalid string field '{key}'"
            ))
        })
}

fn want_u64(p: &Json, key: &str) -> Result<u64, ApiError> {
    p.get(key).as_u64().ok_or_else(|| {
        ApiError::bad_request(format!(
            "missing/invalid u64 field '{key}'"
        ))
    })
}

fn want_f64(p: &Json, key: &str) -> Result<f64, ApiError> {
    p.get(key).as_f64().ok_or_else(|| {
        ApiError::bad_request(format!(
            "missing/invalid number field '{key}'"
        ))
    })
}

fn want_bool(p: &Json, key: &str) -> Result<bool, ApiError> {
    p.get(key).as_bool().ok_or_else(|| {
        ApiError::bad_request(format!(
            "missing/invalid bool field '{key}'"
        ))
    })
}

fn want_id<T>(
    p: &Json,
    key: &str,
    parse: impl Fn(&str) -> Option<T>,
) -> Result<T, ApiError> {
    let s = want_str(p, key)?;
    parse(&s).ok_or_else(|| {
        ApiError::bad_request(format!("bad id in field '{key}': '{s}'"))
    })
}

fn opt_str(p: &Json, key: &str) -> Option<String> {
    p.get(key).as_str().map(String::from)
}

fn opt_u64(p: &Json, key: &str) -> Option<u64> {
    p.get(key).as_u64()
}

fn opt_f64(p: &Json, key: &str) -> Option<f64> {
    p.get(key).as_f64()
}

/// Optional lease-token field: absent is fine, present-but-malformed
/// is an error (a mangled capability must not silently read as "no
/// token" and fall through to laxer handling).
fn opt_lease(
    p: &Json,
    key: &str,
) -> Result<Option<LeaseToken>, ApiError> {
    match p.get(key).as_str() {
        None => Ok(None),
        Some(s) => LeaseToken::parse(s).map(Some).ok_or_else(|| {
            ApiError::bad_request(format!(
                "bad lease token in field '{key}': '{s}'"
            ))
        }),
    }
}

fn set_opt_lease(j: &mut Json, key: &str, lease: Option<LeaseToken>) {
    if let Some(t) = lease {
        j.set(key, Json::from(t.to_string()));
    }
}

/// Optional trace-id field: absent is fine, present-but-malformed is
/// an error (same policy as [`opt_lease`]).
fn opt_trace(
    p: &Json,
    key: &str,
) -> Result<Option<TraceId>, ApiError> {
    match p.get(key).as_str() {
        None => Ok(None),
        Some(s) => TraceId::parse(s).map(Some).ok_or_else(|| {
            ApiError::bad_request(format!(
                "bad trace id in field '{key}': '{s}'"
            ))
        }),
    }
}

fn set_opt_trace(j: &mut Json, key: &str, trace: Option<TraceId>) {
    if let Some(t) = trace {
        j.set(key, Json::from(t.to_string()));
    }
}

fn json_or_null_f64(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::from(x),
        None => Json::Null,
    }
}

// ============================================ hello / negotiation

/// `hello` — version negotiation. A legacy v1 client sends no
/// protocol fields at all, which reads as the window `[1, 1]` — no
/// overlap with the supported `[2, 3]`, so it is rejected with
/// `protocol_mismatch`.
#[derive(Debug, Clone, PartialEq)]
pub struct HelloRequest {
    pub proto_min: u32,
    pub proto_max: u32,
}

impl HelloRequest {
    /// The window this crate's typed client advertises.
    pub fn ours() -> HelloRequest {
        HelloRequest {
            proto_min: PROTO_MIN,
            proto_max: PROTO_MAX,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("proto_min", Json::from(u64::from(self.proto_min))),
            ("proto_max", Json::from(u64::from(self.proto_max))),
        ])
    }

    pub fn from_json(p: &Json) -> Result<HelloRequest, ApiError> {
        let proto_min = opt_u64(p, "proto_min").unwrap_or(1) as u32;
        let proto_max =
            opt_u64(p, "proto_max").unwrap_or(u64::from(proto_min)) as u32;
        if proto_max < proto_min {
            return Err(ApiError::bad_request(format!(
                "proto window [{proto_min}, {proto_max}] is inverted"
            )));
        }
        Ok(HelloRequest {
            proto_min,
            proto_max,
        })
    }

    /// The protocol both sides should use, or `None` when the windows
    /// do not overlap.
    pub fn negotiate(&self) -> Option<u32> {
        let lo = self.proto_min.max(PROTO_MIN);
        let hi = self.proto_max.min(PROTO_MAX);
        (lo <= hi).then_some(hi)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct HelloResponse {
    pub version: String,
    pub service: String,
    pub proto_min: u32,
    pub proto_max: u32,
    /// The protocol the server chose for this client.
    pub proto: u32,
}

impl HelloResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::from(self.version.as_str())),
            ("service", Json::from(self.service.as_str())),
            ("proto_min", Json::from(u64::from(self.proto_min))),
            ("proto_max", Json::from(u64::from(self.proto_max))),
            ("proto", Json::from(u64::from(self.proto))),
        ])
    }

    pub fn from_json(p: &Json) -> Result<HelloResponse, ApiError> {
        Ok(HelloResponse {
            version: want_str(p, "version")?,
            service: want_str(p, "service")?,
            proto_min: opt_u64(p, "proto_min").unwrap_or(1) as u32,
            proto_max: opt_u64(p, "proto_max").unwrap_or(1) as u32,
            proto: opt_u64(p, "proto").unwrap_or(1) as u32,
        })
    }
}

// ========================================================= add_user

#[derive(Debug, Clone, PartialEq)]
pub struct AddUserRequest {
    pub name: String,
}

impl AddUserRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("name", Json::from(self.name.as_str()))])
    }

    pub fn from_json(p: &Json) -> Result<AddUserRequest, ApiError> {
        Ok(AddUserRequest {
            name: want_str(p, "name")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AddUserResponse {
    pub user: UserId,
}

impl AddUserResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("user", Json::from(self.user.to_string()))])
    }

    pub fn from_json(p: &Json) -> Result<AddUserResponse, ApiError> {
        Ok(AddUserResponse {
            user: want_id(p, "user", UserId::parse)?,
        })
    }
}

// =========================================================== status

/// `status` / `agent.status` — one device's live state.
#[derive(Debug, Clone, PartialEq)]
pub struct StatusRequest {
    pub fpga: FpgaId,
}

impl StatusRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("fpga", Json::from(self.fpga.to_string()))])
    }

    pub fn from_json(p: &Json) -> Result<StatusRequest, ApiError> {
        Ok(StatusRequest {
            fpga: want_id(p, "fpga", FpgaId::parse)?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct StatusResponse {
    pub fpga: FpgaId,
    pub board: String,
    pub static_design: Option<String>,
    pub regions_total: u64,
    pub regions_configured: u64,
    pub regions_clocked: u64,
    /// Regions quiesced ahead of relocation/teardown (lifecycle state
    /// `draining`; absent on pre-lifecycle servers reads as 0).
    pub regions_draining: u64,
    /// Regions whose design is being relocated (`migrating`).
    pub regions_migrating: u64,
    pub power_w: f64,
}

impl StatusResponse {
    pub fn from_status(st: &crate::fpga::DeviceStatus) -> StatusResponse {
        StatusResponse {
            fpga: st.fpga,
            board: st.board.to_string(),
            static_design: st.static_design.clone(),
            regions_total: st.regions_total as u64,
            regions_configured: st.regions_configured as u64,
            regions_clocked: st.regions_clocked as u64,
            regions_draining: st.regions_draining as u64,
            regions_migrating: st.regions_migrating as u64,
            power_w: st.power_w,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fpga", Json::from(self.fpga.to_string())),
            ("board", Json::from(self.board.as_str())),
            (
                "static_design",
                match &self.static_design {
                    Some(s) => Json::from(s.as_str()),
                    None => Json::Null,
                },
            ),
            ("regions_total", Json::from(self.regions_total)),
            (
                "regions_configured",
                Json::from(self.regions_configured),
            ),
            ("regions_clocked", Json::from(self.regions_clocked)),
            ("regions_draining", Json::from(self.regions_draining)),
            ("regions_migrating", Json::from(self.regions_migrating)),
            ("power_w", Json::from(self.power_w)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<StatusResponse, ApiError> {
        Ok(StatusResponse {
            fpga: want_id(p, "fpga", FpgaId::parse)?,
            board: want_str(p, "board")?,
            static_design: opt_str(p, "static_design"),
            regions_total: want_u64(p, "regions_total")?,
            regions_configured: want_u64(p, "regions_configured")?,
            regions_clocked: want_u64(p, "regions_clocked")?,
            regions_draining: opt_u64(p, "regions_draining")
                .unwrap_or(0),
            regions_migrating: opt_u64(p, "regions_migrating")
                .unwrap_or(0),
            power_w: want_f64(p, "power_w")?,
        })
    }
}

// ====================================================== allocations

/// `alloc_vfpga`. Absent `model`/`class` take the server defaults
/// (RAaaS / interactive); present-but-unparsable values are errors so
/// a typo cannot silently escalate a batch request to interactive.
/// `regions > 1` requests an atomic gang; `co_located` pins the gang
/// to one device; `board` restricts the device model.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocVfpgaRequest {
    pub user: UserId,
    pub model: Option<ServiceModel>,
    pub class: Option<RequestClass>,
    /// Gang size (absent = 1).
    pub regions: Option<u32>,
    pub co_located: Option<bool>,
    /// Board-model constraint ("vc707", "ml605").
    pub board: Option<String>,
    /// Core the tenant intends to program — a prefetch hint: the
    /// bitstream cache starts warming this design while the request
    /// queues, and federated placement prefers nodes already holding
    /// it. Never a constraint; an unknown name is simply ignored.
    pub core: Option<String>,
}

impl AllocVfpgaRequest {
    /// Single-region request (the common case).
    pub fn single(
        user: UserId,
        model: Option<ServiceModel>,
        class: Option<RequestClass>,
    ) -> AllocVfpgaRequest {
        AllocVfpgaRequest {
            user,
            model,
            class,
            regions: None,
            co_located: None,
            board: None,
            core: None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("user", Json::from(self.user.to_string()))]);
        if let Some(m) = self.model {
            j.set("model", Json::from(m.name()));
        }
        if let Some(c) = self.class {
            j.set("class", Json::from(c.name()));
        }
        if let Some(n) = self.regions {
            j.set("regions", Json::from(u64::from(n)));
        }
        if let Some(co) = self.co_located {
            j.set("co_located", Json::from(co));
        }
        if let Some(b) = &self.board {
            j.set("board", Json::from(b.as_str()));
        }
        if let Some(c) = &self.core {
            j.set("core", Json::from(c.as_str()));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<AllocVfpgaRequest, ApiError> {
        let model = match opt_str(p, "model") {
            Some(s) => Some(ServiceModel::parse(&s).ok_or_else(|| {
                ApiError::bad_request(format!("unknown model '{s}'"))
            })?),
            None => None,
        };
        let class = match opt_str(p, "class") {
            Some(s) => Some(RequestClass::parse(&s).ok_or_else(|| {
                ApiError::bad_request(format!("unknown class '{s}'"))
            })?),
            None => None,
        };
        let regions = match opt_u64(p, "regions") {
            Some(0) => {
                return Err(ApiError::bad_request(
                    "'regions' must be >= 1",
                ))
            }
            Some(n) if n > u64::from(u32::MAX) => {
                return Err(ApiError::bad_request(
                    "'regions' out of range",
                ))
            }
            Some(n) => Some(n as u32),
            None => None,
        };
        Ok(AllocVfpgaRequest {
            user: want_id(p, "user", UserId::parse)?,
            model,
            class,
            regions,
            co_located: p.get("co_located").as_bool(),
            board: opt_str(p, "board"),
            core: opt_str(p, "core"),
        })
    }
}

/// One gang member in an `alloc_vfpga` response.
#[derive(Debug, Clone, PartialEq)]
pub struct GangMemberBody {
    pub alloc: AllocationId,
    pub vfpga: VfpgaId,
    pub fpga: FpgaId,
    pub node: NodeId,
}

impl GangMemberBody {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("alloc", Json::from(self.alloc.to_string())),
            ("vfpga", Json::from(self.vfpga.to_string())),
            ("fpga", Json::from(self.fpga.to_string())),
            ("node", Json::from(self.node.to_string())),
        ])
    }

    pub fn from_json(p: &Json) -> Result<GangMemberBody, ApiError> {
        Ok(GangMemberBody {
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            vfpga: want_id(p, "vfpga", VfpgaId::parse)?,
            fpga: want_id(p, "fpga", FpgaId::parse)?,
            node: want_id(p, "node", NodeId::parse)?,
        })
    }
}

/// `alloc_vfpga` response: the primary member's placement (top-level,
/// wire-compatible with the pre-gang shape), the capability `lease`
/// token every mutating RPC must present, and the full member list
/// for gangs.
#[derive(Debug, Clone, PartialEq)]
pub struct AllocVfpgaResponse {
    pub alloc: AllocationId,
    pub vfpga: VfpgaId,
    pub fpga: FpgaId,
    pub node: NodeId,
    pub wait_ms: f64,
    /// Capability token of the lease (gangs share one token).
    pub lease: LeaseToken,
    /// Every gang member, primary first.
    pub members: Vec<GangMemberBody>,
}

impl AllocVfpgaResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("alloc", Json::from(self.alloc.to_string())),
            ("vfpga", Json::from(self.vfpga.to_string())),
            ("fpga", Json::from(self.fpga.to_string())),
            ("node", Json::from(self.node.to_string())),
            ("wait_ms", Json::from(self.wait_ms)),
            ("lease", Json::from(self.lease.to_string())),
            (
                "members",
                Json::Arr(
                    self.members.iter().map(|m| m.to_json()).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(p: &Json) -> Result<AllocVfpgaResponse, ApiError> {
        let alloc = want_id(p, "alloc", AllocationId::parse)?;
        let vfpga = want_id(p, "vfpga", VfpgaId::parse)?;
        let fpga = want_id(p, "fpga", FpgaId::parse)?;
        let node = want_id(p, "node", NodeId::parse)?;
        let members = match p.get("members").as_arr() {
            Some(arr) => arr
                .iter()
                .map(GangMemberBody::from_json)
                .collect::<Result<Vec<_>, _>>()?,
            None => vec![GangMemberBody {
                alloc,
                vfpga,
                fpga,
                node,
            }],
        };
        Ok(AllocVfpgaResponse {
            alloc,
            vfpga,
            fpga,
            node,
            wait_ms: want_f64(p, "wait_ms")?,
            lease: want_id(p, "lease", LeaseToken::parse)?,
            members,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AllocPhysicalRequest {
    pub user: UserId,
}

impl AllocPhysicalRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("user", Json::from(self.user.to_string()))])
    }

    pub fn from_json(p: &Json) -> Result<AllocPhysicalRequest, ApiError> {
        Ok(AllocPhysicalRequest {
            user: want_id(p, "user", UserId::parse)?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AllocPhysicalResponse {
    pub alloc: AllocationId,
    pub fpga: FpgaId,
    pub node: NodeId,
    /// Capability token of the lease.
    pub lease: LeaseToken,
}

impl AllocPhysicalResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("alloc", Json::from(self.alloc.to_string())),
            ("fpga", Json::from(self.fpga.to_string())),
            ("node", Json::from(self.node.to_string())),
            ("lease", Json::from(self.lease.to_string())),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<AllocPhysicalResponse, ApiError> {
        Ok(AllocPhysicalResponse {
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            fpga: want_id(p, "fpga", FpgaId::parse)?,
            node: want_id(p, "node", NodeId::parse)?,
            lease: want_id(p, "lease", LeaseToken::parse)?,
        })
    }
}

/// `release`. The `lease` token is required and the *whole* lease
/// (every gang member) is released.
#[derive(Debug, Clone, PartialEq)]
pub struct ReleaseRequest {
    pub alloc: AllocationId,
    pub lease: Option<LeaseToken>,
}

impl ReleaseRequest {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("alloc", Json::from(self.alloc.to_string()))]);
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<ReleaseRequest, ApiError> {
        Ok(ReleaseRequest {
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ReleaseResponse {
    pub released: bool,
}

impl ReleaseResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("released", Json::from(self.released))])
    }

    pub fn from_json(p: &Json) -> Result<ReleaseResponse, ApiError> {
        Ok(ReleaseResponse {
            released: want_bool(p, "released")?,
        })
    }
}

// ====================================================== programming

#[derive(Debug, Clone, PartialEq)]
pub struct ProgramCoreRequest {
    pub user: UserId,
    pub alloc: AllocationId,
    pub core: String,
    /// Required on protocol ≥ 2 (capability auth).
    pub lease: Option<LeaseToken>,
}

impl ProgramCoreRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("alloc", Json::from(self.alloc.to_string())),
            ("core", Json::from(self.core.as_str())),
        ]);
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<ProgramCoreRequest, ApiError> {
        Ok(ProgramCoreRequest {
            user: want_id(p, "user", UserId::parse)?,
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            core: want_str(p, "core")?,
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ProgramCoreResponse {
    pub programmed: String,
    pub pr_ms: f64,
}

impl ProgramCoreResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("programmed", Json::from(self.programmed.as_str())),
            ("pr_ms", Json::from(self.pr_ms)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<ProgramCoreResponse, ApiError> {
        Ok(ProgramCoreResponse {
            programmed: want_str(p, "programmed")?,
            pr_ms: want_f64(p, "pr_ms")?,
        })
    }
}

/// `program_full` — RSaaS full-bitstream configuration of an
/// exclusively held device. Long-running: protocol 2 returns a job
/// handle.
#[derive(Debug, Clone, PartialEq)]
pub struct ProgramFullRequest {
    pub user: UserId,
    pub alloc: AllocationId,
    pub name: Option<String>,
    /// Required on protocol ≥ 2 (capability auth).
    pub lease: Option<LeaseToken>,
}

impl ProgramFullRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("alloc", Json::from(self.alloc.to_string())),
        ]);
        if let Some(n) = &self.name {
            j.set("name", Json::from(n.as_str()));
        }
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<ProgramFullRequest, ApiError> {
        Ok(ProgramFullRequest {
            user: want_id(p, "user", UserId::parse)?,
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            name: opt_str(p, "name"),
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ProgramFullResponse {
    pub programmed: String,
    pub config_s: f64,
}

impl ProgramFullResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("programmed", Json::from(self.programmed.as_str())),
            ("config_s", Json::from(self.config_s)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<ProgramFullResponse, ApiError> {
        Ok(ProgramFullResponse {
            programmed: want_str(p, "programmed")?,
            config_s: want_f64(p, "config_s")?,
        })
    }
}

// ======================================================== streaming

/// `stream` — stream a workload through a programmed core.
/// Long-running: protocol 2 returns a job handle.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamRequest {
    pub user: UserId,
    pub alloc: AllocationId,
    pub core: String,
    pub mults: u64,
    /// Required on protocol ≥ 2 (capability auth).
    pub lease: Option<LeaseToken>,
    /// When true the response is multi-frame: a stream header, the
    /// result chunks out-of-band (binary frames on proto ≥ 4, base64
    /// JSON frames on proto 3) and a terminal frame carrying the
    /// [`StreamOutcomeBody`] in `stats`. When false the call returns
    /// a job handle as before.
    pub emit_output: bool,
}

impl StreamRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("alloc", Json::from(self.alloc.to_string())),
            ("core", Json::from(self.core.as_str())),
            ("mults", Json::from(self.mults)),
        ]);
        set_opt_lease(&mut j, "lease", self.lease);
        if self.emit_output {
            j.set("emit_output", Json::from(true));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<StreamRequest, ApiError> {
        Ok(StreamRequest {
            user: want_id(p, "user", UserId::parse)?,
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            core: want_str(p, "core")?,
            mults: want_u64(p, "mults")?,
            lease: opt_lease(p, "lease")?,
            emit_output: p.get("emit_output").as_bool().unwrap_or(false),
        })
    }
}

/// `invoke_service` — BAaaS invocation by service name. Long-running:
/// protocol 2 returns a job handle.
#[derive(Debug, Clone, PartialEq)]
pub struct InvokeServiceRequest {
    pub user: UserId,
    pub service: String,
    pub mults: u64,
}

impl InvokeServiceRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("service", Json::from(self.service.as_str())),
            ("mults", Json::from(self.mults)),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<InvokeServiceRequest, ApiError> {
        Ok(InvokeServiceRequest {
            user: want_id(p, "user", UserId::parse)?,
            service: want_str(p, "service")?,
            mults: want_u64(p, "mults")?,
        })
    }
}

/// A completed stream's outcome (shared by `stream` and
/// `invoke_service`, synchronous and job results alike).
#[derive(Debug, Clone, PartialEq)]
pub struct StreamOutcomeBody {
    pub artifact: String,
    pub mults: u64,
    pub input_bytes: u64,
    pub output_bytes: u64,
    pub virtual_stream_s: f64,
    pub virtual_total_s: f64,
    pub virtual_mbps: f64,
    pub wall_s: f64,
    pub wall_mbps: f64,
    pub checksum: f64,
    pub validation_failures: u64,
}

impl StreamOutcomeBody {
    pub fn from_outcome(out: &StreamOutcome) -> StreamOutcomeBody {
        StreamOutcomeBody {
            artifact: out.artifact.clone(),
            mults: out.mults,
            input_bytes: out.input_bytes,
            output_bytes: out.output_bytes,
            virtual_stream_s: out.virtual_stream.as_secs_f64(),
            virtual_total_s: out.virtual_total.as_secs_f64(),
            virtual_mbps: out.virtual_mbps(),
            wall_s: out.wall_secs,
            wall_mbps: out.wall_mbps(),
            checksum: out.checksum,
            validation_failures: out.validation_failures,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("artifact", Json::from(self.artifact.as_str())),
            ("mults", Json::from(self.mults)),
            ("input_bytes", Json::from(self.input_bytes)),
            ("output_bytes", Json::from(self.output_bytes)),
            ("virtual_stream_s", Json::from(self.virtual_stream_s)),
            ("virtual_total_s", Json::from(self.virtual_total_s)),
            ("virtual_mbps", Json::from(self.virtual_mbps)),
            ("wall_s", Json::from(self.wall_s)),
            ("wall_mbps", Json::from(self.wall_mbps)),
            ("checksum", Json::from(self.checksum)),
            (
                "validation_failures",
                Json::from(self.validation_failures),
            ),
        ])
    }

    pub fn from_json(p: &Json) -> Result<StreamOutcomeBody, ApiError> {
        Ok(StreamOutcomeBody {
            artifact: want_str(p, "artifact")?,
            mults: want_u64(p, "mults")?,
            input_bytes: want_u64(p, "input_bytes")?,
            output_bytes: want_u64(p, "output_bytes")?,
            virtual_stream_s: want_f64(p, "virtual_stream_s")?,
            virtual_total_s: want_f64(p, "virtual_total_s")?,
            virtual_mbps: want_f64(p, "virtual_mbps")?,
            wall_s: want_f64(p, "wall_s")?,
            wall_mbps: want_f64(p, "wall_mbps")?,
            checksum: want_f64(p, "checksum")?,
            validation_failures: want_u64(p, "validation_failures")?,
        })
    }
}

// ======================================================== migration

#[derive(Debug, Clone, PartialEq)]
pub struct MigrateRequest {
    pub user: UserId,
    pub alloc: AllocationId,
    /// Required on protocol ≥ 2 (capability auth).
    pub lease: Option<LeaseToken>,
}

impl MigrateRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("alloc", Json::from(self.alloc.to_string())),
        ]);
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<MigrateRequest, ApiError> {
        Ok(MigrateRequest {
            user: want_id(p, "user", UserId::parse)?,
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct MigrateResponse {
    pub from: VfpgaId,
    pub to: VfpgaId,
    pub cross_device: bool,
    pub downtime_ms: f64,
}

impl MigrateResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("from", Json::from(self.from.to_string())),
            ("to", Json::from(self.to.to_string())),
            ("cross_device", Json::from(self.cross_device)),
            ("downtime_ms", Json::from(self.downtime_ms)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<MigrateResponse, ApiError> {
        Ok(MigrateResponse {
            from: want_id(p, "from", VfpgaId::parse)?,
            to: want_id(p, "to", VfpgaId::parse)?,
            cross_device: want_bool(p, "cross_device")?,
            downtime_ms: want_f64(p, "downtime_ms")?,
        })
    }
}

// =============================================== catalogue queries

#[derive(Debug, Clone, PartialEq)]
pub struct ServicesRequest;

impl ServicesRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<ServicesRequest, ApiError> {
        Ok(ServicesRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ServicesResponse {
    pub services: Vec<String>,
}

impl ServicesResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "services",
            Json::Arr(
                self.services.iter().cloned().map(Json::from).collect(),
            ),
        )])
    }

    pub fn from_json(p: &Json) -> Result<ServicesResponse, ApiError> {
        let arr = p.get("services").as_arr().ok_or_else(|| {
            ApiError::bad_request("missing array field 'services'")
        })?;
        Ok(ServicesResponse {
            services: arr
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct CoresRequest;

impl CoresRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<CoresRequest, ApiError> {
        Ok(CoresRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct CoresResponse {
    pub cores: Vec<String>,
}

impl CoresResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "cores",
            Json::Arr(
                self.cores.iter().cloned().map(Json::from).collect(),
            ),
        )])
    }

    pub fn from_json(p: &Json) -> Result<CoresResponse, ApiError> {
        let arr = p.get("cores").as_arr().ok_or_else(|| {
            ApiError::bad_request("missing array field 'cores'")
        })?;
        Ok(CoresResponse {
            cores: arr
                .iter()
                .filter_map(|v| v.as_str().map(String::from))
                .collect(),
        })
    }
}

// ======================================================= monitoring

#[derive(Debug, Clone, PartialEq)]
pub struct MonitorRequest;

impl MonitorRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<MonitorRequest, ApiError> {
        Ok(MonitorRequest)
    }
}

/// Summary of the `sched.wait` latency histogram (virtual ms).
#[derive(Debug, Clone, PartialEq)]
pub struct WaitStats {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl WaitStats {
    pub fn from_histogram(h: &crate::metrics::Histogram) -> WaitStats {
        WaitStats {
            count: h.count(),
            mean_ms: h.mean_us() / 1e3,
            p50_ms: h.quantile_us(0.5) as f64 / 1e3,
            p99_ms: h.quantile_us(0.99) as f64 / 1e3,
            max_ms: h.max_us() as f64 / 1e3,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::from(self.count)),
            ("mean_ms", Json::from(self.mean_ms)),
            ("p50_ms", Json::from(self.p50_ms)),
            ("p99_ms", Json::from(self.p99_ms)),
            ("max_ms", Json::from(self.max_ms)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<WaitStats, ApiError> {
        Ok(WaitStats {
            count: want_u64(p, "count")?,
            mean_ms: want_f64(p, "mean_ms")?,
            p50_ms: want_f64(p, "p50_ms")?,
            p99_ms: want_f64(p, "p99_ms")?,
            max_ms: want_f64(p, "max_ms")?,
        })
    }
}

/// Per-lifecycle-state region occupancy (the `region.state.*`
/// gauges), carried by the `monitor` response so the `draining` /
/// `migrating` states are operator-visible over the wire.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LifecycleOccupancy {
    pub free: i64,
    pub reserved: i64,
    pub programming: i64,
    pub active: i64,
    pub draining: i64,
    pub migrating: i64,
}

impl LifecycleOccupancy {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("free", Json::from(self.free)),
            ("reserved", Json::from(self.reserved)),
            ("programming", Json::from(self.programming)),
            ("active", Json::from(self.active)),
            ("draining", Json::from(self.draining)),
            ("migrating", Json::from(self.migrating)),
        ])
    }

    pub fn from_json(p: &Json) -> LifecycleOccupancy {
        let field = |k: &str| {
            p.get(k).as_f64().map(|v| v as i64).unwrap_or(0)
        };
        LifecycleOccupancy {
            free: field("free"),
            reserved: field("reserved"),
            programming: field("programming"),
            active: field("active"),
            draining: field("draining"),
            migrating: field("migrating"),
        }
    }
}

/// Scheduler telemetry block in the `monitor` response (ROADMAP item:
/// the admission-wait histogram and queue-depth gauge, exposed — plus
/// the lifecycle refactor's quiesce-wait histogram, raced counter and
/// per-state occupancy).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedTelemetry {
    pub queue_depth: i64,
    pub active_grants: i64,
    pub wait: WaitStats,
    /// Wall time relocations spent winning region quiesces
    /// (`sched.preempt.quiesce_wait`).
    pub quiesce_wait: WaitStats,
    /// Times the defense-in-depth preemption retry fired
    /// (`sched.preempt.raced`) — structurally 0.
    pub preempt_raced: u64,
    /// Region occupancy by lifecycle state.
    pub lifecycle: LifecycleOccupancy,
}

impl SchedTelemetry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queue_depth", Json::from(self.queue_depth)),
            ("active_grants", Json::from(self.active_grants)),
            ("wait", self.wait.to_json()),
            ("quiesce_wait", self.quiesce_wait.to_json()),
            ("preempt_raced", Json::from(self.preempt_raced)),
            ("lifecycle", self.lifecycle.to_json()),
        ])
    }

    pub fn from_json(p: &Json) -> Result<SchedTelemetry, ApiError> {
        let depth = p.get("queue_depth").as_f64().ok_or_else(|| {
            ApiError::bad_request("missing field 'queue_depth'")
        })?;
        let grants = p.get("active_grants").as_f64().ok_or_else(|| {
            ApiError::bad_request("missing field 'active_grants'")
        })?;
        // Lifecycle-era fields are tolerated absent (a one-version-
        // older server) and read as empty telemetry.
        let quiesce_wait = WaitStats::from_json(p.get("quiesce_wait"))
            .unwrap_or(WaitStats {
                count: 0,
                mean_ms: 0.0,
                p50_ms: 0.0,
                p99_ms: 0.0,
                max_ms: 0.0,
            });
        Ok(SchedTelemetry {
            queue_depth: depth as i64,
            active_grants: grants as i64,
            wait: WaitStats::from_json(p.get("wait"))?,
            quiesce_wait,
            preempt_raced: opt_u64(p, "preempt_raced").unwrap_or(0),
            lifecycle: LifecycleOccupancy::from_json(p.get("lifecycle")),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct MonitorResponse {
    /// Per-device summaries as rendered by [`crate::hypervisor::Monitor`].
    pub devices: Json,
    pub cloud_utilization: f64,
    pub sched: SchedTelemetry,
}

impl MonitorResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("devices", self.devices.clone()),
            (
                "cloud_utilization",
                Json::from(self.cloud_utilization),
            ),
            ("sched", self.sched.to_json()),
        ])
    }

    pub fn from_json(p: &Json) -> Result<MonitorResponse, ApiError> {
        Ok(MonitorResponse {
            devices: p.get("devices").clone(),
            cloud_utilization: want_f64(p, "cloud_utilization")?,
            sched: SchedTelemetry::from_json(p.get("sched"))?,
        })
    }
}

// ========================================================= workload

#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadRequest {
    pub rate: Option<f64>,
    pub hold_s: Option<f64>,
    pub sessions: Option<u64>,
    pub seed: Option<u64>,
}

impl WorkloadRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![]);
        if let Some(r) = self.rate {
            j.set("rate", Json::from(r));
        }
        if let Some(h) = self.hold_s {
            j.set("hold_s", Json::from(h));
        }
        if let Some(s) = self.sessions {
            j.set("sessions", Json::from(s));
        }
        if let Some(s) = self.seed {
            j.set("seed", Json::from(s));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<WorkloadRequest, ApiError> {
        Ok(WorkloadRequest {
            rate: opt_f64(p, "rate"),
            hold_s: opt_f64(p, "hold_s"),
            sessions: opt_u64(p, "sessions"),
            seed: opt_u64(p, "seed"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadResponse {
    pub served: u64,
    pub rejected: u64,
    pub admission_rate: f64,
    pub mean_setup_ms: f64,
    pub mean_utilization: f64,
    pub makespan_s: f64,
    pub energy_j: f64,
}

impl WorkloadResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("served", Json::from(self.served)),
            ("rejected", Json::from(self.rejected)),
            ("admission_rate", Json::from(self.admission_rate)),
            ("mean_setup_ms", Json::from(self.mean_setup_ms)),
            (
                "mean_utilization",
                Json::from(self.mean_utilization),
            ),
            ("makespan_s", Json::from(self.makespan_s)),
            ("energy_j", Json::from(self.energy_j)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<WorkloadResponse, ApiError> {
        Ok(WorkloadResponse {
            served: want_u64(p, "served")?,
            rejected: want_u64(p, "rejected")?,
            admission_rate: want_f64(p, "admission_rate")?,
            mean_setup_ms: want_f64(p, "mean_setup_ms")?,
            mean_utilization: want_f64(p, "mean_utilization")?,
            makespan_s: want_f64(p, "makespan_s")?,
            energy_j: want_f64(p, "energy_j")?,
        })
    }
}

// ================================================== scheduler admin

#[derive(Debug, Clone, PartialEq)]
pub struct SchedStatusRequest;

impl SchedStatusRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<SchedStatusRequest, ApiError> {
        Ok(SchedStatusRequest)
    }
}

/// The scheduler's queue/grant/reservation snapshot. The payload is
/// the document [`crate::sched::Scheduler::status_json`] renders; the
/// struct carries it opaquely so the shape stays owned by the
/// scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedStatusResponse {
    pub status: Json,
}

impl SchedStatusResponse {
    pub fn to_json(&self) -> Json {
        self.status.clone()
    }

    pub fn from_json(p: &Json) -> Result<SchedStatusResponse, ApiError> {
        Ok(SchedStatusResponse { status: p.clone() })
    }
}

/// `quota_set` — merge semantics: absent fields keep their current
/// values; `max_vfpgas: 0` restores an unlimited cap; a negative
/// `budget_s` clears the budget.
#[derive(Debug, Clone, PartialEq)]
pub struct QuotaSetRequest {
    pub user: UserId,
    pub max_vfpgas: Option<u64>,
    pub budget_s: Option<f64>,
    pub weight: Option<u64>,
}

impl QuotaSetRequest {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("user", Json::from(self.user.to_string()))]);
        if let Some(m) = self.max_vfpgas {
            j.set("max_vfpgas", Json::from(m));
        }
        if let Some(b) = self.budget_s {
            j.set("budget_s", Json::from(b));
        }
        if let Some(w) = self.weight {
            j.set("weight", Json::from(w));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<QuotaSetRequest, ApiError> {
        Ok(QuotaSetRequest {
            user: want_id(p, "user", UserId::parse)?,
            max_vfpgas: opt_u64(p, "max_vfpgas"),
            budget_s: opt_f64(p, "budget_s"),
            weight: opt_u64(p, "weight"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct QuotaGetRequest {
    pub user: UserId,
}

impl QuotaGetRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("user", Json::from(self.user.to_string()))])
    }

    pub fn from_json(p: &Json) -> Result<QuotaGetRequest, ApiError> {
        Ok(QuotaGetRequest {
            user: want_id(p, "user", UserId::parse)?,
        })
    }
}

/// A tenant's quota as reported on the wire. `max_vfpgas: 0` means
/// unlimited (mirroring `quota_set`'s convention — `u64::MAX` would
/// lose precision through the f64-backed [`Json`] number).
#[derive(Debug, Clone, PartialEq)]
pub struct QuotaResponse {
    pub user: UserId,
    pub max_vfpgas: u64,
    pub budget_s: Option<f64>,
    pub weight: u64,
    pub in_use: u64,
}

impl QuotaResponse {
    pub fn from_quota(
        user: UserId,
        quota: &crate::sched::TenantQuota,
        in_use: u64,
    ) -> QuotaResponse {
        QuotaResponse {
            user,
            max_vfpgas: if quota.max_concurrent == u64::MAX {
                0
            } else {
                quota.max_concurrent
            },
            budget_s: quota.device_seconds_budget,
            weight: quota.weight,
            in_use,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("max_vfpgas", Json::from(self.max_vfpgas)),
            ("budget_s", json_or_null_f64(self.budget_s)),
            ("weight", Json::from(self.weight)),
            ("in_use", Json::from(self.in_use)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<QuotaResponse, ApiError> {
        Ok(QuotaResponse {
            user: want_id(p, "user", UserId::parse)?,
            max_vfpgas: want_u64(p, "max_vfpgas")?,
            budget_s: opt_f64(p, "budget_s"),
            weight: want_u64(p, "weight")?,
            in_use: want_u64(p, "in_use")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct UsageReportRequest;

impl UsageReportRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<UsageReportRequest, ApiError> {
        Ok(UsageReportRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct UsageReportResponse {
    /// Per-tenant rows as rendered by the usage ledger.
    pub tenants: Json,
    /// Pre-rendered operator table.
    pub table: String,
}

impl UsageReportResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tenants", self.tenants.clone()),
            ("table", Json::from(self.table.as_str())),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<UsageReportResponse, ApiError> {
        Ok(UsageReportResponse {
            tenants: p.get("tenants").clone(),
            table: want_str(p, "table")?,
        })
    }
}

/// `reserve`. An optional `model` pins the reservation to one
/// service model's device pool (region-count- and model-aware
/// reservations); absent keeps the cluster-wide semantics.
#[derive(Debug, Clone, PartialEq)]
pub struct ReserveRequest {
    pub user: UserId,
    pub regions: u64,
    pub model: Option<ServiceModel>,
    pub start_s: Option<f64>,
    pub duration_s: Option<f64>,
}

impl ReserveRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("regions", Json::from(self.regions)),
        ]);
        if let Some(m) = self.model {
            j.set("model", Json::from(m.name()));
        }
        if let Some(s) = self.start_s {
            j.set("start_s", Json::from(s));
        }
        if let Some(d) = self.duration_s {
            j.set("duration_s", Json::from(d));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<ReserveRequest, ApiError> {
        let model = match opt_str(p, "model") {
            Some(s) => Some(ServiceModel::parse(&s).ok_or_else(|| {
                ApiError::bad_request(format!("unknown model '{s}'"))
            })?),
            None => None,
        };
        Ok(ReserveRequest {
            user: want_id(p, "user", UserId::parse)?,
            regions: want_u64(p, "regions")?,
            model,
            start_s: opt_f64(p, "start_s"),
            duration_s: opt_f64(p, "duration_s"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ReserveResponse {
    pub reservation: ReservationId,
}

impl ReserveResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "reservation",
            Json::from(self.reservation.to_string()),
        )])
    }

    pub fn from_json(p: &Json) -> Result<ReserveResponse, ApiError> {
        Ok(ReserveResponse {
            reservation: want_id(
                p,
                "reservation",
                ReservationId::parse,
            )?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct CancelReservationRequest {
    pub reservation: ReservationId,
}

impl CancelReservationRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "reservation",
            Json::from(self.reservation.to_string()),
        )])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<CancelReservationRequest, ApiError> {
        Ok(CancelReservationRequest {
            reservation: want_id(
                p,
                "reservation",
                ReservationId::parse,
            )?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct CancelReservationResponse {
    pub cancelled: bool,
}

impl CancelReservationResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("cancelled", Json::from(self.cancelled))])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<CancelReservationResponse, ApiError> {
        Ok(CancelReservationResponse {
            cancelled: want_bool(p, "cancelled")?,
        })
    }
}

// =========================================================== energy

#[derive(Debug, Clone, PartialEq)]
pub struct EnergyRequest;

impl EnergyRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<EnergyRequest, ApiError> {
        Ok(EnergyRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct EnergyResponse {
    pub joules: f64,
    pub power_w: f64,
}

impl EnergyResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("joules", Json::from(self.joules)),
            ("power_w", Json::from(self.power_w)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<EnergyResponse, ApiError> {
        Ok(EnergyResponse {
            joules: want_f64(p, "joules")?,
            power_w: want_f64(p, "power_w")?,
        })
    }
}

// ========================================================== db_dump

#[derive(Debug, Clone, PartialEq)]
pub struct DbDumpRequest;

impl DbDumpRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<DbDumpRequest, ApiError> {
        Ok(DbDumpRequest)
    }
}

/// The device database document. Serialized as the raw DB JSON (both
/// protocols) so `DeviceDb::from_json` reads it directly.
#[derive(Debug, Clone, PartialEq)]
pub struct DbDumpResponse {
    pub db: Json,
}

impl DbDumpResponse {
    pub fn to_json(&self) -> Json {
        self.db.clone()
    }

    pub fn from_json(p: &Json) -> Result<DbDumpResponse, ApiError> {
        Ok(DbDumpResponse { db: p.clone() })
    }
}

// ============================================================= jobs

/// Response to submitting a long-running operation on protocol ≥ 2.
/// Carries the token that owns the job: the lease token the caller
/// presented, or a fresh job-scoped token for leaseless operations
/// (`invoke_service`) — `job_*` calls on an owned job must present it.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSubmitResponse {
    pub job: JobId,
    pub lease: Option<LeaseToken>,
}

impl JobSubmitResponse {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("job", Json::from(self.job.to_string()))]);
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<JobSubmitResponse, ApiError> {
        Ok(JobSubmitResponse {
            job: want_id(p, "job", JobId::parse)?,
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JobStatusRequest {
    pub job: JobId,
    /// Owner token; required on protocol ≥ 2 when the job is owned.
    pub lease: Option<LeaseToken>,
}

impl JobStatusRequest {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("job", Json::from(self.job.to_string()))]);
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<JobStatusRequest, ApiError> {
        Ok(JobStatusRequest {
            job: want_id(p, "job", JobId::parse)?,
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JobWaitRequest {
    pub job: JobId,
    /// Server-side wait bound; the server default applies when
    /// absent, and the server clamps it below the client library's
    /// socket read timeout (see `jobs::MAX_WAIT_S`) — long waits are
    /// built by retrying on the retryable `timeout` code.
    pub timeout_s: Option<f64>,
    /// Owner token; required on protocol ≥ 2 when the job is owned.
    pub lease: Option<LeaseToken>,
}

impl JobWaitRequest {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("job", Json::from(self.job.to_string()))]);
        if let Some(t) = self.timeout_s {
            j.set("timeout_s", Json::from(t));
        }
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<JobWaitRequest, ApiError> {
        Ok(JobWaitRequest {
            job: want_id(p, "job", JobId::parse)?,
            timeout_s: opt_f64(p, "timeout_s"),
            lease: opt_lease(p, "lease")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JobCancelRequest {
    pub job: JobId,
    /// Owner token; required on protocol ≥ 2 when the job is owned.
    pub lease: Option<LeaseToken>,
}

impl JobCancelRequest {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("job", Json::from(self.job.to_string()))]);
        set_opt_lease(&mut j, "lease", self.lease);
        j
    }

    pub fn from_json(p: &Json) -> Result<JobCancelRequest, ApiError> {
        Ok(JobCancelRequest {
            job: want_id(p, "job", JobId::parse)?,
            lease: opt_lease(p, "lease")?,
        })
    }
}

/// One job's wire representation (response of `job_status`,
/// `job_wait` and `job_cancel`).
#[derive(Debug, Clone, PartialEq)]
pub struct JobBody {
    pub job: JobId,
    /// The method the job runs ("stream", "program_full", ...).
    pub method: String,
    /// "running" | "done" | "failed" | "cancelled".
    pub state: String,
    /// The method's response body, when `state == "done"`.
    pub result: Option<Json>,
    /// The failure, when `state == "failed"`.
    pub error: Option<ApiError>,
    /// Flight-recorder trace the job runs under (inherited from the
    /// submitting RPC), when tracing was on at submit time.
    pub trace: Option<TraceId>,
}

impl JobBody {
    pub fn is_terminal(&self) -> bool {
        self.state != "running"
    }

    /// Unwrap a finished job into its result, mapping failed /
    /// cancelled states to errors (the synchronous-call equivalence
    /// `submit + job_wait ≡ old blocking call` rests on this).
    pub fn into_done(self) -> Result<Json, ApiError> {
        match self.state.as_str() {
            "done" => self.result.ok_or_else(|| {
                ApiError::internal("done job carried no result")
            }),
            "failed" => Err(self.error.unwrap_or_else(|| {
                ApiError::internal("failed job carried no error")
            })),
            "cancelled" => Err(ApiError::new(
                ErrorCode::Cancelled,
                format!("{} was cancelled", self.job),
            )),
            s => Err(ApiError::internal(format!(
                "{} still '{s}'",
                self.job
            ))),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("job", Json::from(self.job.to_string())),
            ("method", Json::from(self.method.as_str())),
            ("state", Json::from(self.state.as_str())),
            (
                "result",
                self.result.clone().unwrap_or(Json::Null),
            ),
            (
                "error",
                match &self.error {
                    Some(e) => e.to_json(),
                    None => Json::Null,
                },
            ),
        ]);
        set_opt_trace(&mut j, "trace", self.trace);
        j
    }

    pub fn from_json(p: &Json) -> Result<JobBody, ApiError> {
        let error = match p.get("error") {
            Json::Null => None,
            v => Some(ApiError::from_json(v).map_err(|e| {
                ApiError::bad_request(format!("bad job error field: {e}"))
            })?),
        };
        let result = match p.get("result") {
            Json::Null => None,
            v => Some(v.clone()),
        };
        Ok(JobBody {
            job: want_id(p, "job", JobId::parse)?,
            method: want_str(p, "method")?,
            state: want_str(p, "state")?,
            result,
            error,
            trace: opt_trace(p, "trace")?,
        })
    }
}

// ==================================== protocol 3: event streaming

/// Event topics a subscription can select.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Topic {
    /// Job progress frames ([`Event::JobProgress`]).
    Job,
    /// Lease placement changes ([`Event::LeasePlacementChanged`]).
    Placement,
    /// Region lifecycle transitions ([`Event::RegionTransition`]).
    Region,
    /// Scheduler telemetry ([`Event::QueueDepth`],
    /// [`Event::GrantIssued`]).
    Sched,
}

impl Topic {
    pub const ALL: [Topic; 4] =
        [Topic::Job, Topic::Placement, Topic::Region, Topic::Sched];

    pub fn name(self) -> &'static str {
        match self {
            Topic::Job => "job",
            Topic::Placement => "placement",
            Topic::Region => "region",
            Topic::Sched => "sched",
        }
    }

    pub fn parse(s: &str) -> Option<Topic> {
        Topic::ALL.iter().copied().find(|t| t.name() == s)
    }
}

/// What a subscription wants to see. Empty vectors mean "no
/// constraint on that axis". The *tenant* axis is not client-chosen:
/// it comes from the lease token presented at `subscribe` time —
/// tenant- and token-scoped events are only ever delivered to the
/// subscription holding the matching capability.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SubscriptionFilter {
    pub topics: Vec<Topic>,
    pub job_ids: Vec<JobId>,
    pub fpga_ids: Vec<FpgaId>,
}

impl SubscriptionFilter {
    /// Everything the subscription is allowed to see.
    pub fn all() -> SubscriptionFilter {
        SubscriptionFilter::default()
    }

    /// Only `topic`.
    pub fn topic(topic: Topic) -> SubscriptionFilter {
        SubscriptionFilter {
            topics: vec![topic],
            ..SubscriptionFilter::default()
        }
    }

    /// Does this filter select `event`? (Scope/tenant checks are the
    /// bus's job — this is the client-chosen axis only.)
    pub fn matches(&self, event: &Event) -> bool {
        if !self.topics.is_empty()
            && !self.topics.contains(&event.topic())
        {
            return false;
        }
        if !self.job_ids.is_empty() {
            if let Some(job) = event.job_id() {
                if !self.job_ids.contains(&job) {
                    return false;
                }
            }
        }
        if !self.fpga_ids.is_empty() {
            if let Some(fpga) = event.fpga_id() {
                if !self.fpga_ids.contains(&fpga) {
                    return false;
                }
            }
        }
        true
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![]);
        if !self.topics.is_empty() {
            j.set(
                "topics",
                Json::Arr(
                    self.topics
                        .iter()
                        .map(|t| Json::from(t.name()))
                        .collect(),
                ),
            );
        }
        if !self.job_ids.is_empty() {
            j.set(
                "job_ids",
                Json::Arr(
                    self.job_ids
                        .iter()
                        .map(|id| Json::from(id.to_string()))
                        .collect(),
                ),
            );
        }
        if !self.fpga_ids.is_empty() {
            j.set(
                "fpga_ids",
                Json::Arr(
                    self.fpga_ids
                        .iter()
                        .map(|id| Json::from(id.to_string()))
                        .collect(),
                ),
            );
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<SubscriptionFilter, ApiError> {
        let mut filter = SubscriptionFilter::default();
        if let Some(arr) = p.get("topics").as_arr() {
            for t in arr {
                let s = t.as_str().ok_or_else(|| {
                    ApiError::bad_request("non-string topic")
                })?;
                filter.topics.push(Topic::parse(s).ok_or_else(|| {
                    ApiError::bad_request(format!("unknown topic '{s}'"))
                })?);
            }
        }
        if let Some(arr) = p.get("job_ids").as_arr() {
            for v in arr {
                let s = v.as_str().unwrap_or("");
                filter.job_ids.push(JobId::parse(s).ok_or_else(|| {
                    ApiError::bad_request(format!("bad job id '{s}'"))
                })?);
            }
        }
        if let Some(arr) = p.get("fpga_ids").as_arr() {
            for v in arr {
                let s = v.as_str().unwrap_or("");
                filter.fpga_ids.push(FpgaId::parse(s).ok_or_else(
                    || {
                        ApiError::bad_request(format!(
                            "bad fpga id '{s}'"
                        ))
                    },
                )?);
            }
        }
        Ok(filter)
    }
}

/// A typed server-push event, delivered as `subscribe` stream
/// frames. The wire form is tagged with `"type"`.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A job moved through a phase boundary or stream checkpoint —
    /// and, on the terminal frame, finished: `state` leaves
    /// `"running"` and `result` carries the exact job body
    /// `job_wait` returns.
    JobProgress {
        job: JobId,
        /// RPC method the job runs ("stream", "program_full", ...).
        method: String,
        /// Phase label ("configuring", "streaming", "done", ...).
        phase: String,
        bytes_streamed: u64,
        /// Rough completion estimate in [0, 100].
        pct: f64,
        /// "running" until the terminal frame.
        state: String,
        /// Terminal frames only: the job body (same JSON `job_wait`
        /// returns).
        result: Option<Json>,
        /// Flight-recorder trace the job runs under, so a watcher
        /// can pull the span tree with `trace_get`.
        trace: Option<TraceId>,
    },
    /// A lease member was relocated (preemption, operator `migrate`,
    /// or gang relocation): the placement the tenant cached is stale.
    LeasePlacementChanged {
        alloc: AllocationId,
        /// Where the member lives now.
        vfpga: VfpgaId,
        fpga: FpgaId,
        /// Lifetime move count of the member (monotonic).
        migrations: u64,
    },
    /// One validated region lifecycle transition (sourced from the
    /// per-device transition log).
    RegionTransition {
        fpga: FpgaId,
        region: VfpgaId,
        from: String,
        to: String,
        at_s: f64,
    },
    /// Admission queue depth changed.
    QueueDepth { depth: u64 },
    /// The scheduler issued a grant (operator telemetry).
    GrantIssued {
        alloc: AllocationId,
        tenant: UserId,
        model: ServiceModel,
        class: RequestClass,
        wait_ms: f64,
    },
    /// A federated event forwarded from a node daemon's local bus:
    /// the inner event, tagged with the originating node and that
    /// node's *own* journal cursor. Per-node cursors are dense, so a
    /// cluster-wide subscriber can verify gapless coverage per node;
    /// the outer management cursor still orders the merged stream.
    NodeTagged {
        node: NodeId,
        /// Position in the originating node's local event journal.
        node_cursor: u64,
        event: Box<Event>,
    },
}

impl Event {
    pub fn topic(&self) -> Topic {
        match self {
            Event::JobProgress { .. } => Topic::Job,
            Event::LeasePlacementChanged { .. } => Topic::Placement,
            Event::RegionTransition { .. } => Topic::Region,
            Event::QueueDepth { .. } | Event::GrantIssued { .. } => {
                Topic::Sched
            }
            // Filters see through the federation wrapper: a watcher
            // of Topic::Sched receives node-local sched events too.
            Event::NodeTagged { event, .. } => event.topic(),
        }
    }

    /// Wire tag of this event kind.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::JobProgress { .. } => "job_progress",
            Event::LeasePlacementChanged { .. } => {
                "lease_placement_changed"
            }
            Event::RegionTransition { .. } => "region_transition",
            Event::QueueDepth { .. } => "queue_depth",
            Event::GrantIssued { .. } => "grant_issued",
            Event::NodeTagged { .. } => "node_event",
        }
    }

    /// The job this event concerns, for filter matching.
    pub fn job_id(&self) -> Option<JobId> {
        match self {
            Event::JobProgress { job, .. } => Some(*job),
            Event::NodeTagged { event, .. } => event.job_id(),
            _ => None,
        }
    }

    /// The device this event concerns, for filter matching.
    pub fn fpga_id(&self) -> Option<FpgaId> {
        match self {
            Event::LeasePlacementChanged { fpga, .. }
            | Event::RegionTransition { fpga, .. } => Some(*fpga),
            Event::NodeTagged { event, .. } => event.fpga_id(),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("type", Json::from(self.kind()))]);
        match self {
            Event::JobProgress {
                job,
                method,
                phase,
                bytes_streamed,
                pct,
                state,
                result,
                trace,
            } => {
                j.set("job", Json::from(job.to_string()));
                j.set("method", Json::from(method.as_str()));
                j.set("phase", Json::from(phase.as_str()));
                j.set("bytes_streamed", Json::from(*bytes_streamed));
                j.set("pct", Json::from(*pct));
                j.set("state", Json::from(state.as_str()));
                if let Some(r) = result {
                    j.set("result", r.clone());
                }
                set_opt_trace(&mut j, "trace", *trace);
            }
            Event::LeasePlacementChanged {
                alloc,
                vfpga,
                fpga,
                migrations,
            } => {
                j.set("alloc", Json::from(alloc.to_string()));
                j.set("vfpga", Json::from(vfpga.to_string()));
                j.set("fpga", Json::from(fpga.to_string()));
                j.set("migrations", Json::from(*migrations));
            }
            Event::RegionTransition {
                fpga,
                region,
                from,
                to,
                at_s,
            } => {
                j.set("fpga", Json::from(fpga.to_string()));
                j.set("region", Json::from(region.to_string()));
                j.set("from", Json::from(from.as_str()));
                j.set("to", Json::from(to.as_str()));
                j.set("at_s", Json::from(*at_s));
            }
            Event::QueueDepth { depth } => {
                j.set("depth", Json::from(*depth));
            }
            Event::GrantIssued {
                alloc,
                tenant,
                model,
                class,
                wait_ms,
            } => {
                j.set("alloc", Json::from(alloc.to_string()));
                j.set("tenant", Json::from(tenant.to_string()));
                j.set("model", Json::from(model.name()));
                j.set("class", Json::from(class.name()));
                j.set("wait_ms", Json::from(*wait_ms));
            }
            Event::NodeTagged {
                node,
                node_cursor,
                event,
            } => {
                j.set("node", Json::from(node.to_string()));
                j.set("node_cursor", Json::from(*node_cursor));
                j.set("event", event.to_json());
            }
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<Event, ApiError> {
        match want_str(p, "type")?.as_str() {
            "job_progress" => Ok(Event::JobProgress {
                job: want_id(p, "job", JobId::parse)?,
                method: want_str(p, "method")?,
                phase: want_str(p, "phase")?,
                bytes_streamed: want_u64(p, "bytes_streamed")?,
                pct: want_f64(p, "pct")?,
                state: want_str(p, "state")?,
                result: match p.get("result") {
                    Json::Null => None,
                    v => Some(v.clone()),
                },
                trace: opt_trace(p, "trace")?,
            }),
            "lease_placement_changed" => {
                Ok(Event::LeasePlacementChanged {
                    alloc: want_id(p, "alloc", AllocationId::parse)?,
                    vfpga: want_id(p, "vfpga", VfpgaId::parse)?,
                    fpga: want_id(p, "fpga", FpgaId::parse)?,
                    migrations: want_u64(p, "migrations")?,
                })
            }
            "region_transition" => Ok(Event::RegionTransition {
                fpga: want_id(p, "fpga", FpgaId::parse)?,
                region: want_id(p, "region", VfpgaId::parse)?,
                from: want_str(p, "from")?,
                to: want_str(p, "to")?,
                at_s: want_f64(p, "at_s")?,
            }),
            "queue_depth" => Ok(Event::QueueDepth {
                depth: want_u64(p, "depth")?,
            }),
            "grant_issued" => Ok(Event::GrantIssued {
                alloc: want_id(p, "alloc", AllocationId::parse)?,
                tenant: want_id(p, "tenant", UserId::parse)?,
                model: ServiceModel::parse(&want_str(p, "model")?)
                    .ok_or_else(|| {
                        ApiError::bad_request("unknown model in event")
                    })?,
                class: RequestClass::parse(&want_str(p, "class")?)
                    .ok_or_else(|| {
                        ApiError::bad_request("unknown class in event")
                    })?,
                wait_ms: want_f64(p, "wait_ms")?,
            }),
            "node_event" => Ok(Event::NodeTagged {
                node: want_id(p, "node", NodeId::parse)?,
                node_cursor: want_u64(p, "node_cursor")?,
                event: Box::new(Event::from_json(p.get("event"))?),
            }),
            t => Err(ApiError::bad_request(format!(
                "unknown event type '{t}'"
            ))),
        }
    }
}

/// `subscribe` — open a server-push event stream (protocol 3 only;
/// the response is multi-frame). The optional `lease` token scopes
/// the subscription to that capability's tenant: token- and
/// tenant-scoped events (job progress, placement changes) are only
/// delivered to the holder; without a token only public (operator)
/// events arrive.
#[derive(Debug, Clone, PartialEq)]
pub struct SubscribeRequest {
    pub filter: SubscriptionFilter,
    pub lease: Option<LeaseToken>,
    /// Close the stream after this many events (None = bounded only
    /// by the timeout).
    pub max_events: Option<u64>,
    /// Server-side stream bound in wall seconds (clamped like
    /// `job_wait`; long watches re-subscribe on the terminal frame).
    pub timeout_s: Option<f64>,
    /// Resume position: replay journaled events with cursor >= this
    /// before switching to live delivery (gapless when the cursor is
    /// still within the journal's retention window). Clients quote
    /// the cursor from the last frame they saw, plus one.
    pub from_cursor: Option<u64>,
}

impl SubscribeRequest {
    pub fn to_json(&self) -> Json {
        let mut j = self.filter.to_json();
        set_opt_lease(&mut j, "lease", self.lease);
        if let Some(n) = self.max_events {
            j.set("max_events", Json::from(n));
        }
        if let Some(t) = self.timeout_s {
            j.set("timeout_s", Json::from(t));
        }
        if let Some(c) = self.from_cursor {
            j.set("from_cursor", Json::from(c));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<SubscribeRequest, ApiError> {
        Ok(SubscribeRequest {
            filter: SubscriptionFilter::from_json(p)?,
            lease: opt_lease(p, "lease")?,
            max_events: opt_u64(p, "max_events"),
            timeout_s: opt_f64(p, "timeout_s"),
            from_cursor: opt_u64(p, "from_cursor"),
        })
    }
}

/// The `subscribe` stream *header* body: the subscription id plus
/// the effective (clamped) bounds the server will honor.
#[derive(Debug, Clone, PartialEq)]
pub struct SubscribeResponse {
    pub subscription: u64,
    pub timeout_s: f64,
}

impl SubscribeResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("subscription", Json::from(self.subscription)),
            ("timeout_s", Json::from(self.timeout_s)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<SubscribeResponse, ApiError> {
        Ok(SubscribeResponse {
            subscription: want_u64(p, "subscription")?,
            timeout_s: want_f64(p, "timeout_s")?,
        })
    }
}

// ============================================ lifecycle transition log

/// One applied region lifecycle transition on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct TransitionBody {
    pub region: VfpgaId,
    pub from: String,
    pub to: String,
    pub at_s: f64,
}

impl TransitionBody {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("region", Json::from(self.region.to_string())),
            ("from", Json::from(self.from.as_str())),
            ("to", Json::from(self.to.as_str())),
            ("at_s", Json::from(self.at_s)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<TransitionBody, ApiError> {
        Ok(TransitionBody {
            region: want_id(p, "region", VfpgaId::parse)?,
            from: want_str(p, "from")?,
            to: want_str(p, "to")?,
            at_s: want_f64(p, "at_s")?,
        })
    }
}

/// `lifecycle_log` — the newest records of one device's bounded
/// transition log (`db_dump` only shows *current* states; the log
/// shows how regions got there).
#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleLogRequest {
    pub fpga: FpgaId,
    /// Newest records to return (absent = the whole retained log).
    pub limit: Option<u64>,
}

impl LifecycleLogRequest {
    pub fn to_json(&self) -> Json {
        let mut j =
            Json::obj(vec![("fpga", Json::from(self.fpga.to_string()))]);
        if let Some(n) = self.limit {
            j.set("limit", Json::from(n));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<LifecycleLogRequest, ApiError> {
        Ok(LifecycleLogRequest {
            fpga: want_id(p, "fpga", FpgaId::parse)?,
            limit: opt_u64(p, "limit"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct LifecycleLogResponse {
    pub fpga: FpgaId,
    /// Oldest-first within the returned window.
    pub records: Vec<TransitionBody>,
    /// Records aged out of the bounded log before this query.
    pub dropped: u64,
}

impl LifecycleLogResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fpga", Json::from(self.fpga.to_string())),
            (
                "records",
                Json::Arr(
                    self.records.iter().map(|r| r.to_json()).collect(),
                ),
            ),
            ("dropped", Json::from(self.dropped)),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<LifecycleLogResponse, ApiError> {
        let records = p
            .get("records")
            .as_arr()
            .ok_or_else(|| {
                ApiError::bad_request("missing array field 'records'")
            })?
            .iter()
            .map(TransitionBody::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(LifecycleLogResponse {
            fpga: want_id(p, "fpga", FpgaId::parse)?,
            records,
            dropped: want_u64(p, "dropped")?,
        })
    }
}

// ============================================== scheduler policy knob

#[derive(Debug, Clone, PartialEq)]
pub struct SchedPolicyGetRequest;

impl SchedPolicyGetRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(
        _p: &Json,
    ) -> Result<SchedPolicyGetRequest, ApiError> {
        Ok(SchedPolicyGetRequest)
    }
}

/// `sched_policy_set` — where preemption relocates its victims
/// ("pack" consolidates, "spread" balances).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedPolicySetRequest {
    pub policy: String,
}

impl SchedPolicySetRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("policy", Json::from(self.policy.as_str()))])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<SchedPolicySetRequest, ApiError> {
        Ok(SchedPolicySetRequest {
            policy: want_str(p, "policy")?,
        })
    }
}

/// Response of both policy RPCs: the effective policy.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedPolicyResponse {
    pub policy: String,
}

impl SchedPolicyResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("policy", Json::from(self.policy.as_str()))])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<SchedPolicyResponse, ApiError> {
        Ok(SchedPolicyResponse {
            policy: want_str(p, "policy")?,
        })
    }
}

// ===================================================== observability

/// `metrics_export` — dump every registered instrument.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsExportRequest;

impl MetricsExportRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(
        _p: &Json,
    ) -> Result<MetricsExportRequest, ApiError> {
        Ok(MetricsExportRequest)
    }
}

/// One histogram on the wire: counts *with* boundary metadata, so a
/// consumer can recompute percentiles instead of trusting clamped
/// server-side summaries.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramBody {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    /// Inclusive upper bound of each finite bucket, in µs.
    pub bounds_us: Vec<u64>,
    /// Per-finite-bucket sample counts; same length as `bounds_us`.
    pub buckets: Vec<u64>,
    /// Samples above the last finite bound.
    pub overflow: u64,
}

impl HistogramBody {
    pub fn from_snapshot(s: &HistogramSnapshot) -> HistogramBody {
        HistogramBody {
            count: s.count,
            sum_us: s.sum_us,
            max_us: s.max_us,
            bounds_us: s.bounds_us.clone(),
            buckets: s.buckets.clone(),
            overflow: s.overflow,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::from(self.count)),
            ("sum_us", Json::from(self.sum_us)),
            ("max_us", Json::from(self.max_us)),
            (
                "bounds_us",
                Json::Arr(
                    self.bounds_us.iter().map(|b| Json::from(*b)).collect(),
                ),
            ),
            (
                "buckets",
                Json::Arr(
                    self.buckets.iter().map(|b| Json::from(*b)).collect(),
                ),
            ),
            ("overflow", Json::from(self.overflow)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<HistogramBody, ApiError> {
        let u64_arr = |key: &str| -> Result<Vec<u64>, ApiError> {
            p.get(key)
                .as_arr()
                .ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "missing array field '{key}'"
                    ))
                })?
                .iter()
                .map(|v| {
                    v.as_u64().ok_or_else(|| {
                        ApiError::bad_request(format!(
                            "non-u64 entry in '{key}'"
                        ))
                    })
                })
                .collect()
        };
        let body = HistogramBody {
            count: want_u64(p, "count")?,
            sum_us: want_u64(p, "sum_us")?,
            max_us: want_u64(p, "max_us")?,
            bounds_us: u64_arr("bounds_us")?,
            buckets: u64_arr("buckets")?,
            overflow: want_u64(p, "overflow")?,
        };
        if body.bounds_us.len() != body.buckets.len() {
            return Err(ApiError::bad_request(
                "histogram bounds/buckets length mismatch",
            ));
        }
        Ok(body)
    }
}

/// `metrics_export` response: every instrument by name. Instrument
/// names are unique across kinds (the registry enforces it).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsExportResponse {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub histograms: Vec<(String, HistogramBody)>,
}

impl MetricsExportResponse {
    pub fn from_snapshot(s: &RegistrySnapshot) -> MetricsExportResponse {
        MetricsExportResponse {
            counters: s.counters.clone(),
            gauges: s.gauges.clone(),
            histograms: s
                .histograms
                .iter()
                .map(|(n, h)| {
                    (n.clone(), HistogramBody::from_snapshot(h))
                })
                .collect(),
        }
    }

    pub fn to_json(&self) -> Json {
        let counters = Json::obj(
            self.counters
                .iter()
                .map(|(n, v)| (n.as_str(), Json::from(*v)))
                .collect(),
        );
        let gauges = Json::obj(
            self.gauges
                .iter()
                .map(|(n, v)| {
                    (n.as_str(), Json::from(*v as f64))
                })
                .collect(),
        );
        let histograms = Json::obj(
            self.histograms
                .iter()
                .map(|(n, h)| (n.as_str(), h.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<MetricsExportResponse, ApiError> {
        let obj = |key: &str| {
            p.get(key).as_obj().ok_or_else(|| {
                ApiError::bad_request(format!(
                    "missing object field '{key}'"
                ))
            })
        };
        let mut out = MetricsExportResponse::default();
        for (n, v) in obj("counters")? {
            out.counters.push((
                n.clone(),
                v.as_u64().ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "non-u64 counter '{n}'"
                    ))
                })?,
            ));
        }
        for (n, v) in obj("gauges")? {
            out.gauges.push((
                n.clone(),
                v.as_f64().ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "non-number gauge '{n}'"
                    ))
                })? as i64,
            ));
        }
        for (n, v) in obj("histograms")? {
            out.histograms
                .push((n.clone(), HistogramBody::from_json(v)?));
        }
        Ok(out)
    }
}

/// `trace_get` — fetch a span tree from the flight recorder, by
/// trace id or by the job that carried it (exactly one must be set).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceGetRequest {
    pub trace: Option<TraceId>,
    pub job: Option<JobId>,
}

impl TraceGetRequest {
    pub fn by_trace(trace: TraceId) -> TraceGetRequest {
        TraceGetRequest {
            trace: Some(trace),
            job: None,
        }
    }

    pub fn by_job(job: JobId) -> TraceGetRequest {
        TraceGetRequest {
            trace: None,
            job: Some(job),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![]);
        set_opt_trace(&mut j, "trace", self.trace);
        if let Some(job) = self.job {
            j.set("job", Json::from(job.to_string()));
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<TraceGetRequest, ApiError> {
        let trace = opt_trace(p, "trace")?;
        let job = match p.get("job").as_str() {
            None => None,
            Some(s) => Some(JobId::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "bad id in field 'job': '{s}'"
                ))
            })?),
        };
        if trace.is_some() == job.is_some() {
            return Err(ApiError::bad_request(
                "trace_get takes exactly one of 'trace' or 'job'",
            ));
        }
        Ok(TraceGetRequest { trace, job })
    }
}

/// One span on the wire. Times are virtual-clock nanoseconds.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanBody {
    pub span: SpanId,
    /// Absent on the trace root.
    pub parent: Option<SpanId>,
    pub name: String,
    pub start_ns: u64,
    /// Absent while the span is still open.
    pub end_ns: Option<u64>,
    /// "ok" | "error" | "open".
    pub outcome: String,
    /// The failure message when `outcome == "error"`.
    pub error: Option<String>,
    pub attrs: Vec<(String, String)>,
}

impl SpanBody {
    pub fn from_record(r: &SpanRecord) -> SpanBody {
        use crate::util::trace::SpanOutcome;
        SpanBody {
            span: r.id,
            parent: r.parent,
            name: r.name.clone(),
            start_ns: r.start.0,
            end_ns: r.end.map(|e| e.0),
            outcome: r.outcome.label().to_string(),
            error: match &r.outcome {
                SpanOutcome::Error(e) => Some(e.clone()),
                _ => None,
            },
            attrs: r.attrs.clone(),
        }
    }

    /// Span duration in virtual milliseconds (0 while open).
    pub fn duration_ms(&self) -> f64 {
        match self.end_ns {
            Some(e) => e.saturating_sub(self.start_ns) as f64 / 1e6,
            None => 0.0,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("span", Json::from(self.span.to_string())),
            ("name", Json::from(self.name.as_str())),
            ("start_ns", Json::from(self.start_ns)),
            ("outcome", Json::from(self.outcome.as_str())),
        ]);
        if let Some(p) = self.parent {
            j.set("parent", Json::from(p.to_string()));
        }
        if let Some(e) = self.end_ns {
            j.set("end_ns", Json::from(e));
        }
        if let Some(e) = &self.error {
            j.set("error", Json::from(e.as_str()));
        }
        if !self.attrs.is_empty() {
            j.set(
                "attrs",
                Json::obj(
                    self.attrs
                        .iter()
                        .map(|(k, v)| {
                            (k.as_str(), Json::from(v.as_str()))
                        })
                        .collect(),
                ),
            );
        }
        j
    }

    pub fn from_json(p: &Json) -> Result<SpanBody, ApiError> {
        let parent = match p.get("parent").as_str() {
            None => None,
            Some(s) => Some(SpanId::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "bad id in field 'parent': '{s}'"
                ))
            })?),
        };
        let attrs = match p.get("attrs").as_obj() {
            None => Vec::new(),
            Some(m) => m
                .iter()
                .map(|(k, v)| {
                    Ok((
                        k.clone(),
                        v.as_str()
                            .ok_or_else(|| {
                                ApiError::bad_request(
                                    "non-string span attr",
                                )
                            })?
                            .to_string(),
                    ))
                })
                .collect::<Result<Vec<_>, ApiError>>()?,
        };
        Ok(SpanBody {
            span: want_id(p, "span", SpanId::parse)?,
            parent,
            name: want_str(p, "name")?,
            start_ns: want_u64(p, "start_ns")?,
            end_ns: opt_u64(p, "end_ns"),
            outcome: want_str(p, "outcome")?,
            error: opt_str(p, "error"),
            attrs,
        })
    }
}

/// `trace_get` response: the span tree, spans in open order (the
/// first is the root).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceGetResponse {
    pub trace: TraceId,
    pub spans: Vec<SpanBody>,
    /// Spans dropped past the per-trace cap.
    pub truncated: u64,
}

impl TraceGetResponse {
    pub fn from_snapshot(s: &TraceSnapshot) -> TraceGetResponse {
        TraceGetResponse {
            trace: s.trace,
            spans: s.spans.iter().map(SpanBody::from_record).collect(),
            truncated: s.truncated,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("trace", Json::from(self.trace.to_string())),
            (
                "spans",
                Json::Arr(
                    self.spans.iter().map(|s| s.to_json()).collect(),
                ),
            ),
            ("truncated", Json::from(self.truncated)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<TraceGetResponse, ApiError> {
        let spans = p
            .get("spans")
            .as_arr()
            .ok_or_else(|| {
                ApiError::bad_request("missing array field 'spans'")
            })?
            .iter()
            .map(SpanBody::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(TraceGetResponse {
            trace: want_id(p, "trace", TraceId::parse)?,
            spans,
            truncated: want_u64(p, "truncated")?,
        })
    }
}

// ================================================== bitstream cache

/// `compile_submit` — ahead-of-time compile of `core` for `part`
/// into the cluster bitstream cache. Absent `part` takes the default
/// VC707 part; an unknown core or part fails synchronously.
#[derive(Debug, Clone, PartialEq)]
pub struct CompileSubmitRequest {
    pub user: UserId,
    pub core: String,
    pub part: Option<String>,
}

impl CompileSubmitRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("user", Json::from(self.user.to_string())),
            ("core", Json::from(self.core.as_str())),
        ]);
        if let Some(p) = &self.part {
            j.set("part", Json::from(p.as_str()));
        }
        j
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<CompileSubmitRequest, ApiError> {
        Ok(CompileSubmitRequest {
            user: want_id(p, "user", UserId::parse)?,
            core: want_str(p, "core")?,
            part: opt_str(p, "part"),
        })
    }
}

/// `compile_submit` response: the artifact's content digest and how
/// the request resolved — `cached` (already in the store),
/// `submitted` (a fresh flow job started; wait on `job`), or
/// `coalesced` (another tenant's in-flight flow job is building this
/// digest; `job` is theirs, shared).
#[derive(Debug, Clone, PartialEq)]
pub struct CompileSubmitResponse {
    pub digest: String,
    pub state: String,
    pub job: Option<JobId>,
    /// Owner token of the flow job — subscribe with it to watch the
    /// job's progress events.
    pub lease: Option<LeaseToken>,
}

impl CompileSubmitResponse {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("digest", Json::from(self.digest.as_str())),
            ("state", Json::from(self.state.as_str())),
        ]);
        if let Some(job) = self.job {
            j.set("job", Json::from(job.to_string()));
        }
        if let Some(t) = self.lease {
            j.set("lease", Json::from(t.to_string()));
        }
        j
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<CompileSubmitResponse, ApiError> {
        let job = match p.get("job").as_str() {
            None => None,
            Some(s) => Some(JobId::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "bad id in field 'job': '{s}'"
                ))
            })?),
        };
        Ok(CompileSubmitResponse {
            digest: want_str(p, "digest")?,
            state: want_str(p, "state")?,
            job,
            lease: opt_lease(p, "lease")?,
        })
    }
}

/// `compile_status` — poll a cache digest.
#[derive(Debug, Clone, PartialEq)]
pub struct CompileStatusRequest {
    pub digest: String,
}

impl CompileStatusRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("digest", Json::from(self.digest.as_str()))])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<CompileStatusRequest, ApiError> {
        Ok(CompileStatusRequest {
            digest: want_str(p, "digest")?,
        })
    }
}

/// `compile_status` response: `cached` | `running` (with the job to
/// wait on) | `unknown`.
#[derive(Debug, Clone, PartialEq)]
pub struct CompileStatusResponse {
    pub digest: String,
    pub state: String,
    pub job: Option<JobId>,
}

impl CompileStatusResponse {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("digest", Json::from(self.digest.as_str())),
            ("state", Json::from(self.state.as_str())),
        ]);
        if let Some(job) = self.job {
            j.set("job", Json::from(job.to_string()));
        }
        j
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<CompileStatusResponse, ApiError> {
        let job = match p.get("job").as_str() {
            None => None,
            Some(s) => Some(JobId::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "bad id in field 'job': '{s}'"
                ))
            })?),
        };
        Ok(CompileStatusResponse {
            digest: want_str(p, "digest")?,
            state: want_str(p, "state")?,
            job,
        })
    }
}

/// `agent.fetch_bitstream` — a node daemon pulling an artifact it is
/// missing from the management cache, by core/part. The reply is
/// multi-frame: a stream header carrying the transfer metadata
/// ([`crate::bitstream::Bitstream::to_transfer_json`] without the
/// payload), then the payload as protocol-4 `BIN` frames (base64
/// stream frames on v3), then the terminal frame.
#[derive(Debug, Clone, PartialEq)]
pub struct FetchBitstreamRequest {
    pub core: String,
    pub part: String,
    /// Self-identification of the fetching node daemon (absent for
    /// plain clients) — the coordinator marks that node warm for the
    /// core so later placements of the same design prefer it.
    pub node: Option<NodeId>,
}

impl FetchBitstreamRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("core", Json::from(self.core.as_str())),
            ("part", Json::from(self.part.as_str())),
        ]);
        if let Some(n) = self.node {
            j.set("node", Json::from(n.to_string()));
        }
        j
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<FetchBitstreamRequest, ApiError> {
        let node = match p.get("node").as_str() {
            None => None,
            Some(s) => Some(NodeId::parse(s).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "bad id in field 'node': '{s}'"
                ))
            })?),
        };
        Ok(FetchBitstreamRequest {
            core: want_str(p, "core")?,
            part: want_str(p, "part")?,
            node,
        })
    }
}

// ============================================================ agent

#[derive(Debug, Clone, PartialEq)]
pub struct AgentHelloRequest;

impl AgentHelloRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<AgentHelloRequest, ApiError> {
        Ok(AgentHelloRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AgentHelloResponse {
    pub node: NodeId,
    pub version: String,
}

impl AgentHelloResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::from(self.node.to_string())),
            ("version", Json::from(self.version.as_str())),
        ])
    }

    pub fn from_json(p: &Json) -> Result<AgentHelloResponse, ApiError> {
        Ok(AgentHelloResponse {
            node: want_id(p, "node", NodeId::parse)?,
            version: want_str(p, "version")?,
        })
    }
}

/// `agent.ping` — the heartbeat probe. Empty request; the response
/// carries the node vitals the registry caches for `node_list` and
/// the node's journal head so the health monitor can detect a
/// stalled event forwarder.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentPingRequest;

impl AgentPingRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<AgentPingRequest, ApiError> {
        Ok(AgentPingRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AgentPingResponse {
    pub node: NodeId,
    /// Live leases held by the node-local scheduler.
    pub leases: u64,
    pub regions_free: u64,
    pub regions_active: u64,
    /// The node journal's next cursor (last assigned + 1).
    pub next_cursor: u64,
}

impl AgentPingResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::from(self.node.to_string())),
            ("leases", Json::from(self.leases)),
            ("regions_free", Json::from(self.regions_free)),
            ("regions_active", Json::from(self.regions_active)),
            ("next_cursor", Json::from(self.next_cursor)),
        ])
    }

    pub fn from_json(p: &Json) -> Result<AgentPingResponse, ApiError> {
        Ok(AgentPingResponse {
            node: want_id(p, "node", NodeId::parse)?,
            leases: want_u64(p, "leases")?,
            regions_free: want_u64(p, "regions_free")?,
            regions_active: want_u64(p, "regions_active")?,
            next_cursor: want_u64(p, "next_cursor")?,
        })
    }
}

/// `agent.admit` — place an admission on the node's local scheduler.
/// The tenant travels by *name*: node daemons mint their own
/// `UserId`s, so names are the only identity stable across the
/// cluster. `adopt` is the re-admission path — a lease re-homed off
/// a dead node keeps the token its holder already carries.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentAdmitRequest {
    pub tenant: String,
    pub model: Option<ServiceModel>,
    pub class: Option<RequestClass>,
    /// Gang size (absent = 1); gangs stay node-local.
    pub regions: Option<u32>,
    pub co_located: Option<bool>,
    pub board: Option<String>,
    /// Core the tenant intends to program — a cache-affinity hint
    /// for placement (nodes already holding the artifact win ties),
    /// never a constraint.
    pub core: Option<String>,
    /// Mint the lease under this pre-existing token.
    pub adopt: Option<LeaseToken>,
}

impl AgentAdmitRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![(
            "tenant",
            Json::from(self.tenant.as_str()),
        )]);
        if let Some(m) = self.model {
            j.set("model", Json::from(m.name()));
        }
        if let Some(c) = self.class {
            j.set("class", Json::from(c.name()));
        }
        if let Some(n) = self.regions {
            j.set("regions", Json::from(u64::from(n)));
        }
        if let Some(co) = self.co_located {
            j.set("co_located", Json::from(co));
        }
        if let Some(b) = &self.board {
            j.set("board", Json::from(b.as_str()));
        }
        if let Some(c) = &self.core {
            j.set("core", Json::from(c.as_str()));
        }
        set_opt_lease(&mut j, "adopt", self.adopt);
        j
    }

    pub fn from_json(p: &Json) -> Result<AgentAdmitRequest, ApiError> {
        let model = match opt_str(p, "model") {
            Some(s) => Some(ServiceModel::parse(&s).ok_or_else(|| {
                ApiError::bad_request(format!("unknown model '{s}'"))
            })?),
            None => None,
        };
        let class = match opt_str(p, "class") {
            Some(s) => Some(RequestClass::parse(&s).ok_or_else(|| {
                ApiError::bad_request(format!("unknown class '{s}'"))
            })?),
            None => None,
        };
        let regions = match opt_u64(p, "regions") {
            Some(0) => {
                return Err(ApiError::bad_request(
                    "'regions' must be >= 1",
                ))
            }
            Some(n) if n > u64::from(u32::MAX) => {
                return Err(ApiError::bad_request(
                    "'regions' out of range",
                ))
            }
            Some(n) => Some(n as u32),
            None => None,
        };
        Ok(AgentAdmitRequest {
            tenant: want_str(p, "tenant")?,
            model,
            class,
            regions,
            co_located: p.get("co_located").as_bool(),
            board: opt_str(p, "board"),
            core: opt_str(p, "core"),
            adopt: opt_lease(p, "adopt")?,
        })
    }
}

/// `agent.release` — tear down the lease named by `lease` (every
/// member). The token *is* the authorization, exactly as on the
/// management surface.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentReleaseRequest {
    pub lease: LeaseToken,
}

impl AgentReleaseRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("lease", Json::from(self.lease.to_string()))])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<AgentReleaseRequest, ApiError> {
        Ok(AgentReleaseRequest {
            lease: want_id(p, "lease", LeaseToken::parse)?,
        })
    }
}

/// `agent.program` — partial-reconfigure `alloc` with `core` from the
/// node's local library, fenced by the lease token.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentProgramRequest {
    pub lease: LeaseToken,
    pub alloc: AllocationId,
    pub core: String,
}

impl AgentProgramRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lease", Json::from(self.lease.to_string())),
            ("alloc", Json::from(self.alloc.to_string())),
            ("core", Json::from(self.core.as_str())),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<AgentProgramRequest, ApiError> {
        Ok(AgentProgramRequest {
            lease: want_id(p, "lease", LeaseToken::parse)?,
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            core: want_str(p, "core")?,
        })
    }
}

/// `agent.stream` — run a data stream through `alloc` on the node
/// (multi-frame response, same frames as the management `stream`).
#[derive(Debug, Clone, PartialEq)]
pub struct AgentStreamRequest {
    pub lease: LeaseToken,
    pub alloc: AllocationId,
    pub core: String,
    pub mults: u64,
    /// Multi-frame reply with out-of-band result chunks (see
    /// [`StreamRequest::emit_output`]); the management server relays
    /// the frames to the end client without re-encoding.
    pub emit_output: bool,
}

impl AgentStreamRequest {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("lease", Json::from(self.lease.to_string())),
            ("alloc", Json::from(self.alloc.to_string())),
            ("core", Json::from(self.core.as_str())),
            ("mults", Json::from(self.mults)),
        ]);
        if self.emit_output {
            j.set("emit_output", Json::from(true));
        }
        j
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<AgentStreamRequest, ApiError> {
        Ok(AgentStreamRequest {
            lease: want_id(p, "lease", LeaseToken::parse)?,
            alloc: want_id(p, "alloc", AllocationId::parse)?,
            core: want_str(p, "core")?,
            mults: want_u64(p, "mults")?,
            emit_output: p.get("emit_output").as_bool().unwrap_or(false),
        })
    }
}

/// `agent.events` — drain a batch of the node's journal starting at
/// `from_cursor`. Long-polls up to `timeout_s` when the journal is
/// dry so the forwarder does not busy-spin; per-node cursors are
/// dense, which is what makes federated gap detection possible.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentEventsRequest {
    /// First cursor wanted (cursors start at 1).
    pub from_cursor: u64,
    pub max_events: u64,
    pub timeout_s: f64,
}

impl AgentEventsRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("from_cursor", Json::from(self.from_cursor)),
            ("max_events", Json::from(self.max_events)),
            ("timeout_s", Json::from(self.timeout_s)),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<AgentEventsRequest, ApiError> {
        Ok(AgentEventsRequest {
            from_cursor: want_u64(p, "from_cursor")?,
            max_events: want_u64(p, "max_events")?,
            timeout_s: want_f64(p, "timeout_s")?,
        })
    }
}

/// One journal entry in an `agent.events` batch: the node-local
/// cursor, the visibility scope it was published under (re-applied
/// by the management bus on forward), and the event itself.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeEventBody {
    pub cursor: u64,
    /// "public" | "token:<lease>" | "tenant:<user>".
    pub scope: String,
    pub event: Event,
}

impl NodeEventBody {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cursor", Json::from(self.cursor)),
            ("scope", Json::from(self.scope.as_str())),
            ("event", self.event.to_json()),
        ])
    }

    pub fn from_json(p: &Json) -> Result<NodeEventBody, ApiError> {
        Ok(NodeEventBody {
            cursor: want_u64(p, "cursor")?,
            scope: want_str(p, "scope")?,
            event: Event::from_json(p.get("event"))?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct AgentEventsResponse {
    /// Cursor to resume from (last delivered + 1).
    pub next_cursor: u64,
    pub events: Vec<NodeEventBody>,
}

impl AgentEventsResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("next_cursor", Json::from(self.next_cursor)),
            (
                "events",
                Json::Arr(
                    self.events.iter().map(|e| e.to_json()).collect(),
                ),
            ),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<AgentEventsResponse, ApiError> {
        let events = p
            .get("events")
            .as_arr()
            .ok_or_else(|| {
                ApiError::bad_request("missing array field 'events'")
            })?
            .iter()
            .map(NodeEventBody::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(AgentEventsResponse {
            next_cursor: want_u64(p, "next_cursor")?,
            events,
        })
    }
}

// ========================================================== cluster

/// `node_list` — one registered node as the registry sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeListRequest;

impl NodeListRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![])
    }

    pub fn from_json(_p: &Json) -> Result<NodeListRequest, ApiError> {
        Ok(NodeListRequest)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct NodeBody {
    pub node: NodeId,
    pub addr: String,
    pub boards: Vec<String>,
    pub regions_free: u64,
    pub regions_active: u64,
    /// Live leases homed on the node.
    pub leases: u64,
    /// Wall-clock ms since the last successful heartbeat.
    pub heartbeat_age_ms: f64,
    /// "up" | "suspect" | "down".
    pub state: String,
}

impl NodeBody {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::from(self.node.to_string())),
            ("addr", Json::from(self.addr.as_str())),
            (
                "boards",
                Json::Arr(
                    self.boards
                        .iter()
                        .map(|b| Json::from(b.as_str()))
                        .collect(),
                ),
            ),
            ("regions_free", Json::from(self.regions_free)),
            ("regions_active", Json::from(self.regions_active)),
            ("leases", Json::from(self.leases)),
            ("heartbeat_age_ms", Json::from(self.heartbeat_age_ms)),
            ("state", Json::from(self.state.as_str())),
        ])
    }

    pub fn from_json(p: &Json) -> Result<NodeBody, ApiError> {
        let boards = want_str_arr(p, "boards")?;
        Ok(NodeBody {
            node: want_id(p, "node", NodeId::parse)?,
            addr: want_str(p, "addr")?,
            boards,
            regions_free: want_u64(p, "regions_free")?,
            regions_active: want_u64(p, "regions_active")?,
            leases: want_u64(p, "leases")?,
            heartbeat_age_ms: want_f64(p, "heartbeat_age_ms")?,
            state: want_str(p, "state")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct NodeListResponse {
    pub nodes: Vec<NodeBody>,
}

impl NodeListResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "nodes",
            Json::Arr(self.nodes.iter().map(|n| n.to_json()).collect()),
        )])
    }

    pub fn from_json(p: &Json) -> Result<NodeListResponse, ApiError> {
        let nodes = p
            .get("nodes")
            .as_arr()
            .ok_or_else(|| {
                ApiError::bad_request("missing array field 'nodes'")
            })?
            .iter()
            .map(NodeBody::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(NodeListResponse { nodes })
    }
}

/// `cluster.register` — a node daemon joining (or rejoining) the
/// cluster. `tokens` lists the live leases it re-adopted from its
/// local WAL; the response's `release` list names those the
/// management server has since re-homed elsewhere, which the daemon
/// must tear down locally to keep ownership single-homed.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterRegisterRequest {
    pub node: NodeId,
    pub name: String,
    /// Address the management server dials the daemon back on.
    pub addr: String,
    pub boards: Vec<String>,
    pub regions_total: u64,
    pub tokens: Vec<LeaseToken>,
}

impl ClusterRegisterRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::from(self.node.to_string())),
            ("name", Json::from(self.name.as_str())),
            ("addr", Json::from(self.addr.as_str())),
            (
                "boards",
                Json::Arr(
                    self.boards
                        .iter()
                        .map(|b| Json::from(b.as_str()))
                        .collect(),
                ),
            ),
            ("regions_total", Json::from(self.regions_total)),
            (
                "tokens",
                Json::Arr(
                    self.tokens
                        .iter()
                        .map(|t| Json::from(t.to_string()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<ClusterRegisterRequest, ApiError> {
        Ok(ClusterRegisterRequest {
            node: want_id(p, "node", NodeId::parse)?,
            name: want_str(p, "name")?,
            addr: want_str(p, "addr")?,
            boards: want_str_arr(p, "boards")?,
            regions_total: want_u64(p, "regions_total")?,
            tokens: want_token_arr(p, "tokens")?,
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct ClusterRegisterResponse {
    pub accepted: bool,
    /// Leases the daemon must release locally (re-homed while it was
    /// away).
    pub release: Vec<LeaseToken>,
}

impl ClusterRegisterResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("accepted", Json::from(self.accepted)),
            (
                "release",
                Json::Arr(
                    self.release
                        .iter()
                        .map(|t| Json::from(t.to_string()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(
        p: &Json,
    ) -> Result<ClusterRegisterResponse, ApiError> {
        Ok(ClusterRegisterResponse {
            accepted: p.get("accepted").as_bool().ok_or_else(|| {
                ApiError::bad_request("missing bool field 'accepted'")
            })?,
            release: want_token_arr(p, "release")?,
        })
    }
}

fn want_str_arr(p: &Json, key: &str) -> Result<Vec<String>, ApiError> {
    p.get(key)
        .as_arr()
        .ok_or_else(|| {
            ApiError::bad_request(format!(
                "missing array field '{key}'"
            ))
        })?
        .iter()
        .map(|v| {
            v.as_str().map(str::to_string).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "non-string entry in '{key}'"
                ))
            })
        })
        .collect()
}

fn want_token_arr(
    p: &Json,
    key: &str,
) -> Result<Vec<LeaseToken>, ApiError> {
    p.get(key)
        .as_arr()
        .ok_or_else(|| {
            ApiError::bad_request(format!(
                "missing array field '{key}'"
            ))
        })?
        .iter()
        .map(|v| {
            v.as_str().and_then(LeaseToken::parse).ok_or_else(|| {
                ApiError::bad_request(format!(
                    "bad lease token in '{key}'"
                ))
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_codes_roundtrip_names() {
        for code in ErrorCode::ALL {
            assert_eq!(ErrorCode::parse(code.name()), Some(code));
        }
        assert_eq!(ErrorCode::parse("nope"), None);
    }

    #[test]
    fn methods_roundtrip_names() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("reboot_world"), None);
    }

    #[test]
    fn metrics_export_bodies_roundtrip() {
        let reg = crate::metrics::Registry::new();
        reg.counter("hv.pr").add(4);
        reg.gauge("sched.queue.depth").set(-1);
        reg.histogram("sched.wait").record_us(1500);
        let resp =
            MetricsExportResponse::from_snapshot(&reg.snapshot());
        let rt =
            MetricsExportResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(rt, resp);
        assert_eq!(rt.counters, vec![("hv.pr".to_string(), 4)]);
        assert_eq!(
            rt.gauges,
            vec![("sched.queue.depth".to_string(), -1)]
        );
        let (name, h) = &rt.histograms[0];
        assert_eq!(name, "sched.wait");
        assert_eq!(h.count, 1);
        assert_eq!(h.bounds_us.len(), h.buckets.len());
        assert!(!h.bounds_us.is_empty());
        // Mismatched bounds/buckets arity is rejected.
        let mut bad = h.to_json();
        bad.set("buckets", Json::Arr(vec![Json::from(1u64)]));
        assert!(HistogramBody::from_json(&bad).is_err());
    }

    #[test]
    fn trace_get_bodies_roundtrip() {
        let req = TraceGetRequest::by_trace(TraceId(5));
        assert_eq!(
            TraceGetRequest::from_json(&req.to_json()).unwrap(),
            req
        );
        let req = TraceGetRequest::by_job(JobId(2));
        assert_eq!(
            TraceGetRequest::from_json(&req.to_json()).unwrap(),
            req
        );
        // Exactly one selector: neither and both are rejected.
        assert!(
            TraceGetRequest::from_json(&Json::obj(vec![])).is_err()
        );
        let both = Json::obj(vec![
            ("trace", Json::from("trace-1")),
            ("job", Json::from("job-1")),
        ]);
        assert!(TraceGetRequest::from_json(&both).is_err());

        let resp = TraceGetResponse {
            trace: TraceId(5),
            spans: vec![
                SpanBody {
                    span: SpanId(0),
                    parent: None,
                    name: "rpc.program_full".into(),
                    start_ns: 0,
                    end_ns: Some(3_000_000),
                    outcome: "ok".into(),
                    error: None,
                    attrs: vec![(
                        "method".into(),
                        "program_full".into(),
                    )],
                },
                SpanBody {
                    span: SpanId(1),
                    parent: Some(SpanId(0)),
                    name: "fpga.pr".into(),
                    start_ns: 1_000_000,
                    end_ns: None,
                    outcome: "open".into(),
                    error: None,
                    attrs: vec![],
                },
            ],
            truncated: 0,
        };
        let rt =
            TraceGetResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(rt, resp);
        assert!(
            (rt.spans[0].duration_ms() - 3.0).abs() < 1e-9
        );
        assert_eq!(rt.spans[1].duration_ms(), 0.0);
    }

    #[test]
    fn api_error_json_roundtrip() {
        let e = ApiError::new(ErrorCode::QuotaExceeded, "quota: 2 of 2");
        assert!(e.retryable);
        assert!(e.retry_after_s.is_some());
        let back = ApiError::from_json(&e.to_json()).unwrap();
        assert_eq!(back, e);
        let term = ApiError::new(ErrorCode::QuotaBudget, "budget gone");
        assert!(!term.retryable);
        assert_eq!(term.retry_after_s, None);
    }

    #[test]
    fn sched_error_mapping_is_total() {
        use crate::util::ids::{AllocationId, ReservationId};
        let cases: Vec<(SchedError, ErrorCode)> = vec![
            (SchedError::NoCapacity, ErrorCode::NoCapacity),
            (
                SchedError::QuotaBudget("b".into()),
                ErrorCode::QuotaBudget,
            ),
            (
                SchedError::QuotaConcurrency("c".into()),
                ErrorCode::QuotaExceeded,
            ),
            (
                SchedError::Hypervisor("h".into()),
                ErrorCode::Internal,
            ),
            (
                SchedError::UnknownGrant(AllocationId(1)),
                ErrorCode::BadLease,
            ),
            (SchedError::UnknownLease, ErrorCode::BadToken),
            (
                SchedError::Unsatisfiable("5 > 4".into()),
                ErrorCode::BadRequest,
            ),
            (SchedError::Cancelled, ErrorCode::Cancelled),
            (
                SchedError::UnknownReservation(ReservationId(2)),
                ErrorCode::UnknownReservation,
            ),
        ];
        for (e, code) in cases {
            let api = ApiError::from(&e);
            assert_eq!(api.code, code, "{e}");
            assert_eq!(api.message, e.to_string());
        }
    }

    #[test]
    fn hello_negotiation_window() {
        assert_eq!(HelloRequest::ours().negotiate(), Some(PROTO_MAX));
        // A legacy (proto-less) client reads as window [1, 1] — below
        // the supported window now that protocol 1 is retired.
        let legacy = HelloRequest::from_json(&Json::obj(vec![])).unwrap();
        assert_eq!((legacy.proto_min, legacy.proto_max), (1, 1));
        assert_eq!(legacy.negotiate(), None);
        // A pure-v2 client still negotiates v2.
        let v2_only = HelloRequest {
            proto_min: 2,
            proto_max: 2,
        };
        assert_eq!(v2_only.negotiate(), Some(2));
        let future = HelloRequest {
            proto_min: PROTO_MAX + 1,
            proto_max: PROTO_MAX + 5,
        };
        assert_eq!(future.negotiate(), None);
    }

    #[test]
    fn topics_and_events_roundtrip() {
        for t in Topic::ALL {
            assert_eq!(Topic::parse(t.name()), Some(t));
        }
        assert_eq!(Topic::parse("everything"), None);
        let events = vec![
            Event::JobProgress {
                job: JobId(3),
                method: "stream".into(),
                phase: "streaming".into(),
                bytes_streamed: 4096,
                pct: 50.0,
                state: "running".into(),
                result: None,
                trace: Some(TraceId(9)),
            },
            Event::JobProgress {
                job: JobId(3),
                method: "stream".into(),
                phase: "done".into(),
                bytes_streamed: 8192,
                pct: 100.0,
                state: "done".into(),
                result: Some(Json::obj(vec![(
                    "state",
                    Json::from("done"),
                )])),
                trace: None,
            },
            Event::LeasePlacementChanged {
                alloc: AllocationId(1),
                vfpga: VfpgaId(5),
                fpga: FpgaId(2),
                migrations: 1,
            },
            Event::RegionTransition {
                fpga: FpgaId(0),
                region: VfpgaId(1),
                from: "free".into(),
                to: "reserved".into(),
                at_s: 0.5,
            },
            Event::QueueDepth { depth: 4 },
            Event::GrantIssued {
                alloc: AllocationId(9),
                tenant: UserId(0),
                model: ServiceModel::RAaaS,
                class: RequestClass::Interactive,
                wait_ms: 1.25,
            },
        ];
        for ev in events {
            let rt = Event::from_json(&ev.to_json()).unwrap();
            assert_eq!(rt, ev);
        }
        assert!(Event::from_json(&Json::obj(vec![(
            "type",
            Json::from("martian")
        )]))
        .is_err());
    }

    #[test]
    fn subscription_filter_matches_by_axis() {
        let progress = Event::JobProgress {
            job: JobId(7),
            method: "stream".into(),
            phase: "streaming".into(),
            bytes_streamed: 0,
            pct: 10.0,
            state: "running".into(),
            result: None,
            trace: None,
        };
        let region = Event::RegionTransition {
            fpga: FpgaId(1),
            region: VfpgaId(4),
            from: "free".into(),
            to: "reserved".into(),
            at_s: 0.0,
        };
        // Empty filter: everything matches.
        assert!(SubscriptionFilter::all().matches(&progress));
        assert!(SubscriptionFilter::all().matches(&region));
        // Topic filter.
        let jobs_only = SubscriptionFilter::topic(Topic::Job);
        assert!(jobs_only.matches(&progress));
        assert!(!jobs_only.matches(&region));
        // Job-id filter hits only that job.
        let mut one_job = SubscriptionFilter::topic(Topic::Job);
        one_job.job_ids = vec![JobId(8)];
        assert!(!one_job.matches(&progress));
        one_job.job_ids = vec![JobId(7)];
        assert!(one_job.matches(&progress));
        // Fpga filter applies to events carrying a device.
        let mut dev = SubscriptionFilter::all();
        dev.fpga_ids = vec![FpgaId(0)];
        assert!(!dev.matches(&region));
        dev.fpga_ids = vec![FpgaId(1)];
        assert!(dev.matches(&region));
        // Wire roundtrip, including the rejection of unknown topics.
        let rt =
            SubscriptionFilter::from_json(&one_job.to_json()).unwrap();
        assert_eq!(rt, one_job);
        let mut j = Json::obj(vec![]);
        j.set("topics", Json::Arr(vec![Json::from("martian")]));
        assert!(SubscriptionFilter::from_json(&j).is_err());
    }

    #[test]
    fn lifecycle_log_and_policy_bodies_roundtrip() {
        let resp = LifecycleLogResponse {
            fpga: FpgaId(0),
            records: vec![TransitionBody {
                region: VfpgaId(0),
                from: "free".into(),
                to: "reserved".into(),
                at_s: 1.0,
            }],
            dropped: 3,
        };
        let rt =
            LifecycleLogResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(rt, resp);
        let req = LifecycleLogRequest {
            fpga: FpgaId(2),
            limit: Some(16),
        };
        assert_eq!(
            LifecycleLogRequest::from_json(&req.to_json()).unwrap(),
            req
        );
        let pol = SchedPolicyResponse {
            policy: "spread".into(),
        };
        assert_eq!(
            SchedPolicyResponse::from_json(&pol.to_json()).unwrap(),
            pol
        );
    }

    #[test]
    fn request_structs_roundtrip() {
        let req = AllocVfpgaRequest {
            user: UserId(3),
            model: Some(ServiceModel::BAaaS),
            class: Some(RequestClass::Batch),
            regions: Some(4),
            co_located: Some(true),
            board: Some("vc707".to_string()),
            core: Some("matmul16".to_string()),
        };
        assert_eq!(
            AllocVfpgaRequest::from_json(&req.to_json()).unwrap(),
            req
        );
        // Absent optionals stay absent.
        let bare = AllocVfpgaRequest::single(UserId(0), None, None);
        assert_eq!(
            AllocVfpgaRequest::from_json(&bare.to_json()).unwrap(),
            bare
        );
        // Present-but-bad class is an error, not a default.
        let mut j = bare.to_json();
        j.set("class", Json::from("urgentest"));
        let err = AllocVfpgaRequest::from_json(&j).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // A zero-region gang is an error, not a silent 1.
        let mut j = bare.to_json();
        j.set("regions", Json::from(0u64));
        let err = AllocVfpgaRequest::from_json(&j).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
    }

    #[test]
    fn compile_structs_roundtrip() {
        let req = CompileSubmitRequest {
            user: UserId(4),
            core: "matmul16".to_string(),
            part: Some("xc7vx485t".to_string()),
        };
        assert_eq!(
            CompileSubmitRequest::from_json(&req.to_json()).unwrap(),
            req
        );
        let resp = CompileSubmitResponse {
            digest: "d".repeat(64),
            state: "submitted".to_string(),
            job: Some(JobId(9)),
            lease: Some(LeaseToken::mint()),
        };
        assert_eq!(
            CompileSubmitResponse::from_json(&resp.to_json()).unwrap(),
            resp
        );
        // Cached responses carry no job/lease and stay that way.
        let cached = CompileSubmitResponse {
            digest: "d".repeat(64),
            state: "cached".to_string(),
            job: None,
            lease: None,
        };
        assert_eq!(
            CompileSubmitResponse::from_json(&cached.to_json())
                .unwrap(),
            cached
        );
        let status = CompileStatusResponse {
            digest: "d".repeat(64),
            state: "running".to_string(),
            job: Some(JobId(9)),
        };
        assert_eq!(
            CompileStatusResponse::from_json(&status.to_json())
                .unwrap(),
            status
        );
        let fetch = FetchBitstreamRequest {
            core: "matmul16".to_string(),
            part: "xc7vx485t".to_string(),
            node: Some(NodeId(3)),
        };
        assert_eq!(
            FetchBitstreamRequest::from_json(&fetch.to_json()).unwrap(),
            fetch
        );
    }

    #[test]
    fn lease_token_fields_roundtrip_and_reject_garbage() {
        let token = LeaseToken::mint();
        let req = ReleaseRequest {
            alloc: AllocationId(7),
            lease: Some(token),
        };
        assert_eq!(
            ReleaseRequest::from_json(&req.to_json()).unwrap(),
            req
        );
        // Absent token parses as None (v1 compatibility)...
        let bare = ReleaseRequest {
            alloc: AllocationId(7),
            lease: None,
        };
        assert_eq!(
            ReleaseRequest::from_json(&bare.to_json()).unwrap(),
            bare
        );
        // ...but a malformed token is an error, never None.
        let mut j = bare.to_json();
        j.set("lease", Json::from("lt-xyzzy"));
        let err = ReleaseRequest::from_json(&j).unwrap_err();
        assert_eq!(err.code, ErrorCode::BadRequest);
        // Gang alloc response roundtrips members + lease.
        let resp = AllocVfpgaResponse {
            alloc: AllocationId(0),
            vfpga: VfpgaId(1),
            fpga: FpgaId(2),
            node: NodeId(0),
            wait_ms: 1.5,
            lease: token,
            members: vec![
                GangMemberBody {
                    alloc: AllocationId(0),
                    vfpga: VfpgaId(1),
                    fpga: FpgaId(2),
                    node: NodeId(0),
                },
                GangMemberBody {
                    alloc: AllocationId(1),
                    vfpga: VfpgaId(2),
                    fpga: FpgaId(2),
                    node: NodeId(0),
                },
            ],
        };
        let back =
            AllocVfpgaResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(back, resp);
        assert_eq!(back.members.len(), 2);
    }

    #[test]
    fn job_body_states_unwrap() {
        let done = JobBody {
            job: JobId(1),
            method: "stream".into(),
            state: "done".into(),
            result: Some(Json::from(7u64)),
            error: None,
        };
        let rt = JobBody::from_json(&done.to_json()).unwrap();
        assert_eq!(rt, done);
        assert_eq!(rt.into_done().unwrap(), Json::Num(7.0));
        let failed = JobBody {
            job: JobId(2),
            method: "stream".into(),
            state: "failed".into(),
            result: None,
            error: Some(ApiError::new(ErrorCode::NoCapacity, "full")),
        };
        let e = JobBody::from_json(&failed.to_json())
            .unwrap()
            .into_done()
            .unwrap_err();
        assert_eq!(e.code, ErrorCode::NoCapacity);
    }

    #[test]
    fn quota_response_encodes_unlimited_as_zero() {
        let q = crate::sched::TenantQuota::default();
        let r = QuotaResponse::from_quota(UserId(1), &q, 0);
        assert_eq!(r.max_vfpgas, 0);
        let back = QuotaResponse::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        assert!(back.budget_s.is_none());
    }
}
