//! Length-prefixed JSON framing + request/response envelopes.
//!
//! One envelope generation is on the wire (protocol 1 — the untyped
//! surface — was retired when protocol 3 landed): requests carry a
//! client-chosen `id` (echoed back so pipelined callers can
//! correlate) and a `proto` number; error responses carry a
//! structured [`ApiError`] object under `"error"`. A request without
//! a `proto` stamp reads as protocol 1 and is rejected with
//! `protocol_mismatch` before dispatch.
//!
//! Protocol 3 adds **multi-frame responses**: a response whose
//! envelope carries `"stream": true` is a *header* — it is followed
//! by ordered [`StreamFrame`]s (`seq` strictly increasing) and closed
//! by a terminal frame (`"end": true`), after which the connection
//! returns to request/response mode (see `docs/PROTOCOL.md`).
//!
//! Protocol 4 adds **out-of-band binary frames** for bulk data: a
//! length word with the top bit set introduces a [`BinFrame`]
//! (`[len|BIN][flags u8][seq u64][payload]`) instead of JSON text.
//! Binary frames interleave with JSON [`StreamFrame`]s inside a
//! multi-frame response — sharing one strictly-increasing `seq`
//! space — so stream payloads skip JSON encoding entirely while
//! headers and terminals stay structured. Peers negotiating proto 3
//! receive the same payloads base64-packed inside JSON frames
//! instead. [`read_wire_frame`] reads either kind; [`read_frame`]
//! (the pre-v4 entry point) rejects binary frames.

use std::io::{Read, Write};

use super::api::ApiError;
use crate::util::ids::TraceId;
use crate::util::json::Json;

/// Max frame we accept (a full bitstream upload fits comfortably).
/// Applies to JSON frame text and to binary frame payloads alike.
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// Top bit of the length word marks a binary frame. `MAX_FRAME` is
/// far below bit 31, so the two framings cannot collide.
const BIN_FRAME_BIT: u32 = 0x8000_0000;

/// Binary frame header bytes past the length word: flags(1) + seq(8).
const BIN_HEADER_BYTES: u32 = 9;

/// Flag bit: this binary frame closes the payload sequence (a JSON
/// terminal [`StreamFrame`] still follows with the outcome).
pub const BIN_FLAG_END: u8 = 0x01;

/// An out-of-band binary frame (protocol 4): bulk payload bytes with
/// a sequence number shared with the surrounding multi-frame
/// response's JSON frames.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BinFrame {
    /// [`BIN_FLAG_END`] bits.
    pub flags: u8,
    /// Position in the enclosing stream (strictly increasing).
    pub seq: u64,
    pub payload: Vec<u8>,
}

impl BinFrame {
    /// A data-carrying frame.
    pub fn data(seq: u64, payload: Vec<u8>) -> BinFrame {
        BinFrame {
            flags: 0,
            seq,
            payload,
        }
    }

    /// An empty payload-complete marker (the last binary frame).
    pub fn end_marker(seq: u64) -> BinFrame {
        BinFrame {
            flags: BIN_FLAG_END,
            seq,
            payload: Vec::new(),
        }
    }

    pub fn is_end(&self) -> bool {
        self.flags & BIN_FLAG_END != 0
    }
}

/// Either framing the wire can carry once protocol 4 is in play.
#[derive(Debug, Clone, PartialEq)]
pub enum WireFrame {
    Json(Json),
    Bin(BinFrame),
}

/// An RPC request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    pub params: Json,
    /// Client-chosen correlation id, echoed in the response.
    pub id: Option<u64>,
    /// Protocol the client speaks for this request; absent = 1,
    /// which is below the supported window and rejected.
    pub proto: Option<u32>,
    /// Flight-recorder correlation: when set, the server parents this
    /// request's root span under the named trace (creating it on
    /// first sight), so one client-minted id stitches a multi-RPC
    /// operation into a single span tree.
    pub trace: Option<TraceId>,
}

impl Request {
    /// A request stamped with the newest protocol this crate speaks.
    pub fn v2(method: &str, params: Json, id: u64) -> Request {
        Request {
            method: method.to_string(),
            params,
            id: Some(id),
            proto: Some(super::api::PROTO_MAX),
            trace: None,
        }
    }

    /// The same request carrying a trace correlation id.
    pub fn with_trace(mut self, trace: Option<TraceId>) -> Request {
        self.trace = trace;
        self
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("method", Json::from(self.method.as_str())),
            ("params", self.params.clone()),
        ]);
        if let Some(id) = self.id {
            j.set("id", Json::from(id));
        }
        if let Some(p) = self.proto {
            j.set("proto", Json::from(u64::from(p)));
        }
        if let Some(t) = self.trace {
            j.set("trace", Json::from(t.to_string().as_str()));
        }
        j
    }

    pub fn from_json(v: &Json) -> Result<Request, String> {
        let trace = match v.get("trace") {
            Json::Null => None,
            t => Some(
                t.as_str()
                    .and_then(TraceId::parse)
                    .ok_or_else(|| "invalid 'trace' field".to_string())?,
            ),
        };
        Ok(Request {
            method: v.str_field("method")?.to_string(),
            params: v.get("params").clone(),
            id: v.get("id").as_u64(),
            proto: v.get("proto").as_u64().map(|p| p as u32),
            trace,
        })
    }

    /// Envelope protocol of this request (absent = 1), or a
    /// `protocol_mismatch` error when outside the supported window —
    /// checked before dispatch by every peer. Retired protocol 1 is
    /// rejected here, not silently downgraded.
    pub fn negotiate_proto(&self) -> Result<u32, ApiError> {
        let proto = self.proto.unwrap_or(1);
        if (super::api::PROTO_MIN..=super::api::PROTO_MAX)
            .contains(&proto)
        {
            Ok(proto)
        } else {
            Err(ApiError::protocol_mismatch(proto, proto))
        }
    }
}

/// Wrap a dispatch result in a response envelope — shared by the
/// management server and the node agents. Out-of-window protocols
/// (including retired protocol 1) are answered in the same typed
/// shape so the rejected client can still read the
/// `protocol_mismatch` code.
pub fn respond(id: Option<u64>, result: Result<Json, ApiError>) -> Response {
    match result {
        Ok(body) => Response::success_v2(id, body),
        Err(e) => Response::failure(id, e),
    }
}

/// An RPC response (or, with `stream: true`, the header of a
/// multi-frame response).
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub ok: bool,
    pub body: Json,
    /// Echo of the request's correlation id.
    pub id: Option<u64>,
    /// Structured failure; `body` carries the message string in
    /// parallel for log readability.
    pub error: Option<ApiError>,
    /// Protocol-3 multi-frame marker: when true, this envelope is a
    /// stream *header* and [`StreamFrame`]s follow on the connection
    /// until one with `end: true`.
    pub stream: bool,
}

impl Response {
    /// A success echoing the request id.
    pub fn success_v2(id: Option<u64>, body: Json) -> Response {
        Response {
            ok: true,
            body,
            id,
            error: None,
            stream: false,
        }
    }

    /// The header of a multi-frame (streaming) response.
    pub fn stream_header(id: Option<u64>, body: Json) -> Response {
        Response {
            ok: true,
            body,
            id,
            error: None,
            stream: true,
        }
    }

    /// A failure: structured error + message string body.
    pub fn failure(id: Option<u64>, error: ApiError) -> Response {
        Response {
            ok: false,
            body: Json::from(error.message.as_str()),
            id,
            error: Some(error),
            stream: false,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("ok", Json::from(self.ok)),
            ("body", self.body.clone()),
        ]);
        if let Some(id) = self.id {
            j.set("id", Json::from(id));
        }
        if let Some(e) = &self.error {
            j.set("error", e.to_json());
        }
        if self.stream {
            j.set("stream", Json::from(true));
        }
        j
    }

    pub fn from_json(v: &Json) -> Result<Response, String> {
        let error = match v.get("error") {
            Json::Null => None,
            e => Some(ApiError::from_json(e)?),
        };
        Ok(Response {
            ok: v
                .get("ok")
                .as_bool()
                .ok_or("response missing 'ok'")?,
            body: v.get("body").clone(),
            id: v.get("id").as_u64(),
            error,
            stream: v.get("stream").as_bool().unwrap_or(false),
        })
    }

    /// Unwrap into Result keeping the structured error. A bare string
    /// error (from a pre-v2 peer) maps to
    /// [`crate::middleware::api::ErrorCode::Internal`].
    pub fn into_api_result(self) -> Result<Json, ApiError> {
        if self.ok {
            Ok(self.body)
        } else if let Some(e) = self.error {
            Err(e)
        } else {
            Err(ApiError::internal(
                self.body.as_str().unwrap_or("unknown error"),
            ))
        }
    }
}

/// One frame of a protocol-3 multi-frame response body. Frames are
/// ordered (`seq` strictly increasing per stream, starting at 1) and
/// the stream is closed by a frame with `end: true` (which carries no
/// event). A server-side failure mid-stream lands on the terminal
/// frame's `error`.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamFrame {
    pub seq: u64,
    /// The frame payload (a typed [`super::api::Event`] for
    /// `subscribe` streams); `None` on the terminal frame.
    pub event: Option<Json>,
    /// Durable position of this event in the server's event journal.
    /// A client quotes it back as `subscribe.from_cursor` to resume a
    /// dropped stream without gaps; dense per server history (unlike
    /// `seq`, which is per stream). Absent on terminal frames.
    pub cursor: Option<u64>,
    /// Terminal marker: no more frames follow.
    pub end: bool,
    /// Why the stream ended, when it ended abnormally.
    pub error: Option<ApiError>,
    /// Terminal-frame side data: per-subscriber delivery stats
    /// (`delivered`, `dropped`, `queue_high_water`) so a client
    /// learns how lossy its own subscription was, not just the
    /// process-global counters.
    pub stats: Option<Json>,
}

impl StreamFrame {
    pub fn event(seq: u64, event: Json) -> StreamFrame {
        StreamFrame {
            seq,
            event: Some(event),
            cursor: None,
            end: false,
            error: None,
            stats: None,
        }
    }

    /// Stamp the frame with its durable journal cursor.
    pub fn with_cursor(mut self, cursor: u64) -> StreamFrame {
        self.cursor = Some(cursor);
        self
    }

    pub fn terminal(seq: u64, error: Option<ApiError>) -> StreamFrame {
        StreamFrame {
            seq,
            event: None,
            cursor: None,
            end: true,
            error,
            stats: None,
        }
    }

    /// A terminal frame carrying per-subscriber delivery stats.
    pub fn terminal_with_stats(
        seq: u64,
        error: Option<ApiError>,
        stats: Json,
    ) -> StreamFrame {
        StreamFrame {
            seq,
            event: None,
            cursor: None,
            end: true,
            error,
            stats: Some(stats),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![("seq", Json::from(self.seq))]);
        if let Some(ev) = &self.event {
            j.set("event", ev.clone());
        }
        if let Some(c) = self.cursor {
            j.set("cursor", Json::from(c));
        }
        if self.end {
            j.set("end", Json::from(true));
        }
        if let Some(e) = &self.error {
            j.set("error", e.to_json());
        }
        if let Some(s) = &self.stats {
            j.set("stats", s.clone());
        }
        j
    }

    pub fn from_json(v: &Json) -> Result<StreamFrame, String> {
        let error = match v.get("error") {
            Json::Null => None,
            e => Some(ApiError::from_json(e)?),
        };
        let event = match v.get("event") {
            Json::Null => None,
            e => Some(e.clone()),
        };
        let stats = match v.get("stats") {
            Json::Null => None,
            s => Some(s.clone()),
        };
        Ok(StreamFrame {
            seq: v
                .get("seq")
                .as_u64()
                .ok_or("stream frame missing 'seq'")?,
            event,
            cursor: v.get("cursor").as_u64(),
            end: v.get("end").as_bool().unwrap_or(false),
            error,
            stats,
        })
    }
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, v: &Json) -> std::io::Result<()> {
    let text = v.to_string();
    let len = text.len() as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(text.as_bytes())?;
    w.flush()
}

/// Write one binary frame (protocol 4 out-of-band payload).
pub fn write_bin_frame(
    w: &mut impl Write,
    frame: &BinFrame,
) -> std::io::Result<()> {
    write_bin_chunk(w, frame.flags, frame.seq, &frame.payload)
}

/// [`write_bin_frame`] without the owning struct: the data plane
/// writes pooled buffers straight to the socket, so the payload is
/// only ever borrowed.
pub fn write_bin_chunk(
    w: &mut impl Write,
    flags: u8,
    seq: u64,
    payload: &[u8],
) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!(
                "binary payload of {} bytes exceeds limit",
                payload.len()
            ),
        ));
    }
    let len = BIN_HEADER_BYTES + payload.len() as u32;
    w.write_all(&(len | BIN_FRAME_BIT).to_le_bytes())?;
    w.write_all(&[flags])?;
    w.write_all(&seq.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Write one data-plane frame carrying `chunk`: an out-of-band
/// binary frame when `binary`, otherwise a `stream_data` event
/// (base64 payload) inside a JSON [`StreamFrame`] — the protocol-3
/// fallback framing.
pub fn write_data_frame(
    w: &mut impl Write,
    binary: bool,
    seq: u64,
    chunk: &[u8],
) -> std::io::Result<()> {
    if binary {
        return write_bin_chunk(w, 0, seq, chunk);
    }
    let b64 = crate::util::bytes::b64_encode(chunk);
    write_frame(
        w,
        &StreamFrame::event(
            seq,
            Json::obj(vec![
                ("type", Json::from("stream_data")),
                ("b64", Json::from(b64.as_str())),
            ]),
        )
        .to_json(),
    )
}

/// Read one frame of either kind; `Ok(None)` on clean EOF before the
/// header. Length and header sanity are enforced before any payload
/// allocation.
pub fn read_wire_frame(
    r: &mut impl Read,
) -> std::io::Result<Option<WireFrame>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e),
    }
    let raw = u32::from_le_bytes(len_buf);
    if raw & BIN_FRAME_BIT != 0 {
        let len = raw & !BIN_FRAME_BIT;
        if len < BIN_HEADER_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("binary frame of {len} bytes lacks its header"),
            ));
        }
        let body = len - BIN_HEADER_BYTES;
        if body > MAX_FRAME {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("binary payload of {body} bytes exceeds limit"),
            ));
        }
        let mut hdr = [0u8; BIN_HEADER_BYTES as usize];
        r.read_exact(&mut hdr)?;
        let seq = u64::from_le_bytes(hdr[1..9].try_into().unwrap());
        let mut payload = vec![0u8; body as usize];
        r.read_exact(&mut payload)?;
        return Ok(Some(WireFrame::Bin(BinFrame {
            flags: hdr[0],
            seq,
            payload,
        })));
    }
    if raw > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {raw} bytes exceeds limit"),
        ));
    }
    let mut buf = vec![0u8; raw as usize];
    r.read_exact(&mut buf)?;
    let text = String::from_utf8(buf).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "bad utf-8")
    })?;
    Json::parse(&text)
        .map(|v| Some(WireFrame::Json(v)))
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

/// Read one JSON frame; `Ok(None)` on clean EOF before the header.
/// The pre-v4 entry point: a binary frame here is a protocol error
/// (the peer sent v4 payloads without negotiating them).
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Json>> {
    match read_wire_frame(r)? {
        None => Ok(None),
        Some(WireFrame::Json(v)) => Ok(Some(v)),
        Some(WireFrame::Bin(_)) => Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "unexpected binary frame outside a negotiated v4 stream",
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let v = Request::v2(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from("user-3"))]),
            3,
        )
        .to_json();
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(back, v);
        // EOF afterwards.
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn request_envelope_roundtrip() {
        let req = Request::v2("status", Json::obj(vec![]), 9);
        let back = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        assert!(Request::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn protoless_request_negotiates_as_retired_v1() {
        use super::super::api::ErrorCode;
        let req = Request {
            method: "status".to_string(),
            params: Json::obj(vec![]),
            id: None,
            proto: None,
            trace: None,
        };
        let err = req.negotiate_proto().unwrap_err();
        assert_eq!(err.code, ErrorCode::ProtocolMismatch);
        // An explicit proto-1 stamp is equally retired.
        let req = Request {
            proto: Some(1),
            ..req
        };
        assert!(req.negotiate_proto().is_err());
        // The supported window passes.
        for p in [super::super::api::PROTO_MIN, super::super::api::PROTO_MAX]
        {
            let req = Request {
                method: "status".to_string(),
                params: Json::obj(vec![]),
                id: Some(1),
                proto: Some(p),
                trace: None,
            };
            assert_eq!(req.negotiate_proto().unwrap(), p);
        }
    }

    #[test]
    fn envelope_roundtrips_id_and_error() {
        use super::super::api::{ApiError, ErrorCode};
        let req = Request::v2(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from("user-1"))]),
            7,
        );
        let back = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        assert_eq!(back.id, Some(7));
        let fail = Response::failure(
            Some(7),
            ApiError::new(ErrorCode::NoCapacity, "no capacity"),
        );
        let rt = Response::from_json(&fail.to_json()).unwrap();
        assert_eq!(rt, fail);
        let err = rt.into_api_result().unwrap_err();
        assert_eq!(err.code, ErrorCode::NoCapacity);
        assert!(err.retryable);
    }

    #[test]
    fn bare_string_error_maps_to_internal_code() {
        use super::super::api::ErrorCode;
        let resp = Response {
            ok: false,
            body: Json::from("boom"),
            id: None,
            error: None,
            stream: false,
        };
        let resp = Response::from_json(&resp.to_json()).unwrap();
        let err = resp.into_api_result().unwrap_err();
        assert_eq!(err.code, ErrorCode::Internal);
        assert_eq!(err.message, "boom");
    }

    #[test]
    fn stream_header_and_frames_roundtrip() {
        let header = Response::stream_header(
            Some(4),
            Json::obj(vec![("subscription", Json::from(1u64))]),
        );
        let rt = Response::from_json(&header.to_json()).unwrap();
        assert!(rt.stream);
        assert_eq!(rt, header);
        // A plain response reads back with stream = false.
        let plain = Response::success_v2(Some(5), Json::Null);
        assert!(!Response::from_json(&plain.to_json()).unwrap().stream);

        let ev = StreamFrame::event(
            1,
            Json::obj(vec![("type", Json::from("queue_depth"))]),
        );
        let rt = StreamFrame::from_json(&ev.to_json()).unwrap();
        assert_eq!(rt, ev);
        assert!(!rt.end);
        assert_eq!(rt.cursor, None);
        // A cursor-stamped frame round-trips the cursor.
        let stamped = StreamFrame::event(2, Json::Null).with_cursor(41);
        let rt = StreamFrame::from_json(&stamped.to_json()).unwrap();
        assert_eq!(rt.cursor, Some(41));
        let term = StreamFrame::terminal(2, None);
        let rt = StreamFrame::from_json(&term.to_json()).unwrap();
        assert!(rt.end);
        assert!(rt.event.is_none());
        assert!(rt.stats.is_none());
        let term = StreamFrame::terminal_with_stats(
            3,
            None,
            Json::obj(vec![("dropped", Json::from(2u64))]),
        );
        let rt = StreamFrame::from_json(&term.to_json()).unwrap();
        assert_eq!(rt, term);
        assert_eq!(rt.stats.unwrap().get("dropped").as_u64(), Some(2));
        assert!(StreamFrame::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn request_trace_field_roundtrips() {
        let t = TraceId::mint();
        let req = Request::v2("status", Json::obj(vec![]), 1)
            .with_trace(Some(t));
        let j = req.to_json();
        assert_eq!(j.get("trace").as_str(), Some(t.to_string().as_str()));
        let back = Request::from_json(&j).unwrap();
        assert_eq!(back.trace, Some(t));
        assert_eq!(back, req);
        // Malformed trace ids are rejected, not dropped.
        let mut bad = req.to_json();
        bad.set("trace", Json::from("span-7"));
        assert!(Request::from_json(&bad).is_err());
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"abc"); // claims 10, has 3
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn bin_frame_roundtrip_and_interleaving() {
        let mut buf = Vec::new();
        let data = BinFrame::data(1, vec![0xAB; 300]);
        write_bin_frame(&mut buf, &data).unwrap();
        // JSON frames interleave freely with binary ones.
        write_frame(&mut buf, &StreamFrame::terminal(2, None).to_json())
            .unwrap();
        write_bin_frame(&mut buf, &BinFrame::end_marker(3)).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let f1 = read_wire_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(f1, WireFrame::Bin(data));
        let f2 = read_wire_frame(&mut cursor).unwrap().unwrap();
        assert!(matches!(f2, WireFrame::Json(_)));
        let f3 = read_wire_frame(&mut cursor).unwrap().unwrap();
        let WireFrame::Bin(end) = f3 else {
            panic!("expected binary end marker")
        };
        assert!(end.is_end());
        assert!(end.payload.is_empty());
        assert_eq!(end.seq, 3);
        assert!(read_wire_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn empty_bin_payload_roundtrips() {
        let mut buf = Vec::new();
        write_bin_frame(&mut buf, &BinFrame::data(7, Vec::new())).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let WireFrame::Bin(b) = read_wire_frame(&mut cursor).unwrap().unwrap()
        else {
            panic!("expected binary frame")
        };
        assert_eq!(b.seq, 7);
        assert!(b.payload.is_empty());
        assert!(!b.is_end());
    }

    #[test]
    fn oversized_bin_frame_rejected_both_ways() {
        // Writer refuses payloads beyond MAX_FRAME.
        let huge = BinFrame::data(1, vec![0; MAX_FRAME as usize + 1]);
        assert!(write_bin_frame(&mut Vec::new(), &huge).is_err());
        // Reader refuses a forged oversized length word.
        let mut buf = Vec::new();
        let forged = (MAX_FRAME + BIN_HEADER_BYTES + 1) | BIN_FRAME_BIT;
        buf.extend_from_slice(&forged.to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_wire_frame(&mut cursor).is_err());
    }

    #[test]
    fn short_bin_frame_rejected() {
        // Length word claims binary but is shorter than the header.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(4u32 | BIN_FRAME_BIT).to_le_bytes());
        buf.extend_from_slice(&[0; 4]);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_wire_frame(&mut cursor).is_err());
    }

    #[test]
    fn v3_reader_rejects_bin_frames() {
        let mut buf = Vec::new();
        write_bin_frame(&mut buf, &BinFrame::data(1, vec![1, 2])).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn truncated_bin_payload_is_io_error() {
        let mut buf = Vec::new();
        write_bin_frame(&mut buf, &BinFrame::data(1, vec![9; 64])).unwrap();
        buf.truncate(buf.len() - 10);
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_wire_frame(&mut cursor).is_err());
    }
}
