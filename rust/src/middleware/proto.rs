//! Length-prefixed JSON framing + request/response envelopes.
//!
//! Two envelope generations share the frame format:
//!
//! * **v1** (one version behind, still readable): requests are
//!   `{"method", "params"}`, responses `{"ok", "body"}` with a plain
//!   string body on error.
//! * **v2** (current): requests additionally carry a client-chosen
//!   `id` (echoed back so pipelined callers can correlate) and a
//!   `proto` number; error responses carry a structured
//!   [`ApiError`] object under `"error"` (the string body is kept in
//!   parallel so v1 readers still see a message).

use std::io::{Read, Write};

use super::api::ApiError;
use crate::util::json::Json;

/// Max frame we accept (a full bitstream upload fits comfortably).
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// An RPC request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    pub params: Json,
    /// Client-chosen correlation id, echoed in the response (v2).
    pub id: Option<u64>,
    /// Protocol the client speaks for this request; absent = 1.
    pub proto: Option<u32>,
}

impl Request {
    /// A v1 (legacy-envelope) request.
    pub fn new(method: &str, params: Json) -> Request {
        Request {
            method: method.to_string(),
            params,
            id: None,
            proto: None,
        }
    }

    /// A v2 request with a correlation id.
    pub fn v2(method: &str, params: Json, id: u64) -> Request {
        Request {
            method: method.to_string(),
            params,
            id: Some(id),
            proto: Some(super::api::PROTO_MAX),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("method", Json::from(self.method.as_str())),
            ("params", self.params.clone()),
        ]);
        if let Some(id) = self.id {
            j.set("id", Json::from(id));
        }
        if let Some(p) = self.proto {
            j.set("proto", Json::from(u64::from(p)));
        }
        j
    }

    pub fn from_json(v: &Json) -> Result<Request, String> {
        Ok(Request {
            method: v.str_field("method")?.to_string(),
            params: v.get("params").clone(),
            id: v.get("id").as_u64(),
            proto: v.get("proto").as_u64().map(|p| p as u32),
        })
    }

    /// Envelope protocol of this request (absent = 1), or a
    /// `protocol_mismatch` error when outside the supported window —
    /// checked before dispatch by every peer.
    pub fn negotiate_proto(&self) -> Result<u32, ApiError> {
        let proto = self.proto.unwrap_or(1);
        if (super::api::PROTO_MIN..=super::api::PROTO_MAX)
            .contains(&proto)
        {
            Ok(proto)
        } else {
            Err(ApiError::protocol_mismatch(proto, proto))
        }
    }
}

/// Wrap a dispatch result in the envelope generation the request
/// spoke — shared by the management server and the node agents.
/// Out-of-range protocols (> 2) answer v2-shaped so a future client
/// can still read the `protocol_mismatch` code.
pub fn respond(
    proto: u32,
    id: Option<u64>,
    result: Result<Json, ApiError>,
) -> Response {
    if proto >= 2 {
        match result {
            Ok(body) => Response::success_v2(id, body),
            Err(e) => Response::failure(id, e),
        }
    } else {
        match result {
            Ok(body) => Response::success(body),
            Err(e) => Response::error(&e.message),
        }
    }
}

/// An RPC response.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub ok: bool,
    pub body: Json,
    /// Echo of the request's correlation id (v2).
    pub id: Option<u64>,
    /// Structured failure (v2); `body` carries the message string in
    /// parallel for v1 readers.
    pub error: Option<ApiError>,
}

impl Response {
    pub fn success(body: Json) -> Response {
        Response {
            ok: true,
            body,
            id: None,
            error: None,
        }
    }

    /// A v1 failure: string body only.
    pub fn error(msg: &str) -> Response {
        Response {
            ok: false,
            body: Json::from(msg),
            id: None,
            error: None,
        }
    }

    /// A v2 success echoing the request id.
    pub fn success_v2(id: Option<u64>, body: Json) -> Response {
        Response {
            ok: true,
            body,
            id,
            error: None,
        }
    }

    /// A v2 failure: structured error + message string body.
    pub fn failure(id: Option<u64>, error: ApiError) -> Response {
        Response {
            ok: false,
            body: Json::from(error.message.as_str()),
            id,
            error: Some(error),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj(vec![
            ("ok", Json::from(self.ok)),
            ("body", self.body.clone()),
        ]);
        if let Some(id) = self.id {
            j.set("id", Json::from(id));
        }
        if let Some(e) = &self.error {
            j.set("error", e.to_json());
        }
        j
    }

    pub fn from_json(v: &Json) -> Result<Response, String> {
        let error = match v.get("error") {
            Json::Null => None,
            e => Some(ApiError::from_json(e)?),
        };
        Ok(Response {
            ok: v
                .get("ok")
                .as_bool()
                .ok_or("response missing 'ok'")?,
            body: v.get("body").clone(),
            id: v.get("id").as_u64(),
            error,
        })
    }

    /// Unwrap into Result for client ergonomics (v1 view: errors as
    /// strings).
    pub fn into_result(self) -> Result<Json, String> {
        if self.ok {
            Ok(self.body)
        } else if let Some(e) = self.error {
            Err(e.message)
        } else {
            Err(self
                .body
                .as_str()
                .unwrap_or("unknown error")
                .to_string())
        }
    }

    /// Unwrap into Result keeping the structured error (v2 view). A
    /// v1 string error maps to [`crate::middleware::api::ErrorCode::Internal`].
    pub fn into_api_result(self) -> Result<Json, ApiError> {
        if self.ok {
            Ok(self.body)
        } else if let Some(e) = self.error {
            Err(e)
        } else {
            Err(ApiError::internal(
                self.body.as_str().unwrap_or("unknown error"),
            ))
        }
    }
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, v: &Json) -> std::io::Result<()> {
    let text = v.to_string();
    let len = text.len() as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(text.as_bytes())?;
    w.flush()
}

/// Read one frame; `Ok(None)` on clean EOF before the header.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds limit"),
        ));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    let text = String::from_utf8(buf).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "bad utf-8")
    })?;
    Json::parse(&text)
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let v = Request::new(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from("user-3"))]),
        )
        .to_json();
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(back, v);
        // EOF afterwards.
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn request_envelope_roundtrip() {
        let req = Request::new("status", Json::obj(vec![]));
        let back = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        assert!(Request::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn response_into_result() {
        assert_eq!(
            Response::success(Json::from(5u64)).into_result(),
            Ok(Json::Num(5.0))
        );
        assert_eq!(
            Response::error("nope").into_result(),
            Err("nope".to_string())
        );
        let rt =
            Response::from_json(&Response::error("e").to_json()).unwrap();
        assert!(!rt.ok);
    }

    #[test]
    fn v2_envelope_roundtrips_id_and_error() {
        use super::super::api::{ApiError, ErrorCode};
        let req = Request::v2(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from("user-1"))]),
            7,
        );
        let back = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        assert_eq!(back.id, Some(7));
        let fail = Response::failure(
            Some(7),
            ApiError::new(ErrorCode::NoCapacity, "no capacity"),
        );
        let rt = Response::from_json(&fail.to_json()).unwrap();
        assert_eq!(rt, fail);
        let err = rt.into_api_result().unwrap_err();
        assert_eq!(err.code, ErrorCode::NoCapacity);
        assert!(err.retryable);
        // The same failure still reads as a v1 string error.
        assert_eq!(
            Response::from_json(&fail.to_json())
                .unwrap()
                .into_result(),
            Err("no capacity".to_string())
        );
    }

    #[test]
    fn v1_string_error_maps_to_internal_code() {
        use super::super::api::ErrorCode;
        let resp =
            Response::from_json(&Response::error("boom").to_json()).unwrap();
        let err = resp.into_api_result().unwrap_err();
        assert_eq!(err.code, ErrorCode::Internal);
        assert_eq!(err.message, "boom");
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"abc"); // claims 10, has 3
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }
}
