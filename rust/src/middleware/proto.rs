//! Length-prefixed JSON framing + request/response envelopes.

use std::io::{Read, Write};

use crate::util::json::Json;

/// Max frame we accept (a full bitstream upload fits comfortably).
pub const MAX_FRAME: u32 = 64 * 1024 * 1024;

/// An RPC request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub method: String,
    pub params: Json,
}

impl Request {
    pub fn new(method: &str, params: Json) -> Request {
        Request {
            method: method.to_string(),
            params,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::from(self.method.as_str())),
            ("params", self.params.clone()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Request, String> {
        Ok(Request {
            method: v.str_field("method")?.to_string(),
            params: v.get("params").clone(),
        })
    }
}

/// An RPC response.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub ok: bool,
    pub body: Json,
}

impl Response {
    pub fn success(body: Json) -> Response {
        Response { ok: true, body }
    }

    pub fn error(msg: &str) -> Response {
        Response {
            ok: false,
            body: Json::from(msg),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::from(self.ok)),
            ("body", self.body.clone()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Response, String> {
        Ok(Response {
            ok: v
                .get("ok")
                .as_bool()
                .ok_or("response missing 'ok'")?,
            body: v.get("body").clone(),
        })
    }

    /// Unwrap into Result for client ergonomics.
    pub fn into_result(self) -> Result<Json, String> {
        if self.ok {
            Ok(self.body)
        } else {
            Err(self
                .body
                .as_str()
                .unwrap_or("unknown error")
                .to_string())
        }
    }
}

/// Write one frame.
pub fn write_frame(w: &mut impl Write, v: &Json) -> std::io::Result<()> {
    let text = v.to_string();
    let len = text.len() as u32;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(text.as_bytes())?;
    w.flush()
}

/// Read one frame; `Ok(None)` on clean EOF before the header.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Json>> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            return Ok(None)
        }
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds limit"),
        ));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    let text = String::from_utf8(buf).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "bad utf-8")
    })?;
    Json::parse(&text)
        .map(Some)
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let v = Request::new(
            "alloc_vfpga",
            Json::obj(vec![("user", Json::from("user-3"))]),
        )
        .to_json();
        let mut buf = Vec::new();
        write_frame(&mut buf, &v).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let back = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(back, v);
        // EOF afterwards.
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn request_envelope_roundtrip() {
        let req = Request::new("status", Json::obj(vec![]));
        let back = Request::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
        assert!(Request::from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn response_into_result() {
        assert_eq!(
            Response::success(Json::from(5u64)).into_result(),
            Ok(Json::Num(5.0))
        );
        assert_eq!(
            Response::error("nope").into_result(),
            Err("nope".to_string())
        );
        let rt =
            Response::from_json(&Response::error("e").to_json()).unwrap();
        assert!(!rt.ok);
    }

    #[test]
    fn truncated_body_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"abc"); // claims 10, has 3
        let mut cursor = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cursor).is_err());
    }
}
