//! The RC3E middleware: RPC protocol, management-node server, node
//! agents and the client library the CLI uses.
//!
//! Section IV-C: "The RC3E hypervisor is running on the management
//! node and can access each FPGA node. Users can access the cloud
//! services directly through a middleware with a command line
//! interface on the management node."
//!
//! Topology: one [`server::ManagementServer`] (the management node)
//! fronting the hypervisor, plus one [`agent::NodeAgent`] per FPGA
//! node. Device-local operations (status) are routed management →
//! agent over a second TCP hop, mirroring the paper's
//! node-over-Gigabit-Ethernet structure; Table I's finding — the
//! RC3E overhead dominates and local vs remote node makes no
//! difference — reproduces because the dominant charge is the
//! middleware's virtual RPC overhead, not the wire. The agent has
//! since grown into the full [`crate::cluster`] federation: `rc3e
//! serve --federated` runs the management node as a placement layer
//! over per-node daemon *processes* (`rc3e node`), each owning its
//! local hypervisor, scheduler WAL and event journal — see
//! `docs/FEDERATION.md`.
//!
//! The RPC surface is typed and versioned ([`api`]): every method has
//! request/response structs, errors carry machine-readable
//! [`api::ErrorCode`]s, `hello` negotiates the protocol window, and
//! long-running operations return [`jobs`] handles. Protocol 3 adds
//! the event-stream surface: `subscribe` turns a connection into a
//! multi-frame stream of typed [`api::Event`]s fed by the [`events`]
//! bus (job progress, placement changes, region lifecycle
//! transitions, scheduler telemetry), and `job_wait` callers coalesce
//! on shared per-job wakeup slots. Protocol 1 (the untyped surface)
//! is retired. See `docs/PROTOCOL.md` for the wire format.
//!
//! Wire format: 4-byte little-endian length + JSON
//! (`{"method", "params", "id", "proto"}` /
//! `{"ok", "body", "id"?, "error"?, "stream"?}`, with
//! `{"seq", "event"?, "end"?}` frames after a stream header).
//! Protocol 4 adds an out-of-band binary framing for bulk data: a
//! length word with the top bit set carries `[flags][seq][bytes]`
//! instead of JSON text, so `stream` output moves without base64 or
//! envelope parsing — see [`proto::BinFrame`] and
//! `docs/PROTOCOL.md`.

pub mod agent;
pub mod api;
pub mod client;
pub mod events;
pub mod jobs;
pub mod proto;
pub mod server;

pub use agent::NodeAgent;
pub use api::{
    ApiError, ErrorCode, Event, Method, SubscriptionFilter, Topic,
    PROTO_DATA_FRAMES, PROTO_MAX, PROTO_MIN,
};
pub use client::{Client, EventFrame, EventStream};
pub use events::{EventBus, Scope};
pub use jobs::{JobRegistry, JobState, ProgressReporter};
pub use proto::{
    read_frame, read_wire_frame, write_bin_frame, write_frame,
    BinFrame, Request, Response, StreamFrame, WireFrame,
};
pub use server::ManagementServer;
