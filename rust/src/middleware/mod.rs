//! The RC3E middleware: RPC protocol, management-node server, node
//! agents and the client library the CLI uses.
//!
//! Section IV-C: "The RC3E hypervisor is running on the management
//! node and can access each FPGA node. Users can access the cloud
//! services directly through a middleware with a command line
//! interface on the management node."
//!
//! Topology: one [`server::ManagementServer`] (the management node)
//! fronting the hypervisor, plus one [`agent::NodeAgent`] per FPGA
//! node. Device-local operations (status) are routed management →
//! agent over a second TCP hop, mirroring the paper's
//! node-over-Gigabit-Ethernet structure; Table I's finding — the
//! RC3E overhead dominates and local vs remote node makes no
//! difference — reproduces because the dominant charge is the
//! middleware's virtual RPC overhead, not the wire.
//!
//! Wire format: 4-byte little-endian length + JSON
//! (`{"method": ..., "params": {...}}` / `{"ok": ..., ...}`).

pub mod agent;
pub mod client;
pub mod proto;
pub mod server;

pub use agent::NodeAgent;
pub use client::Client;
pub use proto::{read_frame, write_frame, Request, Response};
pub use server::ManagementServer;
