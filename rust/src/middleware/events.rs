//! Server-side event bus behind the protocol-3 `subscribe` surface.
//!
//! Producers (the job registry, the scheduler sink, the per-device
//! transition sink) publish typed [`Event`]s with a delivery
//! [`Scope`]; the bus fans each event out to every live
//! [`Subscription`] whose filter *and* scope admit it. Consumers (the
//! server's subscribe loop) block on [`Subscription::next`] — one
//! publish wakes every matching subscriber, there is no polling
//! anywhere on the path.
//!
//! **Publishing is O(1) for the producer.** [`EventBus::publish`] is
//! a channel send; a single dispatcher thread performs the
//! per-subscriber fanout. Producers emit from hot critical sections
//! (the scheduler's state lock, a device lock), so the fanout cost
//! must never ride inside those locks. The channel is FIFO and the
//! dispatcher is single-threaded, so publish order *is* delivery
//! order for every subscriber. [`EventBus::flush`] blocks until
//! everything published so far has been fanned out (tests, benches).
//!
//! Scoping is the tenant-isolation boundary: a subscription is bound
//! at creation to the capability token it presented (and the tenant
//! that token resolves to). Token-scoped events (job progress) only
//! reach the subscription holding that exact token; tenant-scoped
//! events (placement changes) only reach subscriptions of that
//! tenant; public events (queue depth, grants, region transitions)
//! reach everyone. A filter can narrow further but can never widen
//! past the scope.
//!
//! Queues are bounded ([`SUBSCRIPTION_QUEUE_CAP`]): a subscriber that
//! stops draining loses its *oldest* events (counted in
//! [`Subscription::dropped`] and the `events.dropped` counter)
//! instead of wedging the dispatcher.
//!
//! **Cursors.** Every fanned-out event carries a cursor: a dense,
//! monotonically increasing position in the bus history. When an
//! [`EventJournal`] is attached ([`EventBus::attach_journal`]) the
//! cursor is the journal sequence number and the event is appended to
//! disk *before* any subscriber queue sees it — so a reconnecting
//! client can quote `from_cursor`, have the server replay the gap
//! from the journal ([`EventBus::replay_for`]) and then switch to
//! live delivery with no gaps and no duplicates (dedup by cursor).
//! Without a journal the cursor is a process-local counter: resume
//! only works within one server lifetime, but the frame format is
//! identical. See `docs/DURABILITY.md`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

use super::api::{Event, SubscriptionFilter};
use crate::journal::EventJournal;
use crate::metrics::Registry;
use crate::util::ids::{LeaseToken, UserId};

/// Events a subscription may hold undelivered before the oldest are
/// dropped.
pub const SUBSCRIPTION_QUEUE_CAP: usize = 1024;

/// Who may see an event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scope {
    /// Operator telemetry: every subscription.
    Public,
    /// Only the subscription presenting this capability token.
    Token(LeaseToken),
    /// Only subscriptions whose token resolves to this tenant.
    Tenant(UserId),
}

/// One live subscription's delivery queue.
#[derive(Debug)]
pub struct Subscription {
    id: u64,
    filter: SubscriptionFilter,
    /// Capability presented at subscribe time (token-scope matching).
    token: Option<LeaseToken>,
    /// Tenant the token resolved to (tenant-scope matching).
    tenant: Option<UserId>,
    queue: Mutex<VecDeque<(u64, Event)>>,
    ready: Condvar,
    closed: AtomicBool,
    dropped: AtomicU64,
    /// Deepest the queue has ever been (backpressure telemetry).
    high_water: AtomicU64,
    /// Events handed to this subscriber's queue so far.
    delivered: AtomicU64,
}

impl Subscription {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Events lost to the bounded queue so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Deepest this subscription's queue has ever been.
    pub fn high_water(&self) -> u64 {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Events enqueued for this subscriber so far (whether or not
    /// the client drained them before the stream closed).
    pub fn delivered(&self) -> u64 {
        self.delivered.load(Ordering::Relaxed)
    }

    /// Does this subscription's scope admit an event published with
    /// `scope`? (The client-chosen filter is checked separately.)
    fn scope_admits(&self, scope: Scope) -> bool {
        match scope {
            Scope::Public => true,
            Scope::Token(t) => self.token == Some(t),
            Scope::Tenant(u) => self.tenant == Some(u),
        }
    }

    /// Enqueue one cursor-stamped event; returns true when the
    /// bounded queue evicted its oldest entry to make room.
    fn push(&self, cursor: u64, event: Event) -> bool {
        let mut q = self.queue.lock().unwrap();
        let mut evicted = false;
        if q.len() == SUBSCRIPTION_QUEUE_CAP {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
            evicted = true;
        }
        q.push_back((cursor, event));
        self.high_water.fetch_max(q.len() as u64, Ordering::Relaxed);
        self.delivered.fetch_add(1, Ordering::Relaxed);
        drop(q);
        self.ready.notify_all();
        evicted
    }

    /// Next queued event, blocking up to `timeout` of wall time.
    /// `None` on expiry or when the subscription was closed.
    pub fn next(&self, timeout: Duration) -> Option<Event> {
        self.next_with_cursor(timeout).map(|(_, ev)| ev)
    }

    /// Like [`Subscription::next`], but also yields the event's
    /// cursor (the position resume clients quote as `from_cursor`).
    pub fn next_with_cursor(
        &self,
        timeout: Duration,
    ) -> Option<(u64, Event)> {
        let deadline = Instant::now() + timeout;
        let mut q = self.queue.lock().unwrap();
        loop {
            if let Some(entry) = q.pop_front() {
                return Some(entry);
            }
            if self.closed.load(Ordering::SeqCst) {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) =
                self.ready.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }
    }

    /// Drain without blocking (tests, shutdown).
    pub fn drain(&self) -> Vec<Event> {
        self.queue.lock().unwrap().drain(..).map(|(_, ev)| ev).collect()
    }
}

#[derive(Debug, Default)]
struct BusState {
    subs: BTreeMap<u64, Arc<Subscription>>,
    next_id: u64,
}

/// The process-wide event bus. Construct with [`EventBus::new`] (it
/// owns a dispatcher thread that exits when the bus is dropped).
#[derive(Debug)]
pub struct EventBus {
    state: Mutex<BusState>,
    /// Producer side of the dispatch channel; dropping the bus drops
    /// it, which ends the dispatcher thread.
    tx: mpsc::Sender<(Event, Scope)>,
    /// Events handed to the channel so far.
    enqueued: AtomicU64,
    /// Events the dispatcher has fanned out so far (flush barrier).
    processed: Mutex<u64>,
    processed_cv: Condvar,
    /// Counters land here when wired (`events.published`,
    /// `events.delivered`, `events.dropped`).
    metrics: Mutex<Option<Arc<Registry>>>,
    /// Durable backing store; when attached, every event is appended
    /// here (assigning its cursor) before any subscriber sees it.
    journal: Mutex<Option<Arc<EventJournal>>>,
    /// Last cursor assigned. Without a journal this counter mints
    /// cursors; with one it mirrors the journal sequence.
    cursor: AtomicU64,
}

impl EventBus {
    pub fn new() -> Arc<EventBus> {
        let (tx, rx) = mpsc::channel::<(Event, Scope)>();
        let bus = Arc::new(EventBus {
            state: Mutex::new(BusState::default()),
            tx,
            enqueued: AtomicU64::new(0),
            processed: Mutex::new(0),
            processed_cv: Condvar::new(),
            metrics: Mutex::new(None),
            journal: Mutex::new(None),
            cursor: AtomicU64::new(0),
        });
        // The dispatcher holds only a Weak: when the last Arc drops,
        // the sender inside it drops, recv() errors and the thread
        // exits.
        let weak: Weak<EventBus> = Arc::downgrade(&bus);
        std::thread::spawn(move || {
            while let Ok((event, scope)) = rx.recv() {
                let Some(bus) = weak.upgrade() else { break };
                bus.fanout(event, scope);
                let mut done = bus.processed.lock().unwrap();
                *done += 1;
                bus.processed_cv.notify_all();
            }
        });
        bus
    }

    /// Wire a metrics registry for bus counters.
    pub fn set_metrics(&self, metrics: Arc<Registry>) {
        *self.metrics.lock().unwrap() = Some(metrics);
    }

    /// Attach the durable event journal. Cursors continue from the
    /// journal's persisted history, so an event published after a
    /// restart never reuses a pre-crash cursor. Call before serving
    /// traffic (cursors minted earlier would not be on disk).
    pub fn attach_journal(&self, journal: Arc<EventJournal>) {
        self.cursor.store(
            journal.next_cursor().saturating_sub(1),
            Ordering::SeqCst,
        );
        *self.journal.lock().unwrap() = Some(journal);
    }

    /// Last cursor assigned to any event (0 before the first one).
    pub fn last_cursor(&self) -> u64 {
        self.cursor.load(Ordering::SeqCst)
    }

    /// Register a subscription. `token` is the capability presented
    /// on the wire; `tenant` is the tenant it resolved to (server
    /// side) — both are fixed for the subscription's lifetime.
    pub fn subscribe(
        &self,
        filter: SubscriptionFilter,
        token: Option<LeaseToken>,
        tenant: Option<UserId>,
    ) -> Arc<Subscription> {
        let mut st = self.state.lock().unwrap();
        st.next_id += 1;
        let sub = Arc::new(Subscription {
            id: st.next_id,
            filter,
            token,
            tenant,
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            closed: AtomicBool::new(false),
            dropped: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
            delivered: AtomicU64::new(0),
        });
        st.subs.insert(sub.id, Arc::clone(&sub));
        sub
    }

    /// Remove a subscription and wake its reader.
    pub fn unsubscribe(&self, id: u64) {
        let sub = self.state.lock().unwrap().subs.remove(&id);
        if let Some(sub) = sub {
            sub.closed.store(true, Ordering::SeqCst);
            sub.ready.notify_all();
        }
    }

    /// Live subscriptions (telemetry, tests).
    pub fn subscriber_count(&self) -> usize {
        self.state.lock().unwrap().subs.len()
    }

    /// Publish one event: a channel send, O(1) for the caller —
    /// producers emit from inside hot critical sections and must
    /// never pay the fanout there. Delivery order equals publish
    /// order for every subscriber (single FIFO dispatcher).
    pub fn publish(&self, event: Event, scope: Scope) {
        self.enqueued.fetch_add(1, Ordering::SeqCst);
        if self.tx.send((event, scope)).is_err() {
            // Dispatcher gone (bus mid-teardown): count it processed
            // so a concurrent flush cannot hang.
            let mut done = self.processed.lock().unwrap();
            *done += 1;
            self.processed_cv.notify_all();
        }
    }

    /// Block until everything published so far has been fanned out
    /// to the subscriber queues (tests and benches; servers never
    /// need it — subscribers just block on their queues).
    pub fn flush(&self) {
        let target = self.enqueued.load(Ordering::SeqCst);
        let mut done = self.processed.lock().unwrap();
        while *done < target {
            done = self.processed_cv.wait(done).unwrap();
        }
    }

    /// Replay journaled history for one subscription: every retained
    /// event with cursor >= `from` that the subscription's scope and
    /// filter admit, in cursor order. Empty without a journal. The
    /// server's subscribe loop calls this *after* registering the
    /// subscription, then skips live events at or below the last
    /// replayed cursor — that overlap discipline is what makes resume
    /// gapless and duplicate-free.
    pub fn replay_for(
        &self,
        sub: &Subscription,
        from: u64,
    ) -> Vec<(u64, Event)> {
        let journal = self.journal.lock().unwrap().clone();
        let Some(journal) = journal else { return Vec::new() };
        let t0 = Instant::now();
        let records = match journal.replay_from(from) {
            Ok(records) => records,
            Err(e) => {
                log::warn!("event journal replay failed: {e}");
                return Vec::new();
            }
        };
        let out: Vec<(u64, Event)> = records
            .into_iter()
            .filter(|(_, ev, scope)| {
                sub.scope_admits(*scope) && sub.filter.matches(ev)
            })
            .map(|(cursor, ev, _)| (cursor, ev))
            .collect();
        if let Some(m) = self.metrics.lock().unwrap().as_ref() {
            m.histogram("journal.events.replay")
                .record_us(t0.elapsed().as_micros() as u64);
        }
        out
    }

    /// Dispatcher half of [`EventBus::publish`]: assign the event its
    /// cursor (journal append first, when attached — the disk sees an
    /// event before any subscriber can), then fan it out to every
    /// subscription whose scope and filter admit it. Never blocks on
    /// consumers (bounded drop-oldest queues).
    fn fanout(&self, event: Event, scope: Scope) {
        let cursor = {
            let journal = self.journal.lock().unwrap();
            match journal.as_ref().map(|j| j.append(&event, scope)) {
                Some(Ok(cursor)) => {
                    self.cursor.store(cursor, Ordering::SeqCst);
                    cursor
                }
                Some(Err(e)) => {
                    // Degrade to live-only delivery rather than
                    // wedging the bus; resume loses this event.
                    log::warn!("event journal append failed: {e}");
                    self.cursor.fetch_add(1, Ordering::SeqCst) + 1
                }
                None => self.cursor.fetch_add(1, Ordering::SeqCst) + 1,
            }
        };
        let subs: Vec<Arc<Subscription>> = {
            let st = self.state.lock().unwrap();
            st.subs.values().cloned().collect()
        };
        let mut delivered = 0u64;
        let mut dropped = 0u64;
        let mut high_water = 0u64;
        for sub in subs {
            if sub.scope_admits(scope) && sub.filter.matches(&event) {
                if sub.push(cursor, event.clone()) {
                    dropped += 1;
                }
                delivered += 1;
                high_water = high_water.max(sub.high_water());
            }
        }
        if let Some(m) = self.metrics.lock().unwrap().as_ref() {
            m.counter("events.published").inc();
            m.counter("events.delivered").add(delivered);
            m.counter("events.dropped").add(dropped);
            m.gauge("events.queue.high_water")
                .fetch_max(high_water as i64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::JobId;

    fn progress(job: u64) -> Event {
        Event::JobProgress {
            job: JobId(job),
            method: "stream".into(),
            phase: "streaming".into(),
            bytes_streamed: 0,
            pct: 10.0,
            state: "running".into(),
            result: None,
            trace: None,
        }
    }

    #[test]
    fn publish_fans_out_to_matching_subscribers() {
        let bus = EventBus::new();
        let a = bus.subscribe(SubscriptionFilter::all(), None, None);
        let b = bus.subscribe(
            SubscriptionFilter::topic(super::super::api::Topic::Sched),
            None,
            None,
        );
        bus.publish(Event::QueueDepth { depth: 2 }, Scope::Public);
        assert_eq!(
            a.next(Duration::from_secs(1)),
            Some(Event::QueueDepth { depth: 2 })
        );
        assert_eq!(
            b.next(Duration::from_secs(1)),
            Some(Event::QueueDepth { depth: 2 })
        );
        // A job event is off-topic for b.
        bus.publish(progress(1), Scope::Public);
        assert!(a.next(Duration::from_millis(500)).is_some());
        bus.flush();
        assert!(b.next(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn token_scope_never_leaks_across_subscriptions() {
        let bus = EventBus::new();
        let mine = LeaseToken::mint();
        let theirs = LeaseToken::mint();
        let me = bus.subscribe(
            SubscriptionFilter::all(),
            Some(mine),
            Some(UserId(0)),
        );
        let them = bus.subscribe(
            SubscriptionFilter::all(),
            Some(theirs),
            Some(UserId(1)),
        );
        bus.publish(progress(7), Scope::Token(mine));
        assert!(me.next(Duration::from_millis(500)).is_some());
        bus.flush();
        assert!(them.next(Duration::from_millis(10)).is_none());
        // Tenant scope behaves the same way.
        bus.publish(
            Event::LeasePlacementChanged {
                alloc: crate::util::ids::AllocationId(0),
                vfpga: crate::util::ids::VfpgaId(1),
                fpga: crate::util::ids::FpgaId(0),
                migrations: 1,
            },
            Scope::Tenant(UserId(1)),
        );
        assert!(them.next(Duration::from_millis(500)).is_some());
        bus.flush();
        assert!(me.next(Duration::from_millis(10)).is_none());
    }

    #[test]
    fn bounded_queue_drops_oldest_and_counts() {
        let metrics = Arc::new(Registry::new());
        let bus = EventBus::new();
        bus.set_metrics(Arc::clone(&metrics));
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        for i in 0..(SUBSCRIPTION_QUEUE_CAP as u64 + 5) {
            bus.publish(Event::QueueDepth { depth: i }, Scope::Public);
        }
        bus.flush();
        assert_eq!(sub.dropped(), 5);
        assert_eq!(metrics.counter("events.dropped").get(), 5);
        assert_eq!(
            metrics.counter("events.published").get(),
            SUBSCRIPTION_QUEUE_CAP as u64 + 5
        );
        // The oldest surviving event is depth 5.
        assert_eq!(
            sub.next(Duration::from_secs(1)),
            Some(Event::QueueDepth { depth: 5 })
        );
        // Backpressure stats: the queue pegged at its cap, and the
        // bus-level high-water gauge observed it.
        assert_eq!(sub.high_water(), SUBSCRIPTION_QUEUE_CAP as u64);
        assert_eq!(
            sub.delivered(),
            SUBSCRIPTION_QUEUE_CAP as u64 + 5
        );
        assert_eq!(
            metrics.gauge("events.queue.high_water").get(),
            SUBSCRIPTION_QUEUE_CAP as i64
        );
    }

    #[test]
    fn high_water_tracks_peak_not_current_depth() {
        let bus = EventBus::new();
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        for i in 0..3u64 {
            bus.publish(Event::QueueDepth { depth: i }, Scope::Public);
        }
        bus.flush();
        // Drain fully; the peak sticks at 3.
        while sub.next(Duration::from_millis(10)).is_some() {}
        assert_eq!(sub.high_water(), 3);
        bus.publish(Event::QueueDepth { depth: 9 }, Scope::Public);
        bus.flush();
        assert_eq!(sub.high_water(), 3, "peak must not regress");
        assert_eq!(sub.dropped(), 0);
    }

    #[test]
    fn delivery_preserves_publish_order() {
        let bus = EventBus::new();
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        for i in 0..100u64 {
            bus.publish(Event::QueueDepth { depth: i }, Scope::Public);
        }
        for i in 0..100u64 {
            assert_eq!(
                sub.next(Duration::from_secs(1)),
                Some(Event::QueueDepth { depth: i })
            );
        }
    }

    #[test]
    fn cursors_are_dense_without_a_journal() {
        let bus = EventBus::new();
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        for i in 0..5u64 {
            bus.publish(Event::QueueDepth { depth: i }, Scope::Public);
        }
        bus.flush();
        for want in 1..=5u64 {
            let (cursor, _) =
                sub.next_with_cursor(Duration::from_secs(1)).unwrap();
            assert_eq!(cursor, want);
        }
        assert_eq!(bus.last_cursor(), 5);
    }

    #[test]
    fn journal_replay_respects_scope_and_filter() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_bus_journal_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let journal = Arc::new(EventJournal::open(&dir).unwrap());
        let bus = EventBus::new();
        bus.attach_journal(Arc::clone(&journal));
        let mine = LeaseToken::mint();
        // Publish with nobody subscribed: one public, one scoped to
        // a token this subscriber won't hold.
        bus.publish(Event::QueueDepth { depth: 1 }, Scope::Public);
        bus.publish(progress(9), Scope::Token(mine));
        bus.publish(Event::QueueDepth { depth: 2 }, Scope::Public);
        bus.flush();
        // A late public subscriber replays only what it could have
        // seen live: the two public events, in cursor order.
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        let replayed = bus.replay_for(&sub, 1);
        assert_eq!(replayed.len(), 2);
        assert_eq!(replayed[0].0, 1);
        assert_eq!(replayed[1].0, 3);
        // The token holder additionally sees its scoped event.
        let owner = bus.subscribe(
            SubscriptionFilter::all(),
            Some(mine),
            None,
        );
        assert_eq!(bus.replay_for(&owner, 1).len(), 3);
        // Live cursors continue past the journaled history.
        bus.publish(Event::QueueDepth { depth: 3 }, Scope::Public);
        bus.flush();
        let (cursor, _) =
            sub.next_with_cursor(Duration::from_secs(1)).unwrap();
        assert_eq!(cursor, 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn attached_journal_resumes_cursors_across_bus_restart() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_bus_restart_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let journal = Arc::new(EventJournal::open(&dir).unwrap());
            let bus = EventBus::new();
            bus.attach_journal(journal);
            bus.publish(Event::QueueDepth { depth: 0 }, Scope::Public);
            bus.publish(Event::QueueDepth { depth: 1 }, Scope::Public);
            bus.flush();
        }
        // A fresh bus over the same directory continues at cursor 3 —
        // pre-crash cursors are never reused.
        let journal = Arc::new(EventJournal::open(&dir).unwrap());
        let bus = EventBus::new();
        bus.attach_journal(journal);
        assert_eq!(bus.last_cursor(), 2);
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        bus.publish(Event::QueueDepth { depth: 2 }, Scope::Public);
        bus.flush();
        let (cursor, _) =
            sub.next_with_cursor(Duration::from_secs(1)).unwrap();
        assert_eq!(cursor, 3);
        // The gap (cursors 1..=2) replays from disk.
        let replayed = bus.replay_for(&sub, 1);
        assert_eq!(
            replayed.iter().map(|(c, _)| *c).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unsubscribe_wakes_blocked_reader() {
        let bus = EventBus::new();
        let sub = bus.subscribe(SubscriptionFilter::all(), None, None);
        let bus2 = Arc::clone(&bus);
        let id = sub.id();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            bus2.unsubscribe(id);
        });
        // Blocks until the unsubscribe, then yields None quickly.
        assert!(sub.next(Duration::from_secs(10)).is_none());
        h.join().unwrap();
        assert_eq!(bus.subscriber_count(), 0);
    }
}
