//! The cluster scheduler — the single admission path for every
//! allocation in the system.
//!
//! The paper's resource manager (Section IV-B) only picks a slot for
//! a request that can be satisfied *right now*; under heavy
//! multi-tenant traffic that collapses into immediate `NoCapacity`
//! errors and ad-hoc retry loops. This subsystem puts a real
//! scheduler between the service façades and the hypervisor:
//!
//! * [`queue`] — priority admission queue with weighted fair-share
//!   across tenants (stride scheduling);
//! * [`quota`] — per-tenant admission control: max concurrent
//!   vFPGA-equivalents and lifetime device-second budgets;
//! * [`reservation`] — time-boxed capacity reservations with
//!   virtual-clock expiry reclamation (vFPGA capacity only;
//!   exclusive physical leases are not reservable);
//! * [`preempt`] — relocation of lower-class leases via
//!   [`crate::hypervisor::migration`] so interactive requests land on
//!   a full cluster;
//! * [`accounting`] — per-tenant usage ledger charging device-seconds
//!   and energy (priced from the [`crate::fpga::power`] model).
//!
//! Everything above the hypervisor routes through [`Scheduler`]:
//! RSaaS/RAaaS/BAaaS façades ([`crate::service`]), VM launches
//! ([`crate::vm`]), the batch system ([`crate::batch`]) and the
//! middleware server's RPC surface ([`crate::middleware::server`]).
//!
//! Admission policy, in order:
//! 1. quota check — budget exhaustion is terminal, a concurrency cap
//!    queues the request until the tenant releases;
//! 2. capacity check — free regions on devices serving the requested
//!    model, minus capacity withheld by other tenants' active
//!    reservations;
//! 3. grant, or (interactive only) preempt a batch lease by
//!    migration, or queue (blocking path) / fail fast (interactive).
//!
//! Classes are strict (`Interactive > Normal > Batch`); within a
//! class tenants share capacity by quota weight. Queued requests of a
//! tenant sitting at its quota are skipped, not head-of-line
//! blockers, so no ready request starves.

pub mod accounting;
pub mod persist;
pub mod preempt;
pub mod queue;
pub mod quota;
pub mod reservation;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::util::clock::VirtualTime;
use crate::util::ids::{
    AllocationId, FpgaId, NodeId, ReservationId, TicketId, UserId, VfpgaId,
    VmId,
};
use crate::util::json::Json;

pub use accounting::{TenantUsage, UsageLedger};
pub use persist::PersistedState;
pub use preempt::{select_victim, victim_order, VictimInfo};
pub use queue::{AdmissionQueue, QueueEntry};
pub use quota::{QuotaBook, QuotaDenial, TenantQuota, PHYSICAL_EQUIV_UNITS};
pub use reservation::{Reservation, ReservationBook};

/// Request priority class. Strictly ordered: interactive beats
/// normal beats batch at every admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RequestClass {
    /// Long-running unattended work (batch system, BAaaS backfill) —
    /// preemptable.
    Batch,
    /// Default service traffic.
    Normal,
    /// Latency-sensitive user-facing requests; may preempt batch.
    Interactive,
}

impl RequestClass {
    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Batch => "batch",
            RequestClass::Normal => "normal",
            RequestClass::Interactive => "interactive",
        }
    }

    pub fn parse(s: &str) -> Option<RequestClass> {
        match s.to_ascii_lowercase().as_str() {
            "batch" => Some(RequestClass::Batch),
            "normal" => Some(RequestClass::Normal),
            "interactive" => Some(RequestClass::Interactive),
            _ => None,
        }
    }
}

/// Scheduler errors.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum SchedError {
    #[error("no capacity for the request")]
    NoCapacity,
    #[error("quota: {0}")]
    QuotaBudget(String),
    #[error("quota: {0}")]
    QuotaConcurrency(String),
    #[error("hypervisor: {0}")]
    Hypervisor(String),
    #[error("no scheduler grant for {0}")]
    UnknownGrant(AllocationId),
    #[error("request was cancelled")]
    Cancelled,
    #[error("unknown reservation {0}")]
    UnknownReservation(ReservationId),
}

impl From<HypervisorError> for SchedError {
    fn from(e: HypervisorError) -> SchedError {
        match e {
            HypervisorError::NoCapacity => SchedError::NoCapacity,
            other => SchedError::Hypervisor(other.to_string()),
        }
    }
}

impl From<SchedError> for HypervisorError {
    fn from(e: SchedError) -> HypervisorError {
        match e {
            SchedError::NoCapacity => HypervisorError::NoCapacity,
            other => HypervisorError::Sched(other.to_string()),
        }
    }
}

/// What a grant leases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrantTarget {
    Vfpga(VfpgaId, FpgaId, NodeId),
    Physical(FpgaId, NodeId),
}

/// An admitted allocation, as the scheduler tracks it.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedGrant {
    pub alloc: AllocationId,
    pub user: UserId,
    pub model: ServiceModel,
    pub class: RequestClass,
    pub target: GrantTarget,
    /// vFPGA-equivalents charged against quota and accounting.
    pub units: u64,
    /// Virtual timestamp of the grant.
    pub started_ns: u64,
    /// Virtual time spent in the admission queue (zero on fast path).
    pub wait: VirtualTime,
    /// Per-unit active power (W) for energy accounting.
    pub charge_w: f64,
    /// Reservation this admission drew a claim from, if any — the
    /// claim is credited back when the lease is released.
    pub from_reservation: Option<ReservationId>,
}

impl SchedGrant {
    pub fn vfpga(&self) -> Option<VfpgaId> {
        match self.target {
            GrantTarget::Vfpga(v, _, _) => Some(v),
            GrantTarget::Physical(_, _) => None,
        }
    }

    pub fn fpga(&self) -> FpgaId {
        match self.target {
            GrantTarget::Vfpga(_, f, _) | GrantTarget::Physical(f, _) => f,
        }
    }

    pub fn node(&self) -> NodeId {
        match self.target {
            GrantTarget::Vfpga(_, _, n) | GrantTarget::Physical(_, n) => n,
        }
    }
}

struct SchedState {
    queue: AdmissionQueue,
    quotas: QuotaBook,
    reservations: ReservationBook,
    ledger: UsageLedger,
    /// Live grants by allocation id (release + victim lookup).
    grants: BTreeMap<AllocationId, SchedGrant>,
    /// Finished queue tickets awaiting collection by their waiter.
    ready: BTreeMap<TicketId, Result<SchedGrant, SchedError>>,
}

/// The cluster scheduler.
///
/// One instance should front each hypervisor: the convenience
/// constructors (`RaaasService::new`, `BatchSystem::new`, …) each
/// build a private scheduler, which is fine in isolation, but when
/// several façades share one hypervisor they should share one
/// scheduler (`with_scheduler`) so quotas, fair-share and the
/// admission queue see all traffic. Blocking admissions still make
/// progress across independent instances (the wait loop re-pumps on
/// a wall-clock tick), but quotas and fairness are per-instance.
pub struct Scheduler {
    hv: Arc<Hypervisor>,
    /// Static device topology (fpga id → served models), cached at
    /// construction — devices never change after boot.
    devices: Vec<(FpgaId, Vec<ServiceModel>)>,
    /// Total vFPGA regions across the cluster (reservation clamp).
    total_regions: u64,
    state: Mutex<SchedState>,
    granted: Condvar,
    /// Where quota + ledger state persists (set by
    /// [`Scheduler::attach_persistence`]); `None` = in-memory only.
    /// Lock order: `state` before `persist_path`.
    persist_path: Mutex<Option<PathBuf>>,
    /// Monotonic snapshot counter, assigned under the state lock so
    /// sequence order matches snapshot order.
    persist_seq: AtomicU64,
    /// Sequence of the newest snapshot already on disk — file writes
    /// happen after the state lock is dropped, so without this guard
    /// two concurrent writers could land out of order and persist a
    /// stale snapshot last.
    persist_written: Mutex<u64>,
}

/// Physically free regions on devices serving `model`, ignoring
/// reservations.
fn raw_free_units(
    hv: &Hypervisor,
    devices: &[(FpgaId, Vec<ServiceModel>)],
    model: ServiceModel,
) -> u64 {
    let db = hv.db.lock().unwrap();
    devices
        .iter()
        .filter(|(_, models)| models.contains(&model))
        .map(|(f, _)| db.free_regions(*f).len() as u64)
        .sum()
}

/// Device-seconds `user` has consumed so far: the released total in
/// the ledger plus the accrued time of every live grant — so budgets
/// bound consumption while leases are still held, not just after the
/// first release.
fn used_device_seconds(
    ledger: &UsageLedger,
    grants: &BTreeMap<AllocationId, SchedGrant>,
    user: UserId,
    now_ns: u64,
) -> f64 {
    let live: f64 = grants
        .values()
        .filter(|g| g.user == user)
        .map(|g| {
            VirtualTime(now_ns.saturating_sub(g.started_ns)).as_secs_f64()
                * g.units as f64
        })
        .sum();
    ledger.device_seconds(user) + live
}

/// Free vFPGA capacity usable by `user` for `model`: free regions on
/// devices serving the model, minus capacity withheld by *other*
/// tenants' active reservations.
fn free_units(
    hv: &Hypervisor,
    devices: &[(FpgaId, Vec<ServiceModel>)],
    reservations: &ReservationBook,
    user: UserId,
    model: ServiceModel,
    now_ns: u64,
) -> u64 {
    raw_free_units(hv, devices, model)
        .saturating_sub(reservations.withheld_from(user, now_ns))
}

impl Scheduler {
    pub fn new(hv: Arc<Hypervisor>) -> Arc<Scheduler> {
        let devices: Vec<(FpgaId, Vec<ServiceModel>)> = hv
            .device_ids()
            .into_iter()
            .map(|id| {
                let models = hv
                    .device(id)
                    .map(|d| d.models.clone())
                    .unwrap_or_default();
                (id, models)
            })
            .collect();
        let total_regions = {
            let db = hv.db.lock().unwrap();
            db.devices
                .values()
                .map(|d| d.regions.len() as u64)
                .sum()
        };
        Arc::new(Scheduler {
            hv,
            devices,
            total_regions,
            state: Mutex::new(SchedState {
                queue: AdmissionQueue::new(),
                quotas: QuotaBook::new(),
                reservations: ReservationBook::new(),
                ledger: UsageLedger::new(),
                grants: BTreeMap::new(),
                ready: BTreeMap::new(),
            }),
            granted: Condvar::new(),
            persist_path: Mutex::new(None),
            persist_seq: AtomicU64::new(1),
            persist_written: Mutex::new(0),
        })
    }

    /// Build a scheduler whose quota + ledger state persists next to
    /// the device DB at `db_path`, loading existing state when
    /// present (accounting survives a management-node restart).
    pub fn new_persistent(
        hv: Arc<Hypervisor>,
        db_path: &Path,
    ) -> Result<Arc<Scheduler>, String> {
        let sched = Scheduler::new(hv);
        sched.attach_persistence(db_path)?;
        Ok(sched)
    }

    pub fn hv(&self) -> &Arc<Hypervisor> {
        &self.hv
    }

    // -------------------------------------------------- persistence

    /// Attach durable accounting: load `<db-stem>.sched.json` (next
    /// to `db_path`) when it exists, and re-save on every accounting
    /// mutation from now on. A raised reloaded cap can admit queued
    /// work, so the queue is pumped after a load.
    pub fn attach_persistence(
        &self,
        db_path: &Path,
    ) -> Result<(), String> {
        let path = persist::sched_state_path(db_path);
        let mut st = self.state.lock().unwrap();
        if path.exists() {
            let loaded = persist::load(&path)?;
            st.quotas.restore_limits(loaded.quotas);
            st.ledger.restore(loaded.usage);
            self.pump_locked(&mut st);
        }
        *self.persist_path.lock().unwrap() = Some(path);
        drop(st);
        self.granted.notify_all();
        Ok(())
    }

    /// Snapshot the durable state for writing, if persistence is
    /// attached. Called under the state lock (which also orders the
    /// sequence numbers); the caller writes the file *after* dropping
    /// it so disk IO never blocks admissions.
    fn persist_snapshot_locked(
        &self,
        st: &SchedState,
    ) -> Option<(u64, PathBuf, String)> {
        let path = self.persist_path.lock().unwrap().clone()?;
        let seq = self.persist_seq.fetch_add(1, Ordering::Relaxed);
        Some((seq, path, persist::render(&st.quotas, &st.ledger)))
    }

    /// Write a snapshot taken by [`Scheduler::persist_snapshot_locked`],
    /// skipping it when a newer snapshot already reached disk.
    fn write_persisted(&self, pending: Option<(u64, PathBuf, String)>) {
        let Some((seq, path, text)) = pending else { return };
        let mut written = self.persist_written.lock().unwrap();
        if *written > seq {
            return;
        }
        match std::fs::write(&path, text) {
            Ok(()) => *written = seq,
            Err(e) => log::warn!(
                "sched state persist to {} failed: {e}",
                path.display()
            ),
        }
    }

    // ------------------------------------------------------- quotas

    pub fn set_quota(&self, user: UserId, quota: TenantQuota) {
        self.update_quota(user, |q| *q = quota);
    }

    /// Atomic read-modify-write of a tenant's quota under the state
    /// lock (concurrent partial updates cannot lose fields). Returns
    /// the resulting quota. A raised cap can unblock queued requests,
    /// so the queue is pumped before returning.
    pub fn update_quota(
        &self,
        user: UserId,
        f: impl FnOnce(&mut TenantQuota),
    ) -> TenantQuota {
        let mut st = self.state.lock().unwrap();
        let mut quota = st.quotas.quota(user);
        f(&mut quota);
        st.quotas.set(user, quota);
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        quota
    }

    pub fn quota(&self, user: UserId) -> TenantQuota {
        self.state.lock().unwrap().quotas.quota(user)
    }

    /// vFPGA-equivalents the tenant currently holds via this
    /// scheduler.
    pub fn in_use(&self, user: UserId) -> u64 {
        self.state.lock().unwrap().quotas.in_use(user)
    }

    pub fn usage(&self, user: UserId) -> TenantUsage {
        self.state.lock().unwrap().ledger.usage(user)
    }

    // ------------------------------------------------- reservations

    /// Reserve `regions` vFPGAs for `user` over a virtual-time
    /// window. Expired windows are reclaimed lazily on admission.
    /// `regions` is clamped so the total booked over any overlapping
    /// window never exceeds the cluster's vFPGA capacity — a pile of
    /// reservations cannot overbook and wedge all admissions (an
    /// over-ask may thus yield a smaller, even zero-region,
    /// reservation; duration is operator-policed — the RPC surface
    /// has no authentication layer to gate it on).
    pub fn reserve(
        &self,
        user: UserId,
        regions: u64,
        start: VirtualTime,
        duration: VirtualTime,
    ) -> ReservationId {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        let already = st
            .reservations
            .reserved_overlapping(start.0, (start + duration).0);
        let regions =
            regions.min(self.total_regions.saturating_sub(already));
        st.reservations.reserve(user, regions, start, duration)
    }

    pub fn cancel_reservation(
        &self,
        id: ReservationId,
    ) -> Result<(), SchedError> {
        let mut st = self.state.lock().unwrap();
        if !st.reservations.cancel(id) {
            return Err(SchedError::UnknownReservation(id));
        }
        // Freed capacity may admit queued work.
        self.pump_locked(&mut st);
        self.granted.notify_all();
        Ok(())
    }

    // --------------------------------------------------- admissions

    /// Non-blocking admission — the interactive fast path. Fails with
    /// [`SchedError::NoCapacity`] rather than queueing; interactive
    /// requests may preempt a batch lease by migration first.
    pub fn acquire_vfpga(
        &self,
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> Result<SchedGrant, SchedError> {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        // Capacity reclaimed since the last pump (reservation expiry,
        // out-of-band release) belongs to queued strictly-higher-class
        // requests before this caller's immediate attempt — classes
        // are strict at every admission decision.
        if st.queue.has_class_above(class) {
            self.pump_locked(&mut st);
        }
        let result = self.try_admit_locked(
            &mut st,
            user,
            model,
            class,
            class == RequestClass::Interactive,
        );
        // Reservation expiry (or a preemption) may have freed
        // capacity queued work can use — pump before returning.
        self.pump_locked(&mut st);
        // Grants and preemption-downtime charges count against
        // budgets, so they must reach the state file too — not just
        // releases and quota updates.
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        result
    }

    /// Blocking admission: take the fast path when nothing of equal
    /// or higher class is queued, otherwise join the queue and wait
    /// for the fair-share pump.
    pub fn acquire_vfpga_blocking(
        &self,
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> Result<SchedGrant, SchedError> {
        let ticket = {
            let mut st = self.state.lock().unwrap();
            self.reap_locked(&mut st);
            if !st.queue.has_class_at_or_above(class) {
                match self.try_admit_locked(
                    &mut st,
                    user,
                    model,
                    class,
                    class == RequestClass::Interactive,
                ) {
                    Ok(grant) => return Ok(grant),
                    Err(SchedError::NoCapacity)
                    | Err(SchedError::QuotaConcurrency(_)) => {}
                    Err(e) => return Err(e),
                }
            }
            self.enqueue_locked(&mut st, user, model, class)
        };
        self.wait(ticket)
    }

    /// Enqueue without waiting; pair with [`Scheduler::wait`] or
    /// [`Scheduler::try_claim`].
    pub fn submit(
        &self,
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> TicketId {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        self.enqueue_locked(&mut st, user, model, class)
    }

    fn enqueue_locked(
        &self,
        st: &mut SchedState,
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> TicketId {
        let now_ns = self.hv.clock.now().0;
        let ticket = st.queue.push(user, model, class, now_ns);
        // A model no device serves can never be admitted — fail the
        // ticket terminally instead of queueing it forever.
        if !self
            .devices
            .iter()
            .any(|(_, models)| models.contains(&model))
        {
            st.queue.remove(ticket);
            st.ready.insert(
                ticket,
                Err(SchedError::Hypervisor(format!(
                    "no device serves model '{}'",
                    model.name()
                ))),
            );
            self.granted.notify_all();
            return ticket;
        }
        st.ledger.row_mut(user).queued += 1;
        self.hv.metrics.counter("sched.enqueued").inc();
        // Capacity may already be free (e.g. first submission).
        self.pump_locked(st);
        self.granted.notify_all();
        ticket
    }

    /// Block until the ticket resolves.
    ///
    /// Wakes on this scheduler's own pump; in-instance progress never
    /// waits on the tick. A half-second fallback tick additionally
    /// re-pumps so capacity freed *outside* this scheduler instance
    /// (a direct `Hypervisor::release`, or a sibling scheduler over
    /// the same hypervisor) is still picked up instead of blocking
    /// forever.
    pub fn wait(&self, ticket: TicketId) -> Result<SchedGrant, SchedError> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(result) = st.ready.remove(&ticket) {
                return result;
            }
            let (guard, timeout) = self
                .granted
                .wait_timeout(st, std::time::Duration::from_millis(500))
                .unwrap();
            st = guard;
            if timeout.timed_out() {
                self.pump_locked(&mut st);
                // The pump may have resolved *other* waiters' tickets.
                self.granted.notify_all();
            }
        }
    }

    /// Non-blocking poll of a submitted ticket.
    pub fn try_claim(
        &self,
        ticket: TicketId,
    ) -> Option<Result<SchedGrant, SchedError>> {
        self.state.lock().unwrap().ready.remove(&ticket)
    }

    /// Cancel a still-queued ticket. Returns false when the ticket
    /// already left the queue (granted, failed, or never existed) —
    /// the caller must then collect it via `wait`/`try_claim`.
    pub fn cancel(&self, ticket: TicketId) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.queue.remove(ticket).is_some() {
            st.ready.insert(ticket, Err(SchedError::Cancelled));
            self.update_gauges_locked(&st);
            self.granted.notify_all();
            true
        } else {
            false
        }
    }

    /// Exclusive physical-device admission (RSaaS / VM passthrough).
    /// Never queues; counts [`PHYSICAL_EQUIV_UNITS`] against the
    /// concurrency quota. Physical capacity is not *reservable*, but
    /// taking a whole device removes its regions from the vFPGA pool,
    /// so admission is denied when that would leave other tenants'
    /// active reservations uncoverable.
    pub fn acquire_physical(
        &self,
        user: UserId,
        vm: Option<VmId>,
        class: RequestClass,
    ) -> Result<SchedGrant, SchedError> {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        // As in acquire_vfpga: queued higher-class requests get first
        // claim on capacity reclaimed since the last pump.
        if st.queue.has_class_above(class) {
            self.pump_locked(&mut st);
        }
        let used_s = used_device_seconds(
            &st.ledger,
            &st.grants,
            user,
            self.hv.clock.now().0,
        );
        if let Err(d) =
            st.quotas.admissible(user, PHYSICAL_EQUIV_UNITS, used_s)
        {
            return Err(self.deny(d));
        }
        // An exclusive lease removes a whole device's regions from
        // the vFPGA pool; keep enough free regions to cover other
        // tenants' active reservations (conservatively assuming the
        // largest possible device).
        let withheld = st
            .reservations
            .withheld_from(user, self.hv.clock.now().0);
        if withheld > 0 {
            let total_free: u64 = {
                let db = self.hv.db.lock().unwrap();
                self.devices
                    .iter()
                    .map(|(f, _)| db.free_regions(*f).len() as u64)
                    .sum()
            };
            if total_free.saturating_sub(crate::paper::MAX_VFPGAS as u64)
                < withheld
            {
                return Err(SchedError::NoCapacity);
            }
        }
        let (alloc, fpga, node) = self
            .hv
            .alloc_physical(user, vm)
            .map_err(SchedError::from)?;
        // charge_w is *per unit*; spread the whole-board static draw
        // over the device's vFPGA-equivalents so release() bills
        // units x charge_w = one board's worth.
        let charge_w = self
            .hv
            .device(fpga)
            .map(|d| d.fpga.lock().unwrap().board.static_power_w)
            .unwrap_or(0.0)
            / PHYSICAL_EQUIV_UNITS as f64;
        let grant = SchedGrant {
            alloc,
            user,
            model: ServiceModel::RSaaS,
            class,
            target: GrantTarget::Physical(fpga, node),
            units: PHYSICAL_EQUIV_UNITS,
            started_ns: self.hv.clock.now().0,
            wait: VirtualTime::ZERO,
            charge_w,
            from_reservation: None,
        };
        self.finish_grant_locked(&mut st, grant.clone());
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        Ok(grant)
    }

    /// Release a scheduler-tracked allocation: returns the lease to
    /// the hypervisor, charges the usage ledger, credits the quota
    /// and pumps the admission queue.
    pub fn release(&self, alloc: AllocationId) -> Result<(), SchedError> {
        // Everything happens under the state lock (the scheduler's
        // lock order is always state → hypervisor, same as the pump
        // and preemption paths), so no concurrent acquire can observe
        // the freed region with the quota still charged or vice
        // versa.
        let mut st = self.state.lock().unwrap();
        let grant = st
            .grants
            .remove(&alloc)
            .ok_or(SchedError::UnknownGrant(alloc))?;
        // Hypervisor::release removes the DB allocation before its
        // fallible device cleanup, so after an error the lease is
        // gone either way (removed now, or it never existed).
        // Bookkeeping must still run — restoring the grant would
        // leak the tenant's quota units forever — and the device
        // error is reported after the credit.
        let release_result = self.hv.release(alloc);
        let now = self.hv.clock.now();
        let held =
            VirtualTime(now.0.saturating_sub(grant.started_ns)).as_secs_f64();
        st.ledger.charge_release(
            grant.user,
            held * grant.units as f64,
            grant.charge_w,
        );
        st.quotas.credit(grant.user, grant.units);
        if let Some(reservation) = grant.from_reservation {
            // The reservation guarantees concurrent regions — return
            // the claim now that the lease is gone (no-op if the
            // window already expired).
            st.reservations.release_claim(reservation);
        }
        self.hv.metrics.counter("sched.released").inc();
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        release_result.map_err(|e| SchedError::Hypervisor(e.to_string()))
    }

    /// Record an out-of-band migration (e.g. the middleware `migrate`
    /// RPC calling the hypervisor directly) so the tracked grant's
    /// target stays accurate for victim selection and status.
    pub fn note_migration(&self, alloc: AllocationId, to: VfpgaId) {
        let mut st = self.state.lock().unwrap();
        self.rebind_grant_locked(&mut st, alloc, to);
    }

    /// Point a tracked grant at the region its lease now occupies.
    fn rebind_grant_locked(
        &self,
        st: &mut SchedState,
        alloc: AllocationId,
        to: VfpgaId,
    ) {
        let new_home = {
            let db = self.hv.db.lock().unwrap();
            db.device_of_vfpga(to).map(|d| (d.id, d.node))
        };
        if let Some((fpga, node)) = new_home {
            if let Some(grant) = st.grants.get_mut(&alloc) {
                grant.target = GrantTarget::Vfpga(to, fpga, node);
            }
        }
    }

    /// Live grants (status surface + tests).
    pub fn active_grants(&self) -> Vec<SchedGrant> {
        self.state.lock().unwrap().grants.values().cloned().collect()
    }

    // ----------------------------------------------- internal logic

    /// Map a quota denial to its error, bumping the denial counter.
    fn deny(&self, d: QuotaDenial) -> SchedError {
        self.hv.metrics.counter("sched.quota.denied").inc();
        match d {
            QuotaDenial::Budget { .. } => {
                SchedError::QuotaBudget(d.to_string())
            }
            QuotaDenial::Concurrency { .. } => {
                SchedError::QuotaConcurrency(d.to_string())
            }
        }
    }

    fn reap_locked(&self, st: &mut SchedState) {
        let expired = st.reservations.reap(self.hv.clock.now().0);
        if expired > 0 {
            self.hv
                .metrics
                .counter("sched.reservations.expired")
                .add(expired as u64);
        }
    }

    /// One immediate admission attempt under the state lock.
    fn try_admit_locked(
        &self,
        st: &mut SchedState,
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
        allow_preempt: bool,
    ) -> Result<SchedGrant, SchedError> {
        let now_ns = self.hv.clock.now().0;
        let used_s = used_device_seconds(&st.ledger, &st.grants, user, now_ns);
        if let Err(d) = st.quotas.admissible(user, 1, used_s) {
            return Err(self.deny(d));
        }
        if free_units(&self.hv, &self.devices, &st.reservations, user, model, now_ns)
            == 0
        {
            // Preemption only helps when the model's devices are
            // *physically* full AND no active reservation would
            // swallow the vacated region. Otherwise migrating a
            // victim is futile downtime: either free-but-reserved
            // regions already exist, or the one region a preemption
            // frees is owed to a reservation holder.
            if raw_free_units(&self.hv, &self.devices, model) > 0
                || st.reservations.withheld_from(user, now_ns) > 0
            {
                return Err(SchedError::NoCapacity);
            }
            if !(allow_preempt
                && self.try_preempt_locked(st, user, model, class))
            {
                return Err(SchedError::NoCapacity);
            }
            // A migration relocates a victim but cannot conjure
            // capacity out of another tenant's reserved headroom: the
            // vacated region only counts if the post-preemption free
            // total still covers every active reservation.
            if free_units(
                &self.hv,
                &self.devices,
                &st.reservations,
                user,
                model,
                now_ns,
            ) == 0
            {
                return Err(SchedError::NoCapacity);
            }
        }
        match self.hv.alloc_vfpga(user, model) {
            Ok((alloc, vfpga, fpga, node)) => Ok(self.grant_vfpga_locked(
                st, user, model, class, alloc, vfpga, fpga, node, None,
            )),
            Err(HypervisorError::NoCapacity) => Err(SchedError::NoCapacity),
            Err(e) => Err(SchedError::Hypervisor(e.to_string())),
        }
    }

    /// Record a fresh vFPGA grant. `enqueued_ns` is set for requests
    /// that came through the queue (wait-time accounting).
    #[allow(clippy::too_many_arguments)]
    fn grant_vfpga_locked(
        &self,
        st: &mut SchedState,
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
        alloc: AllocationId,
        vfpga: VfpgaId,
        fpga: FpgaId,
        node: NodeId,
        enqueued_ns: Option<u64>,
    ) -> SchedGrant {
        let now_ns = self.hv.clock.now().0;
        let wait = VirtualTime(
            now_ns.saturating_sub(enqueued_ns.unwrap_or(now_ns)),
        );
        let charge_w = self
            .hv
            .device(fpga)
            .map(|d| d.fpga.lock().unwrap().board.active_region_power_w)
            .unwrap_or(0.0);
        // Draw on the tenant's reservation only when this admission
        // actually needed reserved headroom: with enough unreserved
        // free capacity left (pre-alloc free = post-alloc + 1), the
        // grant came out of the general pool and the guarantee stays
        // intact for the real burst.
        let raw_free_after = raw_free_units(&self.hv, &self.devices, model);
        let from_reservation =
            if raw_free_after + 1 <= st.reservations.withheld_total(now_ns) {
                st.reservations.consume(user, now_ns)
            } else {
                None
            };
        let grant = SchedGrant {
            alloc,
            user,
            model,
            class,
            target: GrantTarget::Vfpga(vfpga, fpga, node),
            units: 1,
            started_ns: now_ns,
            wait,
            charge_w,
            from_reservation,
        };
        // Histogram stats render in microseconds; keep the name
        // unit-free so `rc3e stats` reads correctly.
        self.hv
            .metrics
            .histogram("sched.wait")
            .record_us((wait.as_millis_f64() * 1e3) as u64);
        let row = st.ledger.row_mut(user);
        row.max_wait_ms = row.max_wait_ms.max(wait.as_millis_f64());
        self.finish_grant_locked(st, grant.clone());
        grant
    }

    fn finish_grant_locked(&self, st: &mut SchedState, grant: SchedGrant) {
        st.quotas.charge(grant.user, grant.units);
        st.ledger.row_mut(grant.user).granted += 1;
        st.grants.insert(grant.alloc, grant);
        self.hv.metrics.counter("sched.granted").inc();
        self.update_gauges_locked(st);
    }

    /// Relocate the best lower-class victim via migration so a region
    /// on a device serving `model` frees up. Returns true on success.
    ///
    /// Cost model: the migration downtime is billed to `preemptor`'s
    /// tenant ([`UsageLedger::charge_preemption`]), and the victim's
    /// accrual clock is advanced past the outage so the displaced
    /// tenant is not charged for time it could not use.
    fn try_preempt_locked(
        &self,
        st: &mut SchedState,
        preemptor: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> bool {
        let candidates: Vec<VictimInfo> = st
            .grants
            .values()
            .filter(|g| g.class < class)
            .filter_map(|g| match g.target {
                GrantTarget::Vfpga(v, f, _) => {
                    let serves = self
                        .devices
                        .iter()
                        .any(|(id, models)| *id == f && models.contains(&model));
                    if serves {
                        Some(VictimInfo {
                            alloc: g.alloc,
                            user: g.user,
                            class: g.class,
                            model: g.model,
                            vfpga: v,
                            fpga: f,
                            started_ns: g.started_ns,
                        })
                    } else {
                        None
                    }
                }
                GrantTarget::Physical(_, _) => None,
            })
            .collect();
        for victim in victim_order(&candidates) {
            // Pick the migration target ourselves: a free region on a
            // *different* device that serves the victim's own model.
            // The hypervisor's default selection is model-aware but
            // falls back to a same-device move, which frees nothing
            // net — useless for preemption.
            let target = {
                let db = self.hv.db.lock().unwrap();
                self.devices
                    .iter()
                    .filter(|(f, models)| {
                        *f != victim.fpga && models.contains(&victim.model)
                    })
                    .find_map(|(f, _)| db.free_regions(*f).first().copied())
            };
            let Some(target) = target else { continue };
            match self
                .hv
                .migrate_vfpga(victim.alloc, victim.user, Some(target))
            {
                Ok(report) => {
                    self.rebind_grant_locked(st, victim.alloc, report.to);
                    // Charge the outage to the preemptor, skip the
                    // victim's accrual clock over it (migrate_vfpga
                    // advanced the virtual clock by the downtime, so
                    // the victim's lease would otherwise be billed
                    // for time it was dark).
                    let now_ns = self.hv.clock.now().0;
                    let mut victim_rate_w = 0.0;
                    let mut victim_units = 1u64;
                    if let Some(g) = st.grants.get_mut(&victim.alloc) {
                        g.started_ns = g
                            .started_ns
                            .saturating_add(report.downtime.0)
                            .min(now_ns);
                        victim_rate_w = g.charge_w;
                        victim_units = g.units;
                    }
                    st.ledger.charge_preemption(
                        preemptor,
                        report.downtime.as_secs_f64()
                            * victim_units as f64,
                        victim_rate_w,
                    );
                    st.ledger.row_mut(victim.user).preempted += 1;
                    self.hv.metrics.counter("sched.preemptions").inc();
                    log::info!(
                        "preempted {} ({} -> {}) for an incoming {} request",
                        victim.alloc,
                        report.from,
                        report.to,
                        class.name()
                    );
                    return true;
                }
                Err(e) => {
                    log::debug!(
                        "preemption candidate {} not movable: {e}",
                        victim.alloc
                    );
                }
            }
        }
        false
    }

    /// Grant queued requests while capacity and quotas allow,
    /// fair-share order. Tenants at quota are skipped; budget-
    /// exhausted requests fail terminally.
    fn pump_locked(&self, st: &mut SchedState) {
        self.reap_locked(st);
        // Budget exhaustion never recovers: fail those tickets now.
        // (Skipped entirely while no tenant has a budget configured —
        // the common case.)
        if st.quotas.has_budgets() {
            let scan_now_ns = self.hv.clock.now().0;
            let terminal: Vec<(TicketId, QuotaDenial)> = st
                .queue
                .snapshot()
                .into_iter()
                .filter_map(|e| {
                    match st.quotas.admissible(
                        e.user,
                        1,
                        used_device_seconds(
                            &st.ledger,
                            &st.grants,
                            e.user,
                            scan_now_ns,
                        ),
                    ) {
                        Err(d @ QuotaDenial::Budget { .. }) => {
                            Some((e.ticket, d))
                        }
                        _ => None,
                    }
                })
                .collect();
            for (ticket, denial) in terminal {
                st.queue.remove(ticket);
                st.ready.insert(ticket, Err(self.deny(denial)));
            }
        }
        loop {
            let now_ns = self.hv.clock.now().0;
            // Snapshot physical free counts once per iteration (they
            // only change when a grant lands) so the pop predicate
            // does not lock the device DB per queued entry.
            let free_by_device: Vec<u64> = {
                let db = self.hv.db.lock().unwrap();
                self.devices
                    .iter()
                    .map(|(f, _)| db.free_regions(*f).len() as u64)
                    .collect()
            };
            let popped = {
                let SchedState {
                    queue,
                    quotas,
                    reservations,
                    ledger,
                    grants,
                    ..
                } = st;
                let quotas_ro: &QuotaBook = quotas;
                let reservations_ro: &ReservationBook = reservations;
                let ledger_ro: &UsageLedger = ledger;
                let grants_ro: &BTreeMap<AllocationId, SchedGrant> = grants;
                let devices = &self.devices;
                let free_for = |user: UserId, model: ServiceModel| -> u64 {
                    let mut free = 0u64;
                    for (i, (_, models)) in devices.iter().enumerate() {
                        if models.contains(&model) {
                            free += free_by_device[i];
                        }
                    }
                    free.saturating_sub(
                        reservations_ro.withheld_from(user, now_ns),
                    )
                };
                queue.pop_best(
                    |u| quotas_ro.weight(u),
                    |e| {
                        quotas_ro
                            .admissible(
                                e.user,
                                1,
                                used_device_seconds(
                                    ledger_ro, grants_ro, e.user, now_ns,
                                ),
                            )
                            .is_ok()
                            && free_for(e.user, e.model) > 0
                    },
                )
            };
            let Some(entry) = popped else {
                // Nothing admits into free capacity — but a queued
                // interactive request may still land by preempting a
                // batch lease, exactly like the fast path does.
                if self.pump_preempt_locked(st) {
                    continue;
                }
                break;
            };
            match self.hv.alloc_vfpga(entry.user, entry.model) {
                Ok((alloc, vfpga, fpga, node)) => {
                    let grant = self.grant_vfpga_locked(
                        st,
                        entry.user,
                        entry.model,
                        entry.class,
                        alloc,
                        vfpga,
                        fpga,
                        node,
                        Some(entry.enqueued_ns),
                    );
                    st.ready.insert(entry.ticket, Ok(grant));
                }
                Err(HypervisorError::NoCapacity) => {
                    // Raced with an out-of-band allocation: put the
                    // entry back unchanged (refunding the fair-share
                    // pass charge pop_best took) and stop pumping.
                    let weight = st.quotas.weight(entry.user);
                    st.queue.refund(entry.user, weight);
                    st.queue.requeue(entry);
                    break;
                }
                Err(e) => {
                    // Terminal failure: refund the fair-share charge
                    // (the tenant received nothing) and fail the
                    // ticket.
                    let weight = st.quotas.weight(entry.user);
                    st.queue.refund(entry.user, weight);
                    st.ready.insert(
                        entry.ticket,
                        Err(SchedError::Hypervisor(e.to_string())),
                    );
                }
            }
        }
        self.update_gauges_locked(st);
    }

    /// Preempt on behalf of the first queued interactive request
    /// whose tenant quota admits and whose model's devices are
    /// physically full. Returns true when a victim was relocated (the
    /// pump loop then re-runs and the interactive entry wins the pop
    /// by class).
    fn pump_preempt_locked(&self, st: &mut SchedState) -> bool {
        let now_ns = self.hv.clock.now().0;
        let mut candidates: Vec<QueueEntry> = st
            .queue
            .snapshot()
            .into_iter()
            .filter(|e| e.class == RequestClass::Interactive)
            .filter(|e| {
                st.quotas
                    .admissible(
                        e.user,
                        1,
                        used_device_seconds(
                            &st.ledger,
                            &st.grants,
                            e.user,
                            now_ns,
                        ),
                    )
                    .is_ok()
            })
            .collect();
        candidates.sort_by_key(|e| e.seq);
        for entry in candidates {
            if raw_free_units(&self.hv, &self.devices, entry.model) > 0
                || st.reservations.withheld_from(entry.user, now_ns) > 0
            {
                // Capacity exists but is reservation-withheld, or the
                // vacated region would be owed to a reservation
                // holder; migrating a victim cannot help this entry
                // (see try_admit_locked) — but a later queued
                // interactive entry for another model still might.
                continue;
            }
            if self.try_preempt_locked(
                st,
                entry.user,
                entry.model,
                entry.class,
            ) {
                return true;
            }
        }
        false
    }

    fn update_gauges_locked(&self, st: &SchedState) {
        self.hv
            .metrics
            .gauge("sched.queue.depth")
            .set(st.queue.len() as i64);
        self.hv
            .metrics
            .gauge("sched.active_grants")
            .set(st.grants.len() as i64);
    }

    // ------------------------------------------------------- status

    /// Queue/quota/reservation snapshot for the `sched_status` RPC.
    pub fn status_json(&self) -> Json {
        let now_ns = self.hv.clock.now().0;
        let st = self.state.lock().unwrap();
        let entries = st.queue.snapshot();
        let per_class = |c: RequestClass| {
            entries.iter().filter(|e| e.class == c).count()
        };
        let mut tenants: BTreeMap<UserId, u64> = BTreeMap::new();
        for e in &entries {
            *tenants.entry(e.user).or_insert(0) += 1;
        }
        Json::obj(vec![
            ("queue_depth", Json::from(entries.len())),
            (
                "queued_interactive",
                Json::from(per_class(RequestClass::Interactive)),
            ),
            (
                "queued_normal",
                Json::from(per_class(RequestClass::Normal)),
            ),
            ("queued_batch", Json::from(per_class(RequestClass::Batch))),
            ("active_grants", Json::from(st.grants.len())),
            (
                "queued_by_tenant",
                Json::Obj(
                    tenants
                        .iter()
                        .map(|(u, n)| (u.to_string(), Json::from(*n)))
                        .collect(),
                ),
            ),
            (
                "reservations",
                Json::Arr(
                    st.reservations
                        .snapshot(now_ns)
                        .into_iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::from(r.id.to_string())),
                                ("user", Json::from(r.user.to_string())),
                                ("regions", Json::from(r.regions)),
                                ("claimed", Json::from(r.claimed)),
                                (
                                    "start_s",
                                    Json::from(
                                        VirtualTime(r.start_ns)
                                            .as_secs_f64(),
                                    ),
                                ),
                                (
                                    "end_s",
                                    Json::from(
                                        VirtualTime(r.end_ns).as_secs_f64(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Operator usage table (CLI `rc3e usage`).
    pub fn usage_report(&self) -> String {
        let names: BTreeMap<UserId, String> = {
            let db = self.hv.db.lock().unwrap();
            db.users
                .iter()
                .map(|(id, name)| (*id, name.clone()))
                .collect()
        };
        self.state.lock().unwrap().ledger.report(&names)
    }

    /// Usage rows for the `usage_report` RPC.
    pub fn usage_json(&self) -> Json {
        self.state.lock().unwrap().ledger.to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::hypervisor::PlacementPolicy;
    use crate::util::clock::VirtualClock;

    fn sched() -> Arc<Scheduler> {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        Scheduler::new(hv)
    }

    fn sched_on(config: &ClusterConfig) -> Arc<Scheduler> {
        let hv = Arc::new(
            Hypervisor::boot(
                config,
                VirtualClock::new(),
                PlacementPolicy::ConsolidateFirst,
            )
            .unwrap(),
        );
        Scheduler::new(hv)
    }

    #[test]
    fn acquire_and_release_roundtrip() {
        let s = sched();
        let user = s.hv().add_user("alice");
        let g = s
            .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Interactive)
            .unwrap();
        assert_eq!(s.in_use(user), 1);
        assert!(g.vfpga().is_some());
        s.release(g.alloc).unwrap();
        assert_eq!(s.in_use(user), 0);
        assert_eq!(s.usage(user).released, 1);
        assert!(s.usage(user).device_seconds >= 0.0);
        // Releasing twice is an UnknownGrant error.
        assert!(matches!(
            s.release(g.alloc),
            Err(SchedError::UnknownGrant(_))
        ));
    }

    #[test]
    fn concurrency_quota_blocks_fast_path() {
        let s = sched();
        let user = s.hv().add_user("bounded");
        s.set_quota(
            user,
            TenantQuota {
                max_concurrent: 2,
                ..TenantQuota::default()
            },
        );
        let g0 = s
            .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal)
            .unwrap();
        let _g1 = s
            .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal)
            .unwrap();
        assert!(matches!(
            s.acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal),
            Err(SchedError::QuotaConcurrency(_))
        ));
        s.release(g0.alloc).unwrap();
        assert!(s
            .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal)
            .is_ok());
    }

    #[test]
    fn budget_quota_is_terminal() {
        let s = sched();
        let user = s.hv().add_user("broke");
        s.set_quota(
            user,
            TenantQuota {
                device_seconds_budget: Some(10.0),
                ..TenantQuota::default()
            },
        );
        let g = s
            .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal)
            .unwrap();
        // Hold the lease for 60 virtual seconds — way over budget.
        s.hv().clock.advance(VirtualTime::from_secs_f64(60.0));
        s.release(g.alloc).unwrap();
        assert!(s.usage(user).device_seconds > 10.0);
        assert!(matches!(
            s.acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal),
            Err(SchedError::QuotaBudget(_))
        ));
    }

    #[test]
    fn queue_grants_on_release_in_fair_order() {
        let s = sched();
        let users: Vec<UserId> =
            (0..4).map(|i| s.hv().add_user(&format!("u{i}"))).collect();
        // Fill all 16 regions with user 0.
        let mut held = Vec::new();
        for _ in 0..16 {
            held.push(
                s.acquire_vfpga(
                    users[0],
                    ServiceModel::RAaaS,
                    RequestClass::Normal,
                )
                .unwrap(),
            );
        }
        // Queue one request per other tenant.
        let tickets: Vec<TicketId> = users[1..]
            .iter()
            .map(|u| s.submit(*u, ServiceModel::RAaaS, RequestClass::Batch))
            .collect();
        assert!(s.try_claim(tickets[0]).is_none());
        // Three releases admit all three queued tenants.
        for g in held.drain(..3) {
            s.release(g.alloc).unwrap();
        }
        for t in &tickets {
            let res = s.try_claim(*t).expect("granted after release");
            assert!(res.is_ok());
        }
    }

    #[test]
    fn interactive_preempts_batch_via_migration() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let batcher = s.hv().add_user("batcher");
        let vip = s.hv().add_user("vip");
        // Fill the RAaaS-capable device (fpga-0, consolidate-first
        // packs it first) with programmed batch leases; the BAaaS-only
        // device keeps free regions.
        let batch_grants = crate::testing::fill_batch_leases(&s, batcher, 4);
        // All four batch leases landed on the RAaaS-capable device.
        assert!(batch_grants
            .iter()
            .all(|g| g.fpga() == crate::util::ids::FpgaId(0)));
        // An interactive RAaaS request has no free RAaaS region —
        // without preemption this is NoCapacity.
        assert!(matches!(
            s.acquire_vfpga(vip, ServiceModel::RAaaS, RequestClass::Batch),
            Err(SchedError::NoCapacity)
        ));
        // Interactive class preempts: one batch lease migrates to the
        // BAaaS-only device and the vip lands on fpga-0.
        let g = s
            .acquire_vfpga(vip, ServiceModel::RAaaS, RequestClass::Interactive)
            .unwrap();
        assert_eq!(g.fpga(), crate::util::ids::FpgaId(0));
        assert_eq!(
            s.hv().metrics.counter("sched.preemptions").get(),
            1
        );
        assert_eq!(s.usage(batcher).preempted, 1);
        // The victim's grant now points at the other device and is
        // still releasable.
        let moved = s
            .active_grants()
            .into_iter()
            .filter(|g| g.user == batcher)
            .find(|g| g.fpga() != crate::util::ids::FpgaId(0))
            .expect("one batch lease migrated");
        s.release(moved.alloc).unwrap();
    }

    #[test]
    fn preemption_downtime_charged_to_preemptor() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let batcher = s.hv().add_user("batcher");
        let vip = s.hv().add_user("vip");
        // Fill the RAaaS-capable device with programmed batch leases
        // so the vip's interactive request must preempt.
        let _grants = crate::testing::fill_batch_leases(&s, batcher, 4);
        let _g = s
            .acquire_vfpga(vip, ServiceModel::RAaaS, RequestClass::Interactive)
            .unwrap();
        // The migration outage lands on the preemptor's bill...
        let vip_row = s.usage(vip);
        assert!(
            vip_row.preempt_downtime_s > 0.0,
            "preemptor not charged: {vip_row:?}"
        );
        assert!(
            vip_row.device_seconds >= vip_row.preempt_downtime_s
        );
        assert!(vip_row.energy_joules > 0.0);
        // ...and not on the victim's.
        let batcher_row = s.usage(batcher);
        assert_eq!(batcher_row.preempted, 1);
        assert_eq!(batcher_row.preempt_downtime_s, 0.0);
        // The victim's accrual clock skipped the outage: its grant
        // now starts at (or after) the pre-preemption timestamps.
        let moved = s
            .active_grants()
            .into_iter()
            .filter(|g| g.user == batcher)
            .max_by_key(|g| g.started_ns)
            .unwrap();
        assert!(moved.started_ns <= s.hv().clock.now().0);
    }

    #[test]
    fn persistence_survives_restart() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e-sched-persist-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let db_path = dir.join("devices.json");
        let state_path = persist::sched_state_path(&db_path);
        let _ = std::fs::remove_file(&state_path);
        let user;
        {
            let s = sched();
            s.attach_persistence(&db_path).unwrap();
            user = s.hv().add_user("durable");
            s.set_quota(
                user,
                TenantQuota {
                    max_concurrent: 3,
                    device_seconds_budget: Some(500.0),
                    weight: 2,
                },
            );
            let g = s
                .acquire_vfpga(user, ServiceModel::RAaaS, RequestClass::Normal)
                .unwrap();
            s.hv().clock.advance(VirtualTime::from_secs_f64(5.0));
            s.release(g.alloc).unwrap();
        }
        assert!(state_path.exists());
        // "Restart": a fresh hypervisor + scheduler reload the
        // accounting from disk.
        let s2 = Scheduler::new_persistent(
            Arc::new(
                Hypervisor::boot_paper_testbed(VirtualClock::new())
                    .unwrap(),
            ),
            &db_path,
        )
        .unwrap();
        let q = s2.quota(user);
        assert_eq!(q.max_concurrent, 3);
        assert_eq!(q.device_seconds_budget, Some(500.0));
        assert_eq!(q.weight, 2);
        let usage = s2.usage(user);
        assert_eq!(usage.released, 1);
        assert!(usage.device_seconds >= 5.0, "{usage:?}");
        std::fs::remove_file(&state_path).unwrap();
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn reservation_withholds_capacity_until_expiry() {
        // Single device, 4 regions.
        let s = sched_on(&ClusterConfig::single_vc707());
        let holder = s.hv().add_user("holder");
        let other = s.hv().add_user("other");
        let now = s.hv().clock.now();
        s.reserve(
            holder,
            2,
            now,
            VirtualTime::from_secs_f64(100.0),
        );
        // Other tenant can only take the 2 unreserved regions.
        let _a = s
            .acquire_vfpga(other, ServiceModel::RAaaS, RequestClass::Normal)
            .unwrap();
        let _b = s
            .acquire_vfpga(other, ServiceModel::RAaaS, RequestClass::Normal)
            .unwrap();
        assert!(matches!(
            s.acquire_vfpga(other, ServiceModel::RAaaS, RequestClass::Normal),
            Err(SchedError::NoCapacity)
        ));
        // The holder draws from its reservation.
        let _h = s
            .acquire_vfpga(holder, ServiceModel::RAaaS, RequestClass::Normal)
            .unwrap();
        // Window expires: remaining reserved capacity is reclaimed.
        s.hv().clock.advance(VirtualTime::from_secs_f64(200.0));
        assert!(s
            .acquire_vfpga(other, ServiceModel::RAaaS, RequestClass::Normal)
            .is_ok());
        assert_eq!(
            s.hv().metrics.counter("sched.reservations.expired").get(),
            1
        );
    }

    #[test]
    fn blocking_acquire_waits_for_release() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        let b = s.hv().add_user("b");
        let mut held = Vec::new();
        for _ in 0..4 {
            held.push(
                s.acquire_vfpga(a, ServiceModel::RAaaS, RequestClass::Normal)
                    .unwrap(),
            );
        }
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || {
            s2.acquire_vfpga_blocking(
                b,
                ServiceModel::RAaaS,
                RequestClass::Batch,
            )
        });
        // Give the waiter time to enqueue, then free a region.
        while s.hv().metrics.counter("sched.enqueued").get() == 0 {
            std::thread::yield_now();
        }
        s.release(held.pop().unwrap().alloc).unwrap();
        let grant = waiter.join().unwrap().unwrap();
        assert_eq!(grant.user, b);
        s.release(grant.alloc).unwrap();
    }

    #[test]
    fn cancel_resolves_waiters() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        let b = s.hv().add_user("b");
        for _ in 0..4 {
            s.acquire_vfpga(a, ServiceModel::RAaaS, RequestClass::Normal)
                .unwrap();
        }
        let t = s.submit(b, ServiceModel::RAaaS, RequestClass::Batch);
        assert!(s.cancel(t));
        assert_eq!(s.wait(t), Err(SchedError::Cancelled));
        assert!(!s.cancel(t));
    }

    #[test]
    fn status_json_reports_queue_shape() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        for _ in 0..4 {
            s.acquire_vfpga(a, ServiceModel::RAaaS, RequestClass::Normal)
                .unwrap();
        }
        s.submit(a, ServiceModel::RAaaS, RequestClass::Batch);
        s.reserve(
            a,
            1,
            s.hv().clock.now(),
            VirtualTime::from_secs_f64(10.0),
        );
        let j = s.status_json();
        assert_eq!(j.get("queue_depth").as_u64(), Some(1));
        assert_eq!(j.get("queued_batch").as_u64(), Some(1));
        assert_eq!(j.get("active_grants").as_u64(), Some(4));
        assert_eq!(j.get("reservations").as_arr().unwrap().len(), 1);
        let report = s.usage_report();
        assert!(report.contains("tenant"), "{report}");
    }
}
