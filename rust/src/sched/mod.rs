//! The cluster scheduler — the single admission path for every
//! allocation in the system.
//!
//! The paper's resource manager (Section IV-B) only picks a slot for
//! a request that can be satisfied *right now*; under heavy
//! multi-tenant traffic that collapses into immediate `NoCapacity`
//! errors and ad-hoc retry loops. This subsystem puts a real
//! scheduler between the service façades and the hypervisor:
//!
//! * [`queue`] — priority admission queue with weighted fair-share
//!   across tenants (stride scheduling);
//! * [`quota`] — per-tenant admission control: max concurrent
//!   vFPGA-equivalents and lifetime device-second budgets;
//! * [`reservation`] — time-boxed capacity reservations with
//!   virtual-clock expiry reclamation (vFPGA capacity only;
//!   exclusive physical leases are not reservable);
//! * [`preempt`] — relocation of lower-class leases via
//!   [`crate::hypervisor::migration`] so interactive requests land on
//!   a full cluster. Quiesce-based: only victims whose region quiesce
//!   is immediately winnable are displaced (in-flight setup/stream
//!   pins are never raced), gang leases relocate atomically, and the
//!   landing spot follows a spread-vs-pack [`PreemptPolicy`] knob;
//! * [`accounting`] — per-tenant usage ledger charging device-seconds
//!   and energy (priced from the [`crate::fpga::power`] model).
//!
//! Everything above the hypervisor routes through [`Scheduler`] by
//! way of one typed entry point: an [`AdmissionRequest`] (tenant,
//! model, class, gang size, placement constraints, deadline) admitted
//! via [`Scheduler::admit`] / [`Scheduler::admit_blocking`] /
//! [`Scheduler::enqueue`] yields a capability [`Lease`] carrying an
//! unguessable [`LeaseToken`]. RSaaS/RAaaS/BAaaS façades
//! ([`crate::service`]), VM launches ([`crate::vm`]), the batch
//! system ([`crate::batch`]) and the middleware server's RPC surface
//! ([`crate::middleware::server`]) all allocate exclusively through
//! it. Gang requests (`regions > 1`) grant N regions atomically —
//! all-or-nothing, via deadlock-free two-phase reservation of
//! candidate regions in a fixed global order.
//!
//! Admission policy, in order:
//! 1. quota check — budget exhaustion is terminal, a concurrency cap
//!    queues the request until the tenant releases;
//! 2. capacity check — free regions on devices serving the requested
//!    model, minus capacity withheld by other tenants' active
//!    reservations;
//! 3. grant, or (interactive only) preempt a batch lease by
//!    migration, or queue (blocking path) / fail fast (interactive).
//!
//! Classes are strict (`Interactive > Normal > Batch`); within a
//! class tenants share capacity by quota weight. Queued requests of a
//! tenant sitting at its quota are skipped, not head-of-line
//! blockers, so no ready request starves.

pub mod accounting;
pub mod lease;
pub mod persist;
pub mod preempt;
pub mod queue;
pub mod quota;
pub mod reservation;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::config::ServiceModel;
use crate::fpga::board::BoardKind;
use crate::hypervisor::migration::MigrationReport;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::journal::{
    LeaseRecord, MemberRecord, RecoveredLive, SchedWal, WalRecord,
};
use crate::util::clock::VirtualTime;
use crate::util::ids::{
    AllocationId, FpgaId, LeaseToken, NodeId, ReservationId, TicketId,
    UserId, VfpgaId, VmId,
};
use crate::util::json::Json;
use crate::util::trace;

pub use accounting::{TenantUsage, UsageLedger};
pub use lease::{
    with_preemption_retry, AdmissionRequest, Constraints, Lease,
    MemberPlacement,
};
pub use persist::PersistedState;
pub use preempt::{
    choose_target, select_victim, victim_order, PreemptPolicy, VictimInfo,
};
pub use queue::{AdmissionQueue, QueueEntry, AGING_BOOST_GRANTS};
pub use quota::{QuotaBook, QuotaDenial, TenantQuota, PHYSICAL_EQUIV_UNITS};
pub use reservation::{Reservation, ReservationBook};

/// Request priority class. Strictly ordered: interactive beats
/// normal beats batch at every admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RequestClass {
    /// Long-running unattended work (batch system, BAaaS backfill) —
    /// preemptable.
    Batch,
    /// Default service traffic.
    Normal,
    /// Latency-sensitive user-facing requests; may preempt batch.
    Interactive,
}

impl RequestClass {
    pub fn name(self) -> &'static str {
        match self {
            RequestClass::Batch => "batch",
            RequestClass::Normal => "normal",
            RequestClass::Interactive => "interactive",
        }
    }

    pub fn parse(s: &str) -> Option<RequestClass> {
        match s.to_ascii_lowercase().as_str() {
            "batch" => Some(RequestClass::Batch),
            "normal" => Some(RequestClass::Normal),
            "interactive" => Some(RequestClass::Interactive),
            _ => None,
        }
    }

    /// One step up the strict class ladder (aging boost); saturates
    /// at interactive.
    pub fn promote(self) -> RequestClass {
        match self {
            RequestClass::Batch => RequestClass::Normal,
            _ => RequestClass::Interactive,
        }
    }
}

/// Scheduler errors.
#[derive(Debug, Clone, PartialEq, thiserror::Error)]
pub enum SchedError {
    #[error("no capacity for the request")]
    NoCapacity,
    #[error("quota: {0}")]
    QuotaBudget(String),
    #[error("quota: {0}")]
    QuotaConcurrency(String),
    #[error("hypervisor: {0}")]
    Hypervisor(String),
    #[error("no scheduler grant for {0}")]
    UnknownGrant(AllocationId),
    #[error("unknown or stale lease token")]
    UnknownLease,
    #[error("request unsatisfiable: {0}")]
    Unsatisfiable(String),
    #[error("request was cancelled")]
    Cancelled,
    #[error("unknown reservation {0}")]
    UnknownReservation(ReservationId),
}

impl From<HypervisorError> for SchedError {
    fn from(e: HypervisorError) -> SchedError {
        match e {
            HypervisorError::NoCapacity => SchedError::NoCapacity,
            other => SchedError::Hypervisor(other.to_string()),
        }
    }
}

impl From<SchedError> for HypervisorError {
    fn from(e: SchedError) -> HypervisorError {
        match e {
            SchedError::NoCapacity => HypervisorError::NoCapacity,
            other => HypervisorError::Sched(other.to_string()),
        }
    }
}

/// What a grant leases.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GrantTarget {
    Vfpga(VfpgaId, FpgaId, NodeId),
    Physical(FpgaId, NodeId),
}

/// An admitted allocation, as the scheduler tracks it.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedGrant {
    pub alloc: AllocationId,
    pub user: UserId,
    pub model: ServiceModel,
    pub class: RequestClass,
    pub target: GrantTarget,
    /// vFPGA-equivalents charged against quota and accounting.
    pub units: u64,
    /// Virtual timestamp of the grant.
    pub started_ns: u64,
    /// Virtual time spent in the admission queue (zero on fast path).
    pub wait: VirtualTime,
    /// Per-unit active power (W) for energy accounting.
    pub charge_w: f64,
    /// Reservation this admission drew a claim from, if any — the
    /// claim is credited back when the lease is released.
    pub from_reservation: Option<ReservationId>,
    /// Capability token of the lease this grant belongs to (gang
    /// members share one token).
    pub token: LeaseToken,
    /// Times this grant's region has been rebound by migration
    /// (preemptions + explicit moves) — the preemption-retry signal.
    pub migrations: u64,
}

impl SchedGrant {
    pub fn vfpga(&self) -> Option<VfpgaId> {
        match self.target {
            GrantTarget::Vfpga(v, _, _) => Some(v),
            GrantTarget::Physical(_, _) => None,
        }
    }

    pub fn fpga(&self) -> FpgaId {
        match self.target {
            GrantTarget::Vfpga(_, f, _) | GrantTarget::Physical(f, _) => f,
        }
    }

    pub fn node(&self) -> NodeId {
        match self.target {
            GrantTarget::Vfpga(_, _, n) | GrantTarget::Physical(_, n) => n,
        }
    }
}

/// Scheduler-side record of one lease (the [`Lease`] handle is a
/// re-materializable view over this).
#[derive(Debug, Clone)]
struct LeaseMeta {
    tenant: UserId,
    model: ServiceModel,
    class: RequestClass,
    /// Member allocations, primary first.
    members: Vec<AllocationId>,
    wait: VirtualTime,
    /// The admission's co-location constraint — relocation must
    /// preserve it (a scattered multi-core design is broken, not
    /// relocated).
    co_located: bool,
}

struct SchedState {
    queue: AdmissionQueue,
    quotas: QuotaBook,
    reservations: ReservationBook,
    ledger: UsageLedger,
    /// Live grants by allocation id (release + victim lookup).
    grants: BTreeMap<AllocationId, SchedGrant>,
    /// Live leases by capability token.
    leases: BTreeMap<LeaseToken, LeaseMeta>,
    /// Finished queue tickets awaiting collection by their waiter
    /// (tokens of granted leases, or the terminal error).
    ready: BTreeMap<TicketId, Result<LeaseToken, SchedError>>,
}

/// Static facts about one device, cached at boot (devices never
/// change after boot).
#[derive(Debug, Clone)]
struct DeviceInfo {
    fpga: FpgaId,
    models: Vec<ServiceModel>,
    board: BoardKind,
    /// Total vFPGA regions the device carves.
    regions: u64,
}

impl DeviceInfo {
    fn matches(&self, model: ServiceModel, board: Option<BoardKind>) -> bool {
        self.models.contains(&model)
            && board.map_or(true, |b| self.board == b)
    }
}

/// Normalized admission work item — what the fast path and the queue
/// pump both admit from ([`AdmissionRequest`] or a popped
/// [`QueueEntry`]).
struct AdmitSpec {
    tenant: UserId,
    model: ServiceModel,
    class: RequestClass,
    regions: u64,
    co_located: bool,
    board: Option<BoardKind>,
    vm: Option<VmId>,
    /// Set for requests that came through the queue (wait-time
    /// accounting).
    enqueued_ns: Option<u64>,
    allow_preempt: bool,
    /// Mint the lease under this pre-existing token instead of a
    /// fresh one — the federation re-admission path, where a lease
    /// re-homed from a dead node must keep the capability token its
    /// holder already carries.
    adopt: Option<LeaseToken>,
}

impl AdmitSpec {
    fn of_request(req: &AdmissionRequest, allow_preempt: bool) -> AdmitSpec {
        AdmitSpec {
            tenant: req.tenant,
            model: req.model,
            class: req.class,
            regions: u64::from(req.regions.get()),
            co_located: req.constraints.co_located,
            board: req.constraints.board,
            vm: req.constraints.vm,
            enqueued_ns: None,
            allow_preempt,
            adopt: None,
        }
    }

    fn of_entry(entry: &QueueEntry) -> AdmitSpec {
        AdmitSpec {
            tenant: entry.user,
            model: entry.model,
            class: entry.class,
            regions: entry.regions,
            co_located: entry.co_located,
            board: entry.board,
            vm: None,
            enqueued_ns: Some(entry.enqueued_ns),
            allow_preempt: false,
            adopt: None,
        }
    }
}

/// The cluster scheduler.
///
/// One instance should front each hypervisor: the convenience
/// constructors (`RaaasService::new`, `BatchSystem::new`, …) each
/// build a private scheduler, which is fine in isolation, but when
/// several façades share one hypervisor they should share one
/// scheduler (`with_scheduler`) so quotas, fair-share and the
/// admission queue see all traffic. Blocking admissions still make
/// progress across independent instances (the wait loop re-pumps on
/// a wall-clock tick), but quotas and fairness are per-instance.
pub struct Scheduler {
    hv: Arc<Hypervisor>,
    /// Static device topology, cached at construction — devices never
    /// change after boot.
    devices: Vec<DeviceInfo>,
    /// Total vFPGA regions across the cluster (reservation clamp).
    total_regions: u64,
    state: Mutex<SchedState>,
    granted: Condvar,
    /// Where quota + ledger state persists (set by
    /// [`Scheduler::attach_persistence`]); `None` = in-memory only.
    /// Lock order: `state` before `persist_path`.
    persist_path: Mutex<Option<PathBuf>>,
    /// Write-ahead log for grant/queue/quota mutations (set by
    /// [`Scheduler::attach_persistence`]); `None` = in-memory only.
    /// Lock order: `state` before `wal` — records are appended while
    /// the state lock is held, so WAL order equals application order.
    wal: Mutex<Option<Arc<SchedWal>>>,
    /// Monotonic snapshot counter, assigned under the state lock so
    /// sequence order matches snapshot order.
    persist_seq: AtomicU64,
    /// Sequence of the newest snapshot already on disk — file writes
    /// happen after the state lock is dropped, so without this guard
    /// two concurrent writers could land out of order and persist a
    /// stale snapshot last.
    persist_written: Mutex<u64>,
    /// Where preemption relocates victims (spread-vs-pack knob).
    preempt_policy: Mutex<PreemptPolicy>,
    /// Telemetry event sink ([`Scheduler::set_event_sink`]); the
    /// middleware server fans these to `subscribe` clients.
    event_sink: Mutex<Option<SchedEventSink>>,
    /// Admission-driven prefetch sink
    /// ([`Scheduler::set_prefetch_sink`]): every enqueued request is
    /// announced so the bitstream cache can compile or fetch the
    /// tenant's artifact while the request waits in the queue.
    prefetch_sink: Mutex<Option<PrefetchSink>>,
    /// Last queue depth pushed to the sink — depth events fire on
    /// change, not on every gauge refresh.
    last_queue_depth: AtomicI64,
}

/// Telemetry events the scheduler pushes to an attached sink.
/// Variants mirror the wire [`crate::middleware::api::Event`]
/// shapes, but live here so the scheduler never depends on the wire
/// layer. Sinks run under scheduler locks: they must be cheap and
/// must never call back into the scheduler.
#[derive(Debug, Clone)]
pub enum SchedEvent {
    /// The admission queue depth changed.
    QueueDepth { depth: u64 },
    /// A grant was issued (one event per lease member).
    GrantIssued {
        alloc: AllocationId,
        tenant: UserId,
        model: ServiceModel,
        class: RequestClass,
        wait: VirtualTime,
    },
    /// A tracked grant was rebound to a new region (preemption,
    /// operator migrate, gang relocation).
    PlacementChanged {
        alloc: AllocationId,
        tenant: UserId,
        vfpga: VfpgaId,
        fpga: FpgaId,
        migrations: u64,
    },
}

/// Callback the scheduler pushes [`SchedEvent`]s through.
pub type SchedEventSink = Arc<dyn Fn(SchedEvent) + Send + Sync>;

/// What the scheduler knows about a queued admission at enqueue time
/// — enough for the bitstream cache to warm the right artifact before
/// the grant lands. Deliberately *not* a [`SchedEvent`]: it feeds the
/// cache, not the telemetry stream.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchHint {
    pub tenant: UserId,
    /// Board constraint, when the request pinned one.
    pub board: Option<BoardKind>,
    /// Gang width (how many regions will want the artifact).
    pub regions: u32,
}

/// Callback the scheduler pushes [`PrefetchHint`]s through. Runs
/// under scheduler locks: it must be cheap and must never call back
/// into the scheduler.
pub type PrefetchSink = Arc<dyn Fn(PrefetchHint) + Send + Sync>;

/// A durable snapshot prepared under the state lock and written after
/// it drops (disk IO never blocks admissions). Carries the WAL handle
/// and the cursor the snapshot covers so a landed write can compact
/// the log.
struct PersistPending {
    seq: u64,
    path: PathBuf,
    text: String,
    wal: Option<Arc<SchedWal>>,
    wal_cursor: u64,
}

/// Device-seconds `user` has consumed so far: the released total in
/// the ledger plus the accrued time of every live grant — so budgets
/// bound consumption while leases are still held, not just after the
/// first release.
fn used_device_seconds(
    ledger: &UsageLedger,
    grants: &BTreeMap<AllocationId, SchedGrant>,
    user: UserId,
    now_ns: u64,
) -> f64 {
    let live: f64 = grants
        .values()
        .filter(|g| g.user == user)
        .map(|g| {
            VirtualTime(now_ns.saturating_sub(g.started_ns)).as_secs_f64()
                * g.units as f64
        })
        .sum();
    ledger.device_seconds(user) + live
}

impl Scheduler {
    pub fn new(hv: Arc<Hypervisor>) -> Arc<Scheduler> {
        let devices: Vec<DeviceInfo> = {
            let db = hv.db.lock().unwrap();
            hv.device_ids()
                .into_iter()
                .filter_map(|id| {
                    db.device(id).map(|d| DeviceInfo {
                        fpga: id,
                        models: d.models.clone(),
                        board: d.board,
                        regions: d.regions.len() as u64,
                    })
                })
                .collect()
        };
        let total_regions = devices.iter().map(|d| d.regions).sum();
        Arc::new(Scheduler {
            hv,
            devices,
            total_regions,
            state: Mutex::new(SchedState {
                queue: AdmissionQueue::new(),
                quotas: QuotaBook::new(),
                reservations: ReservationBook::new(),
                ledger: UsageLedger::new(),
                grants: BTreeMap::new(),
                leases: BTreeMap::new(),
                ready: BTreeMap::new(),
            }),
            granted: Condvar::new(),
            persist_path: Mutex::new(None),
            wal: Mutex::new(None),
            persist_seq: AtomicU64::new(1),
            persist_written: Mutex::new(0),
            preempt_policy: Mutex::new(PreemptPolicy::default()),
            event_sink: Mutex::new(None),
            prefetch_sink: Mutex::new(None),
            last_queue_depth: AtomicI64::new(0),
        })
    }

    /// Install the telemetry event sink (queue depth, grants,
    /// placement changes). One sink; installing replaces the old one.
    pub fn set_event_sink(&self, sink: SchedEventSink) {
        *self.event_sink.lock().unwrap() = Some(sink);
    }

    /// Install the admission-driven prefetch sink (the bitstream
    /// cache). One sink; installing replaces the old one.
    pub fn set_prefetch_sink(&self, sink: PrefetchSink) {
        *self.prefetch_sink.lock().unwrap() = Some(sink);
    }

    /// Push one event through the sink, if any.
    fn emit(&self, event: SchedEvent) {
        let sink = self.event_sink.lock().unwrap().clone();
        if let Some(sink) = sink {
            sink(event);
        }
    }

    /// Set where preemption relocates its victims (pack vs spread).
    pub fn set_preempt_policy(&self, policy: PreemptPolicy) {
        *self.preempt_policy.lock().unwrap() = policy;
    }

    pub fn preempt_policy(&self) -> PreemptPolicy {
        *self.preempt_policy.lock().unwrap()
    }

    // ----------------------------------------------- topology facts

    /// Does a reservation pinned to `reserved` (None = cluster-wide)
    /// withhold capacity from requests for `requested`? True when the
    /// two models share at least one device.
    fn models_share_device(
        &self,
        reserved: Option<ServiceModel>,
        requested: ServiceModel,
    ) -> bool {
        match reserved {
            None => true,
            Some(m) => self.devices.iter().any(|d| {
                d.models.contains(&m) && d.models.contains(&requested)
            }),
        }
    }

    /// Total regions on devices serving `model`.
    fn total_regions_for(&self, model: ServiceModel) -> u64 {
        self.devices
            .iter()
            .filter(|d| d.models.contains(&model))
            .map(|d| d.regions)
            .sum()
    }

    /// Physically free regions on devices matching `model` (+ board
    /// constraint), ignoring reservations.
    fn raw_free(&self, model: ServiceModel, board: Option<BoardKind>) -> u64 {
        let db = self.hv.db.lock().unwrap();
        self.devices
            .iter()
            .filter(|d| d.matches(model, board))
            .map(|d| db.free_regions(d.fpga).len() as u64)
            .sum()
    }

    /// Capacity withheld from `user` for a `model` request by other
    /// tenants' active reservations whose model overlaps it.
    fn withheld_for(
        &self,
        st: &SchedState,
        user: UserId,
        model: ServiceModel,
        now_ns: u64,
    ) -> u64 {
        st.reservations.withheld_from(user, now_ns, |rm| {
            self.models_share_device(rm, model)
        })
    }

    /// Build a scheduler whose quota + ledger state persists next to
    /// the device DB at `db_path`, loading existing state when
    /// present (accounting survives a management-node restart).
    pub fn new_persistent(
        hv: Arc<Hypervisor>,
        db_path: &Path,
    ) -> Result<Arc<Scheduler>, String> {
        let sched = Scheduler::new(hv);
        sched.attach_persistence(db_path)?;
        Ok(sched)
    }

    pub fn hv(&self) -> &Arc<Hypervisor> {
        &self.hv
    }

    // -------------------------------------------------- persistence

    /// Attach durable state: open the write-ahead log
    /// (`<db-stem>.sched.wal/` next to `db_path`), load the snapshot
    /// (`<db-stem>.sched.json`) when it exists, fold the WAL suffix
    /// past the snapshot's cursor into it, and **re-adopt** the
    /// recovered live state — leases re-register their placements
    /// with the hypervisor (tokens keep validating), queued
    /// admissions resume waiting, quota limits and the usage ledger
    /// are restored. From now on every grant/queue/quota mutation
    /// appends a WAL record and accounting boundaries re-snapshot
    /// (which compacts the WAL). Recovered capacity or raised caps
    /// can admit queued work, so the queue is pumped before
    /// returning.
    pub fn attach_persistence(
        &self,
        db_path: &Path,
    ) -> Result<(), String> {
        let path = persist::sched_state_path(db_path);
        let wal_dir = persist::sched_wal_dir(db_path);
        let wal = SchedWal::open(&wal_dir)
            .map_err(|e| format!("{}: {e}", wal_dir.display()))?;
        wal.set_metrics(Arc::clone(&self.hv.metrics));
        let wal = Arc::new(wal);
        let mut st = self.state.lock().unwrap();
        let mut recovered = RecoveredLive::default();
        let mut replay_from = 1;
        if path.exists() {
            let loaded = persist::load(&path)?;
            st.quotas.restore_limits(loaded.quotas);
            st.ledger.restore(loaded.usage);
            // Seed the fold with the snapshot's live state; WAL
            // records past its cursor then replay over it (apply is
            // idempotent, so a record the snapshot already covers is
            // harmless).
            for lease in loaded.leases {
                recovered.apply(&WalRecord::Grant(lease));
            }
            for entry in loaded.queue {
                recovered.apply(&WalRecord::Enqueue(entry));
            }
            replay_from = loaded.wal_cursor + 1;
        }
        for (_, record) in wal
            .replay_from(replay_from)
            .map_err(|e| format!("{}: {e}", wal_dir.display()))?
        {
            recovered.apply(&record);
        }
        self.adopt_recovered_locked(&mut st, recovered);
        // Install the WAL *before* pumping so grants the pump issues
        // are journaled like any others.
        *self.wal.lock().unwrap() = Some(Arc::clone(&wal));
        self.pump_locked(&mut st);
        *self.persist_path.lock().unwrap() = Some(path);
        // A fresh snapshot covers everything just recovered; writing
        // it (below, off the lock) compacts the recovered WAL away.
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        Ok(())
    }

    /// Snapshot the durable state for writing, if persistence is
    /// attached. Called under the state lock (which also orders the
    /// sequence numbers); the caller writes the file *after* dropping
    /// it so disk IO never blocks admissions.
    fn persist_snapshot_locked(
        &self,
        st: &SchedState,
    ) -> Option<PersistPending> {
        let path = self.persist_path.lock().unwrap().clone()?;
        let seq = self.persist_seq.fetch_add(1, Ordering::Relaxed);
        let wal = self.wal.lock().unwrap().clone();
        // Everything up to the WAL's current head is (by lock order)
        // already reflected in `st`, so this snapshot covers it.
        let wal_cursor = wal
            .as_ref()
            .map(|w| w.next_cursor().saturating_sub(1))
            .unwrap_or(0);
        let leases: Vec<LeaseRecord> = st
            .leases
            .keys()
            .filter_map(|t| Self::lease_record_locked(st, *t))
            .collect();
        let queue = st.queue.snapshot();
        Some(PersistPending {
            seq,
            path,
            text: persist::render(
                &st.quotas,
                &st.ledger,
                &leases,
                &queue,
                wal_cursor,
            ),
            wal,
            wal_cursor,
        })
    }

    /// Write a snapshot taken by [`Scheduler::persist_snapshot_locked`],
    /// skipping it when a newer snapshot already reached disk. A
    /// snapshot that lands compacts the WAL: segments at or below its
    /// cursor are no longer needed for recovery.
    fn write_persisted(&self, pending: Option<PersistPending>) {
        let Some(p) = pending else { return };
        {
            let mut written = self.persist_written.lock().unwrap();
            if *written > p.seq {
                return;
            }
            match crate::util::fsx::write_atomic(&p.path, &p.text) {
                Ok(()) => *written = p.seq,
                Err(e) => {
                    log::warn!(
                        "sched state persist to {} failed: {e}",
                        p.path.display()
                    );
                    return;
                }
            }
        }
        if let Some(wal) = p.wal {
            if let Err(e) = wal.retain_from(p.wal_cursor) {
                log::warn!("sched wal compaction failed: {e}");
            }
        }
    }

    /// Append one record to the write-ahead log, if attached. Always
    /// called under the state lock, so the log order is exactly the
    /// order mutations were applied. On an IO error the scheduler
    /// degrades to snapshot-only durability rather than failing the
    /// operation (the next boundary snapshot still captures the
    /// state).
    fn wal_append_locked(&self, record: &WalRecord) {
        let wal = self.wal.lock().unwrap().clone();
        if let Some(wal) = wal {
            if let Err(e) = wal.append(record) {
                log::warn!("sched wal append failed: {e}");
            }
        }
    }

    /// The durable [`LeaseRecord`] for a live lease, assembled from
    /// its meta + member grants.
    fn lease_record_locked(
        st: &SchedState,
        token: LeaseToken,
    ) -> Option<LeaseRecord> {
        let meta = st.leases.get(&token)?;
        Some(LeaseRecord {
            token,
            tenant: meta.tenant,
            model: meta.model,
            class: meta.class,
            co_located: meta.co_located,
            wait_ns: meta.wait.0,
            members: meta
                .members
                .iter()
                .filter_map(|a| {
                    st.grants.get(a).map(|g| MemberRecord {
                        alloc: *a,
                        target: g.target,
                        units: g.units,
                        started_ns: g.started_ns,
                        charge_w: g.charge_w,
                        migrations: g.migrations,
                    })
                })
                .collect(),
        })
    }

    /// Re-adopt state recovered from snapshot + WAL: quota limits
    /// first (upserted over the snapshot's), then each lease
    /// all-or-nothing against the hypervisor — if any member fails to
    /// re-adopt (its region vanished from the topology, say), the
    /// members already adopted are rolled back and the whole lease is
    /// dropped with a warning, never half-restored. Accrual clocks
    /// restart at now (the downtime is not billed to the tenant) and
    /// queue entries rebase their enqueue time and deadline window
    /// onto the fresh virtual clock.
    fn adopt_recovered_locked(
        &self,
        st: &mut SchedState,
        recovered: RecoveredLive,
    ) {
        let now_ns = self.hv.clock.now().0;
        for (user, quota) in recovered.quotas {
            st.quotas.set(user, quota);
        }
        'lease: for rec in recovered.leases {
            let mut adopted: Vec<AllocationId> = Vec::new();
            for m in &rec.members {
                let result = match m.target {
                    GrantTarget::Vfpga(v, _, _) => self
                        .hv
                        .adopt_vfpga(m.alloc, rec.tenant, rec.model, v)
                        .map(|_| ())
                        .map_err(|e| e.to_string()),
                    GrantTarget::Physical(f, _) => self
                        .hv
                        .adopt_physical(m.alloc, rec.tenant, f)
                        .map(|_| ())
                        .map_err(|e| e.to_string()),
                };
                match result {
                    Ok(()) => adopted.push(m.alloc),
                    Err(e) => {
                        log::warn!(
                            "recovery: lease {} member {} failed to \
                             re-adopt ({e}); dropping the lease",
                            rec.token,
                            m.alloc
                        );
                        for a in adopted.drain(..) {
                            let _ = self.hv.release(a);
                        }
                        continue 'lease;
                    }
                }
            }
            for m in &rec.members {
                st.quotas.charge(rec.tenant, m.units);
                st.grants.insert(
                    m.alloc,
                    SchedGrant {
                        alloc: m.alloc,
                        user: rec.tenant,
                        model: rec.model,
                        class: rec.class,
                        target: m.target,
                        units: m.units,
                        started_ns: now_ns,
                        wait: VirtualTime(rec.wait_ns),
                        charge_w: m.charge_w,
                        from_reservation: None,
                        token: rec.token,
                        migrations: m.migrations,
                    },
                );
            }
            st.leases.insert(
                rec.token,
                LeaseMeta {
                    tenant: rec.tenant,
                    model: rec.model,
                    class: rec.class,
                    members: rec.members.iter().map(|m| m.alloc).collect(),
                    wait: VirtualTime(rec.wait_ns),
                    co_located: rec.co_located,
                },
            );
            self.hv.metrics.counter("sched.adopted").inc();
        }
        for mut entry in recovered.queue {
            entry.deadline_ns = entry
                .deadline_ns
                .map(|d| now_ns + d.saturating_sub(entry.enqueued_ns));
            entry.enqueued_ns = now_ns;
            st.queue.adopt(entry);
        }
        self.update_gauges_locked(st);
    }

    // ------------------------------------------------------- quotas

    pub fn set_quota(&self, user: UserId, quota: TenantQuota) {
        self.update_quota(user, |q| *q = quota);
    }

    /// Atomic read-modify-write of a tenant's quota under the state
    /// lock (concurrent partial updates cannot lose fields). Returns
    /// the resulting quota. A raised cap can unblock queued requests,
    /// so the queue is pumped before returning.
    pub fn update_quota(
        &self,
        user: UserId,
        f: impl FnOnce(&mut TenantQuota),
    ) -> TenantQuota {
        let mut st = self.state.lock().unwrap();
        let mut quota = st.quotas.quota(user);
        f(&mut quota);
        st.quotas.set(user, quota);
        self.wal_append_locked(&WalRecord::Quota { user, quota });
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        quota
    }

    pub fn quota(&self, user: UserId) -> TenantQuota {
        self.state.lock().unwrap().quotas.quota(user)
    }

    /// vFPGA-equivalents the tenant currently holds via this
    /// scheduler.
    pub fn in_use(&self, user: UserId) -> u64 {
        self.state.lock().unwrap().quotas.in_use(user)
    }

    pub fn usage(&self, user: UserId) -> TenantUsage {
        self.state.lock().unwrap().ledger.usage(user)
    }

    // ------------------------------------------------- reservations

    /// Reserve `regions` vFPGAs for `user` over a virtual-time
    /// window, optionally pinned to a service model (the reservation
    /// then only withholds capacity from requests sharing that
    /// model's devices, and is clamped to that model's region count —
    /// region-count- and model-aware instead of a cluster-wide
    /// count). Expired windows are reclaimed lazily on admission.
    /// `regions` is clamped so the total booked over any overlapping
    /// window never exceeds the capacity it draws on — a pile of
    /// reservations cannot overbook and wedge all admissions (an
    /// over-ask may thus yield a smaller, even zero-region,
    /// reservation; duration is operator-policed).
    pub fn reserve(
        &self,
        user: UserId,
        regions: u64,
        model: Option<ServiceModel>,
        start: VirtualTime,
        duration: VirtualTime,
    ) -> ReservationId {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        let already = st.reservations.reserved_overlapping(
            start.0,
            (start + duration).0,
            |rm| match (rm, model) {
                (None, _) | (_, None) => true,
                (Some(a), Some(b)) => self.models_share_device(Some(a), b),
            },
        );
        let cap = match model {
            Some(m) => self.total_regions_for(m),
            None => self.total_regions,
        };
        let regions = regions.min(cap.saturating_sub(already));
        st.reservations.reserve(user, regions, model, start, duration)
    }

    pub fn cancel_reservation(
        &self,
        id: ReservationId,
    ) -> Result<(), SchedError> {
        let mut st = self.state.lock().unwrap();
        if !st.reservations.cancel(id) {
            return Err(SchedError::UnknownReservation(id));
        }
        // Freed capacity may admit queued work — and those grants
        // count against budgets, so they must reach the state file.
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        Ok(())
    }

    // --------------------------------------------------- admissions

    /// Non-blocking admission — the interactive fast path. Fails with
    /// [`SchedError::NoCapacity`] rather than queueing; single-region
    /// interactive requests may preempt a batch lease by migration
    /// first. Gang requests (`regions > 1`) grant atomically or fail.
    pub fn admit(
        self: &Arc<Self>,
        req: &AdmissionRequest,
    ) -> Result<Lease, SchedError> {
        let sp = trace::span("sched.admit");
        sp.attr("model", req.model.name());
        sp.attr("regions", req.regions.get());
        let spec = AdmitSpec::of_request(
            req,
            req.class == RequestClass::Interactive,
        );
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        // Capacity reclaimed since the last pump (reservation expiry,
        // out-of-band release) belongs to queued effectively-higher-
        // class requests before this caller's immediate attempt —
        // classes are strict at every admission decision.
        let now_ns = self.hv.clock.now().0;
        if st.queue.has_class_above(req.class, now_ns) {
            self.pump_locked(&mut st);
        }
        let result = self.try_admit_locked(&mut st, &spec);
        // Reservation expiry (or a preemption) may have freed
        // capacity queued work can use — pump before returning.
        self.pump_locked(&mut st);
        let lease = result.and_then(|token| {
            self.lease_locked(&st, token, true)
                .ok_or(SchedError::UnknownLease)
        });
        // Grants and preemption-downtime charges count against
        // budgets, so they must reach the state file too — not just
        // releases and quota updates.
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        if let Err(e) = &lease {
            sp.fail(format!("{e:?}"));
        }
        lease
    }

    /// Non-blocking admission that mints the lease under a
    /// pre-existing capability token instead of a fresh one — the
    /// federation re-admission path. When a node dies, its surviving
    /// leases are re-homed on another node *under their original
    /// tokens*, so the capability the tenant already holds keeps
    /// fencing the re-placed lease. Fails with
    /// [`SchedError::Unsatisfiable`] if the token already names a
    /// live lease here.
    pub fn admit_adopted(
        self: &Arc<Self>,
        req: &AdmissionRequest,
        token: LeaseToken,
    ) -> Result<Lease, SchedError> {
        let sp = trace::span("sched.admit_adopted");
        sp.attr("model", req.model.name());
        sp.attr("regions", req.regions.get());
        let mut spec = AdmitSpec::of_request(req, false);
        spec.adopt = Some(token);
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        let result = self.try_admit_locked(&mut st, &spec);
        self.pump_locked(&mut st);
        let lease = result.and_then(|token| {
            self.lease_locked(&st, token, true)
                .ok_or(SchedError::UnknownLease)
        });
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        if let Err(e) = &lease {
            sp.fail(format!("{e:?}"));
        }
        lease
    }

    /// Blocking admission: take the fast path when nothing of equal
    /// or higher class is queued, otherwise join the queue and wait
    /// for the fair-share pump. Physical (RSaaS) requests never
    /// queue — they take the immediate path.
    pub fn admit_blocking(
        self: &Arc<Self>,
        req: &AdmissionRequest,
    ) -> Result<Lease, SchedError> {
        if req.model == ServiceModel::RSaaS {
            return self.admit(req);
        }
        let sp = trace::span("sched.admit");
        sp.attr("model", req.model.name());
        sp.attr("regions", req.regions.get());
        let ticket = {
            let mut st = self.state.lock().unwrap();
            self.reap_locked(&mut st);
            let now_ns = self.hv.clock.now().0;
            if !st.queue.has_class_at_or_above(req.class, now_ns) {
                let spec = AdmitSpec::of_request(
                    req,
                    req.class == RequestClass::Interactive,
                );
                match self.try_admit_locked(&mut st, &spec) {
                    Ok(token) => {
                        let lease = self
                            .lease_locked(&st, token, true)
                            .ok_or(SchedError::UnknownLease);
                        let pending = self.persist_snapshot_locked(&st);
                        drop(st);
                        self.granted.notify_all();
                        self.write_persisted(pending);
                        return lease;
                    }
                    Err(SchedError::NoCapacity)
                    | Err(SchedError::QuotaConcurrency(_)) => {}
                    Err(e) => return Err(e),
                }
            }
            self.enqueue_locked(&mut st, req)
        };
        let result = self.wait_ticket(ticket);
        if let Err(e) = &result {
            sp.fail(format!("{e:?}"));
        }
        result
    }

    /// Enqueue without waiting; pair with [`Scheduler::wait_ticket`]
    /// or [`Scheduler::poll_ticket`].
    pub fn enqueue(&self, req: &AdmissionRequest) -> TicketId {
        let mut st = self.state.lock().unwrap();
        self.reap_locked(&mut st);
        let ticket = self.enqueue_locked(&mut st, req);
        // enqueue_locked pumps — grants it produced count against
        // budgets and must reach the state file.
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.write_persisted(pending);
        ticket
    }

    /// Can any device configuration ever satisfy this request?
    /// Terminal-failure check for queued requests (a request no
    /// topology can serve must not queue forever).
    fn satisfiable(&self, req: &AdmissionRequest) -> Result<(), String> {
        if req.model == ServiceModel::RSaaS {
            return Err(
                "physical (RSaaS) leases admit immediately; they do not \
                 queue"
                    .to_string(),
            );
        }
        let board = req.constraints.board;
        let matching: Vec<&DeviceInfo> = self
            .devices
            .iter()
            .filter(|d| d.matches(req.model, board))
            .collect();
        if matching.is_empty() {
            return Err(format!(
                "no device serves model '{}'{}",
                req.model.name(),
                board
                    .map(|b| format!(" on board '{}'", b.name()))
                    .unwrap_or_default()
            ));
        }
        let regions = u64::from(req.regions.get());
        let cap: u64 = matching.iter().map(|d| d.regions).sum();
        if cap < regions {
            return Err(format!(
                "gang of {regions} exceeds the {cap} regions the \
                 matching devices have in total"
            ));
        }
        if req.constraints.co_located
            && !matching.iter().any(|d| d.regions >= regions)
        {
            return Err(format!(
                "no single matching device has {regions} regions for a \
                 co-located gang"
            ));
        }
        Ok(())
    }

    fn enqueue_locked(
        &self,
        st: &mut SchedState,
        req: &AdmissionRequest,
    ) -> TicketId {
        let now_ns = self.hv.clock.now().0;
        let ticket = st.queue.push(req, now_ns);
        if let Err(why) = self.satisfiable(req) {
            st.queue.remove(ticket);
            st.ready
                .insert(ticket, Err(SchedError::Unsatisfiable(why)));
            self.granted.notify_all();
            return ticket;
        }
        // A gang wider than the tenant's concurrency cap can never
        // admit even on an idle cluster — fail it now rather than
        // queueing it forever (the pump re-checks in case a cap is
        // lowered later).
        let cap = st.quotas.quota(req.tenant).max_concurrent;
        let regions = u64::from(req.regions.get());
        if regions > cap {
            st.queue.remove(ticket);
            st.ready.insert(
                ticket,
                Err(SchedError::Unsatisfiable(format!(
                    "gang of {regions} exceeds the tenant's \
                     concurrency quota of {cap}"
                ))),
            );
            self.granted.notify_all();
            return ticket;
        }
        // Journal only entries that actually wait — the early
        // terminal failures above never enqueued durably, so recovery
        // has nothing to resume for them.
        if let Some(entry) = st.queue.entry(ticket).cloned() {
            self.wal_append_locked(&WalRecord::Enqueue(entry));
        }
        st.ledger.row_mut(req.tenant).queued += 1;
        self.hv.metrics.counter("sched.enqueued").inc();
        // Announce the queued admission to the bitstream cache: the
        // wait in this queue is exactly the window in which an AOT
        // compile or a cross-node artifact fetch is free.
        let prefetch = self.prefetch_sink.lock().unwrap().clone();
        if let Some(prefetch) = prefetch {
            prefetch(PrefetchHint {
                tenant: req.tenant,
                board: req.constraints.board,
                regions: req.regions.get(),
            });
        }
        // Capacity may already be free (e.g. first submission).
        self.pump_locked(st);
        self.granted.notify_all();
        ticket
    }

    /// Materialize the [`Lease`] handle for a token whose meta is in
    /// `st`. `armed` handles release on drop; disarmed ones are
    /// server-side views. `None` when the lease is gone — a granted
    /// ticket's members can be released out-of-band (by allocation
    /// id) before the waiter collects it, and that must read as a
    /// stale lease, not a panic under the state lock.
    fn lease_locked(
        self: &Arc<Self>,
        st: &SchedState,
        token: LeaseToken,
        armed: bool,
    ) -> Option<Lease> {
        let meta = st.leases.get(&token)?;
        Some(Lease::assemble(
            Arc::clone(self),
            token,
            meta.tenant,
            meta.model,
            meta.class,
            meta.members.clone(),
            meta.wait,
            armed,
        ))
    }

    /// Block until the ticket resolves.
    ///
    /// Wakes on this scheduler's own pump; in-instance progress never
    /// waits on the tick. A half-second fallback tick additionally
    /// re-pumps so capacity freed *outside* this scheduler instance
    /// (a direct `Hypervisor::release`, or a sibling scheduler over
    /// the same hypervisor) is still picked up instead of blocking
    /// forever.
    pub fn wait_ticket(
        self: &Arc<Self>,
        ticket: TicketId,
    ) -> Result<Lease, SchedError> {
        let _sp = trace::span("sched.queue_wait");
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(result) = st.ready.remove(&ticket) {
                return result.and_then(|token| {
                    self.lease_locked(&st, token, true)
                        .ok_or(SchedError::UnknownLease)
                });
            }
            let (guard, timeout) = self
                .granted
                .wait_timeout(st, std::time::Duration::from_millis(500))
                .unwrap();
            st = guard;
            if timeout.timed_out() {
                self.pump_locked(&mut st);
                // The tick pump can admit queued work whose grants
                // count against budgets — persist them (brief file
                // write under the lock; the tick is a 500 ms
                // fallback, not a hot path).
                let pending = self.persist_snapshot_locked(&st);
                self.write_persisted(pending);
                // The pump may have resolved *other* waiters' tickets.
                self.granted.notify_all();
            }
        }
    }

    /// Non-blocking poll of an enqueued ticket.
    pub fn poll_ticket(
        self: &Arc<Self>,
        ticket: TicketId,
    ) -> Option<Result<Lease, SchedError>> {
        let mut st = self.state.lock().unwrap();
        let result = st.ready.remove(&ticket)?;
        Some(result.and_then(|token| {
            self.lease_locked(&st, token, true)
                .ok_or(SchedError::UnknownLease)
        }))
    }

    /// Cancel a still-queued ticket. Returns false when the ticket
    /// already left the queue (granted, failed, or never existed) —
    /// the caller must then collect it via
    /// `wait_ticket`/`poll_ticket`.
    pub fn cancel_ticket(&self, ticket: TicketId) -> bool {
        let mut st = self.state.lock().unwrap();
        if st.queue.remove(ticket).is_some() {
            self.wal_append_locked(&WalRecord::Dequeue { ticket });
            st.ready.insert(ticket, Err(SchedError::Cancelled));
            self.update_gauges_locked(&st);
            self.granted.notify_all();
            true
        } else {
            false
        }
    }

    /// Exclusive physical-device admission (RSaaS / VM passthrough) —
    /// the `model == RSaaS` arm of [`Scheduler::admit`]. Never
    /// queues; counts [`PHYSICAL_EQUIV_UNITS`] against the
    /// concurrency quota. Physical capacity is not *reservable*, but
    /// taking a whole device removes its regions from the vFPGA pool,
    /// so admission is denied when that would leave other tenants'
    /// active reservations (of any model — conservative) uncoverable.
    fn admit_physical_locked(
        &self,
        st: &mut SchedState,
        spec: &AdmitSpec,
    ) -> Result<LeaseToken, SchedError> {
        if spec.regions != 1 {
            return Err(SchedError::Unsatisfiable(
                "physical (RSaaS) leases take whole devices; gang \
                 regions apply to vFPGA models"
                    .to_string(),
            ));
        }
        let user = spec.tenant;
        let used_s = used_device_seconds(
            &st.ledger,
            &st.grants,
            user,
            self.hv.clock.now().0,
        );
        if let Err(d) =
            st.quotas.admissible(user, PHYSICAL_EQUIV_UNITS, used_s)
        {
            return Err(self.deny(d));
        }
        let withheld = st
            .reservations
            .withheld_from_any(user, self.hv.clock.now().0);
        if withheld > 0 {
            let total_free: u64 = {
                let db = self.hv.db.lock().unwrap();
                self.devices
                    .iter()
                    .map(|d| db.free_regions(d.fpga).len() as u64)
                    .sum()
            };
            if total_free.saturating_sub(crate::paper::MAX_VFPGAS as u64)
                < withheld
            {
                return Err(SchedError::NoCapacity);
            }
        }
        let (alloc, fpga, node) = self
            .hv
            .alloc_physical(user, spec.vm)
            .map_err(SchedError::from)?;
        // charge_w is *per unit*; spread the whole-board static draw
        // over the device's vFPGA-equivalents so release() bills
        // units x charge_w = one board's worth.
        let charge_w = self
            .hv
            .device(fpga)
            .map(|d| d.fpga.lock().unwrap().board.static_power_w)
            .unwrap_or(0.0)
            / PHYSICAL_EQUIV_UNITS as f64;
        let token = LeaseToken::mint();
        let grant = SchedGrant {
            alloc,
            user,
            model: ServiceModel::RSaaS,
            class: spec.class,
            target: GrantTarget::Physical(fpga, node),
            units: PHYSICAL_EQUIV_UNITS,
            started_ns: self.hv.clock.now().0,
            wait: VirtualTime::ZERO,
            charge_w,
            from_reservation: None,
            token,
            migrations: 0,
        };
        self.finish_grant_locked(st, grant);
        st.leases.insert(
            token,
            LeaseMeta {
                tenant: user,
                model: ServiceModel::RSaaS,
                class: spec.class,
                members: vec![alloc],
                wait: VirtualTime::ZERO,
                co_located: false,
            },
        );
        if let Some(rec) = Self::lease_record_locked(st, token) {
            self.wal_append_locked(&WalRecord::Grant(rec));
        }
        Ok(token)
    }

    /// Release one scheduler-tracked allocation (a single lease
    /// member): returns it to the hypervisor, charges the usage
    /// ledger, credits the quota and pumps the admission queue.
    /// Whole-lease release goes through [`Scheduler::release_token`]
    /// (or [`Lease::release`]).
    pub fn release(&self, alloc: AllocationId) -> Result<(), SchedError> {
        let mut st = self.state.lock().unwrap();
        let result = self.release_member_locked(&mut st, alloc);
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        result
    }

    /// Release every member of a lease by capability token — the
    /// [`Lease`] handle's release/drop path. Members already released
    /// out-of-band (by allocation id) are skipped, not errors.
    pub fn release_token(
        &self,
        token: LeaseToken,
    ) -> Result<(), SchedError> {
        let mut st = self.state.lock().unwrap();
        let meta = st
            .leases
            .get(&token)
            .cloned()
            .ok_or(SchedError::UnknownLease)?;
        let mut first_err = None;
        for alloc in meta.members {
            match self.release_member_locked(&mut st, alloc) {
                Ok(()) | Err(SchedError::UnknownGrant(_)) => {}
                Err(e) => first_err = first_err.or(Some(e)),
            }
        }
        st.leases.remove(&token);
        self.pump_locked(&mut st);
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.granted.notify_all();
        self.write_persisted(pending);
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// One member's release bookkeeping. Everything happens under the
    /// state lock (the scheduler's lock order is always state →
    /// hypervisor, same as the pump and preemption paths), so no
    /// concurrent admit can observe the freed region with the quota
    /// still charged or vice versa.
    fn release_member_locked(
        &self,
        st: &mut SchedState,
        alloc: AllocationId,
    ) -> Result<(), SchedError> {
        let grant = st
            .grants
            .remove(&alloc)
            .ok_or(SchedError::UnknownGrant(alloc))?;
        self.wal_append_locked(&WalRecord::ReleaseMember { alloc });
        // Hypervisor::release removes the DB allocation before its
        // fallible device cleanup, so after an error the lease is
        // gone either way (removed now, or it never existed).
        // Bookkeeping must still run — restoring the grant would
        // leak the tenant's quota units forever — and the device
        // error is reported after the credit.
        let release_result = self.hv.release(alloc);
        let now = self.hv.clock.now();
        let held =
            VirtualTime(now.0.saturating_sub(grant.started_ns)).as_secs_f64();
        st.ledger.charge_release(
            grant.user,
            held * grant.units as f64,
            grant.charge_w,
        );
        st.quotas.credit(grant.user, grant.units);
        if let Some(reservation) = grant.from_reservation {
            // The reservation guarantees concurrent regions — return
            // the claim now that the lease is gone (no-op if the
            // window already expired).
            st.reservations.release_claim(reservation);
        }
        // Drop the member from its lease; the lease record goes with
        // its last member.
        if let Some(meta) = st.leases.get_mut(&grant.token) {
            meta.members.retain(|a| *a != alloc);
            if meta.members.is_empty() {
                st.leases.remove(&grant.token);
            }
        }
        self.hv.metrics.counter("sched.released").inc();
        release_result.map_err(|e| SchedError::Hypervisor(e.to_string()))
    }

    /// Split a live lease's device-second accrual at a job boundary:
    /// every member's accrued-so-far seconds are charged to the
    /// ledger *now* (same billing as a release, without counting a
    /// release) and the accrual clocks restart. The pipelined batch
    /// mode calls this between jobs on its long-lived region pair so
    /// per-job accounting stays correct without re-admitting.
    /// Returns the unit-seconds charged.
    pub fn checkpoint_accrual(
        &self,
        token: LeaseToken,
    ) -> Result<f64, SchedError> {
        let mut st = self.state.lock().unwrap();
        let meta = st
            .leases
            .get(&token)
            .cloned()
            .ok_or(SchedError::UnknownLease)?;
        let now_ns = self.hv.clock.now().0;
        let mut charges: Vec<(UserId, f64, f64)> = Vec::new();
        for alloc in &meta.members {
            if let Some(g) = st.grants.get_mut(alloc) {
                let held =
                    VirtualTime(now_ns.saturating_sub(g.started_ns))
                        .as_secs_f64()
                        * g.units as f64;
                g.started_ns = now_ns;
                charges.push((g.user, held, g.charge_w));
            }
        }
        let mut charged = 0.0;
        for (user, held, watts) in charges {
            st.ledger.charge_accrual(user, held, watts);
            charged += held;
        }
        let pending = self.persist_snapshot_locked(&st);
        drop(st);
        self.write_persisted(pending);
        Ok(charged)
    }

    // ------------------------------------------- lease capabilities

    /// Re-materialize a (disarmed) lease handle from its capability
    /// token. `None` for forged or stale tokens — possessing a valid
    /// token IS the authorization, so this is the middleware's
    /// auth check.
    pub fn lease_handle(
        self: &Arc<Self>,
        token: LeaseToken,
    ) -> Option<Lease> {
        let st = self.state.lock().unwrap();
        self.lease_locked(&st, token, false)
    }

    /// Tokens of every live lease, in token order. The node daemon
    /// reports these at `cluster.register` so the management server
    /// can reconcile WAL-adopted leases after a rejoin.
    pub fn live_tokens(&self) -> Vec<LeaseToken> {
        let st = self.state.lock().unwrap();
        st.leases.keys().copied().collect()
    }

    /// Verify that `token` owns the member allocation `alloc`.
    /// Distinguishes "no such grant" ([`SchedError::UnknownGrant`],
    /// the caller named a dead lease) from "grant exists but the
    /// token does not own it" ([`SchedError::UnknownLease`], a forged
    /// or stale capability).
    pub fn verify_member(
        &self,
        token: LeaseToken,
        alloc: AllocationId,
    ) -> Result<(), SchedError> {
        let st = self.state.lock().unwrap();
        let grant = st
            .grants
            .get(&alloc)
            .ok_or(SchedError::UnknownGrant(alloc))?;
        if grant.token != token {
            return Err(SchedError::UnknownLease);
        }
        Ok(())
    }

    /// A live grant by allocation id (lease placement queries,
    /// status surfaces, tests).
    pub fn grant(&self, alloc: AllocationId) -> Option<SchedGrant> {
        self.state.lock().unwrap().grants.get(&alloc).cloned()
    }

    #[cfg(test)]
    pub(crate) fn bump_migrations_for_test(&self, alloc: AllocationId) {
        let mut st = self.state.lock().unwrap();
        if let Some(g) = st.grants.get_mut(&alloc) {
            g.migrations += 1;
        }
    }

    /// Record an out-of-band migration (e.g. the middleware `migrate`
    /// RPC calling the hypervisor directly) so the tracked grant's
    /// target stays accurate for victim selection and status.
    pub fn note_migration(&self, alloc: AllocationId, to: VfpgaId) {
        let mut st = self.state.lock().unwrap();
        self.rebind_grant_locked(&mut st, alloc, to);
    }

    /// Point a tracked grant at the region its lease now occupies.
    fn rebind_grant_locked(
        &self,
        st: &mut SchedState,
        alloc: AllocationId,
        to: VfpgaId,
    ) {
        let new_home = {
            let db = self.hv.db.lock().unwrap();
            db.device_of_vfpga(to).map(|d| (d.id, d.node))
        };
        if let Some((fpga, node)) = new_home {
            if let Some(grant) = st.grants.get_mut(&alloc) {
                grant.target = GrantTarget::Vfpga(to, fpga, node);
                // Count the move so lease handles can tell a clean
                // preemption race from a real fault (retry signal).
                grant.migrations += 1;
                self.emit(SchedEvent::PlacementChanged {
                    alloc,
                    tenant: grant.user,
                    vfpga: to,
                    fpga,
                    migrations: grant.migrations,
                });
                self.wal_append_locked(&WalRecord::Rebind {
                    alloc,
                    vfpga: Some(to),
                    fpga,
                    node,
                });
            }
        }
    }

    /// Live grants (status surface + tests).
    pub fn active_grants(&self) -> Vec<SchedGrant> {
        self.state.lock().unwrap().grants.values().cloned().collect()
    }

    // ----------------------------------------------- internal logic

    /// Map a quota denial to its error, bumping the denial counter.
    fn deny(&self, d: QuotaDenial) -> SchedError {
        self.hv.metrics.counter("sched.quota.denied").inc();
        match d {
            QuotaDenial::Budget { .. } => {
                SchedError::QuotaBudget(d.to_string())
            }
            QuotaDenial::Concurrency { .. } => {
                SchedError::QuotaConcurrency(d.to_string())
            }
        }
    }

    fn reap_locked(&self, st: &mut SchedState) {
        let expired = st.reservations.reap(self.hv.clock.now().0);
        if expired > 0 {
            self.hv
                .metrics
                .counter("sched.reservations.expired")
                .add(expired as u64);
        }
    }

    /// One immediate admission attempt under the state lock:
    /// quota → capacity (model- and constraint-aware, minus
    /// reservation withholdings) → allocate (placement policy for a
    /// single region, two-phase candidate reservation for a gang) →
    /// record the lease. All-or-nothing for gangs.
    fn try_admit_locked(
        &self,
        st: &mut SchedState,
        spec: &AdmitSpec,
    ) -> Result<LeaseToken, SchedError> {
        if spec.model == ServiceModel::RSaaS {
            return self.admit_physical_locked(st, spec);
        }
        // Forensic marker: a crash *during* this admission leaves an
        // unpaired intent in the WAL (recovery ignores it; operators
        // can see what was in flight). Fires on denied attempts too —
        // compaction keeps the log bounded.
        self.wal_append_locked(&WalRecord::Intent {
            user: spec.tenant,
            model: spec.model,
            class: spec.class,
            regions: spec.regions,
            co_located: spec.co_located,
        });
        let now_ns = self.hv.clock.now().0;
        let used_s = used_device_seconds(
            &st.ledger,
            &st.grants,
            spec.tenant,
            now_ns,
        );
        // The whole gang counts against the concurrency quota at
        // once — N regions admitted atomically are N units.
        {
            let q = trace::span("sched.quota");
            if let Err(d) =
                st.quotas.admissible(spec.tenant, spec.regions, used_s)
            {
                let err = self.deny(d);
                q.fail(format!("{err:?}"));
                return Err(err);
            }
        }
        let raw_free = self.raw_free(spec.model, spec.board);
        let withheld =
            self.withheld_for(st, spec.tenant, spec.model, now_ns);
        if raw_free.saturating_sub(withheld) < spec.regions {
            // Preemption only helps a *single-region interactive*
            // request when the model's devices are physically full
            // AND no active reservation would swallow the vacated
            // region. Otherwise migrating a victim is futile
            // downtime: either free-but-reserved regions already
            // exist, or the one region a preemption frees is owed to
            // a reservation holder. Gang *requests* never preempt;
            // gang *victims* are relocated atomically when no single
            // victim suffices (try_preempt_gang_locked).
            if spec.regions != 1
                || !spec.allow_preempt
                || raw_free > 0
                || withheld > 0
                || !self.try_preempt_locked(
                    st,
                    spec.tenant,
                    spec.model,
                    spec.class,
                )
            {
                return Err(SchedError::NoCapacity);
            }
            // A migration relocates a victim but cannot conjure
            // capacity out of another tenant's reserved headroom: the
            // vacated region only counts if the post-preemption free
            // total still covers every active reservation.
            let withheld =
                self.withheld_for(st, spec.tenant, spec.model, now_ns);
            if self
                .raw_free(spec.model, spec.board)
                .saturating_sub(withheld)
                < 1
            {
                return Err(SchedError::NoCapacity);
            }
        }
        let members = self.allocate_members_locked(spec)?;
        let now_ns = self.hv.clock.now().0;
        let wait = VirtualTime(
            now_ns.saturating_sub(spec.enqueued_ns.unwrap_or(now_ns)),
        );
        let token = match spec.adopt {
            Some(t) if st.leases.contains_key(&t) => {
                // An adopted token must stay unambiguous: refuse to
                // shadow a live lease (roll the claims back first).
                for (alloc, _, _, _) in &members {
                    let _ = self.hv.release(*alloc);
                }
                return Err(SchedError::Unsatisfiable(
                    "adopt token already names a live lease".into(),
                ));
            }
            Some(t) => t,
            None => LeaseToken::mint(),
        };
        for (alloc, vfpga, fpga, node) in &members {
            self.grant_member_locked(
                st, spec, token, *alloc, *vfpga, *fpga, *node, wait,
            );
        }
        self.record_wait_locked(st, spec.tenant, wait);
        st.leases.insert(
            token,
            LeaseMeta {
                tenant: spec.tenant,
                model: spec.model,
                class: spec.class,
                members: members.iter().map(|m| m.0).collect(),
                wait,
                co_located: spec.co_located,
            },
        );
        if let Some(rec) = Self::lease_record_locked(st, token) {
            self.wal_append_locked(&WalRecord::Grant(rec));
        }
        Ok(token)
    }

    /// Claim the regions for one admission. A single unconstrained
    /// region goes through the hypervisor's placement policy; a gang
    /// (or a board-/co-location-constrained request) runs two-phase
    /// reservation: phase 1 picks candidate regions in ascending
    /// `(FpgaId, VfpgaId)` order — one fixed global order, so
    /// concurrent gang admissions can never hold-and-wait in
    /// conflicting orders (deadlock-free) — and phase 2 claims each
    /// candidate, rolling every claimed region back if any claim
    /// loses a race (no partial grant is ever observable).
    fn allocate_members_locked(
        &self,
        spec: &AdmitSpec,
    ) -> Result<Vec<(AllocationId, VfpgaId, FpgaId, NodeId)>, SchedError>
    {
        if spec.regions == 1 && spec.board.is_none() && !spec.co_located {
            return match self.hv.alloc_vfpga(spec.tenant, spec.model) {
                Ok(m) => Ok(vec![m]),
                Err(HypervisorError::NoCapacity) => {
                    Err(SchedError::NoCapacity)
                }
                Err(e) => Err(SchedError::Hypervisor(e.to_string())),
            };
        }
        // Phase 1: candidate selection against a consistent snapshot.
        let candidates: Vec<VfpgaId> = {
            let db = self.hv.db.lock().unwrap();
            let mut picked: Vec<VfpgaId> = Vec::new();
            if spec.co_located {
                for d in self
                    .devices
                    .iter()
                    .filter(|d| d.matches(spec.model, spec.board))
                {
                    let free = db.free_regions(d.fpga);
                    if free.len() as u64 >= spec.regions {
                        picked = free
                            .into_iter()
                            .take(spec.regions as usize)
                            .collect();
                        break;
                    }
                }
            } else {
                'devices: for d in self
                    .devices
                    .iter()
                    .filter(|d| d.matches(spec.model, spec.board))
                {
                    for v in db.free_regions(d.fpga) {
                        picked.push(v);
                        if picked.len() as u64 == spec.regions {
                            break 'devices;
                        }
                    }
                }
            }
            picked
        };
        if (candidates.len() as u64) < spec.regions {
            return Err(SchedError::NoCapacity);
        }
        // Phase 2: claim; all-or-nothing.
        let mut granted: Vec<(AllocationId, VfpgaId, FpgaId, NodeId)> =
            Vec::new();
        for v in candidates {
            match self.hv.alloc_vfpga_on(spec.tenant, spec.model, v) {
                Ok(m) => granted.push(m),
                Err(e) => {
                    for (alloc, _, _, _) in &granted {
                        let _ = self.hv.release(*alloc);
                    }
                    return Err(match e {
                        HypervisorError::NoCapacity => {
                            SchedError::NoCapacity
                        }
                        other => {
                            SchedError::Hypervisor(other.to_string())
                        }
                    });
                }
            }
        }
        Ok(granted)
    }

    /// Record one member grant of a fresh lease.
    #[allow(clippy::too_many_arguments)]
    fn grant_member_locked(
        &self,
        st: &mut SchedState,
        spec: &AdmitSpec,
        token: LeaseToken,
        alloc: AllocationId,
        vfpga: VfpgaId,
        fpga: FpgaId,
        node: NodeId,
        wait: VirtualTime,
    ) {
        let now_ns = self.hv.clock.now().0;
        let charge_w = self
            .hv
            .device(fpga)
            .map(|d| d.fpga.lock().unwrap().board.active_region_power_w)
            .unwrap_or(0.0);
        // Draw on the tenant's reservation only when this admission
        // actually needed reserved headroom: with enough unreserved
        // free capacity left (pre-alloc free = post-alloc + 1), the
        // grant came out of the general pool and the guarantee stays
        // intact for the real burst.
        let raw_free_after = self.raw_free(spec.model, None);
        let reserved_total = st.reservations.withheld_total(now_ns, |rm| {
            self.models_share_device(rm, spec.model)
        });
        let from_reservation = if raw_free_after + 1 <= reserved_total {
            st.reservations.consume(spec.tenant, spec.model, now_ns)
        } else {
            None
        };
        let grant = SchedGrant {
            alloc,
            user: spec.tenant,
            model: spec.model,
            class: spec.class,
            target: GrantTarget::Vfpga(vfpga, fpga, node),
            units: 1,
            started_ns: now_ns,
            wait,
            charge_w,
            from_reservation,
            token,
            migrations: 0,
        };
        self.finish_grant_locked(st, grant);
    }

    /// One wait-histogram sample per *lease* (a gang is one
    /// admission, not N samples).
    fn record_wait_locked(
        &self,
        st: &mut SchedState,
        tenant: UserId,
        wait: VirtualTime,
    ) {
        // Histogram stats render in microseconds; keep the name
        // unit-free so `rc3e stats` reads correctly.
        self.hv
            .metrics
            .histogram("sched.wait")
            .record_us((wait.as_millis_f64() * 1e3) as u64);
        let row = st.ledger.row_mut(tenant);
        row.max_wait_ms = row.max_wait_ms.max(wait.as_millis_f64());
    }

    fn finish_grant_locked(&self, st: &mut SchedState, grant: SchedGrant) {
        st.quotas.charge(grant.user, grant.units);
        st.ledger.row_mut(grant.user).granted += 1;
        self.emit(SchedEvent::GrantIssued {
            alloc: grant.alloc,
            tenant: grant.user,
            model: grant.model,
            class: grant.class,
            wait: grant.wait,
        });
        st.grants.insert(grant.alloc, grant);
        self.hv.metrics.counter("sched.granted").inc();
        self.update_gauges_locked(st);
    }

    /// Relocate the best lower-class victim via migration so a region
    /// on a device serving `model` frees up. Returns true on success.
    ///
    /// Only *quiescable* victims are eligible: the scheduler wins a
    /// non-blocking region quiesce before any state is touched, so a
    /// victim with an in-flight setup or stream pin is skipped, never
    /// raced — the old retry-on-race path is structurally dead (the
    /// `sched.preempt.raced` counter stays 0). Single leases are
    /// tried first (cheapest displacement); if none works, a whole
    /// gang lease is relocated atomically.
    ///
    /// Cost model: the migration downtime is billed to `preemptor`'s
    /// tenant ([`UsageLedger::charge_preemption`]), and the victim's
    /// accrual clock is advanced past the outage so the displaced
    /// tenant is not charged for time it could not use.
    fn try_preempt_locked(
        &self,
        st: &mut SchedState,
        preemptor: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> bool {
        let _sp = trace::span("sched.preempt");
        let policy = self.preempt_policy();
        let candidates: Vec<VictimInfo> = st
            .grants
            .values()
            .filter(|g| g.class < class)
            // Gang members never move one at a time: whole gangs
            // relocate atomically below.
            .filter(|g| {
                st.leases
                    .get(&g.token)
                    .map_or(true, |m| m.members.len() == 1)
            })
            .filter_map(|g| match g.target {
                GrantTarget::Vfpga(v, f, _) => {
                    let serves = self
                        .devices
                        .iter()
                        .any(|d| d.fpga == f && d.models.contains(&model));
                    if serves {
                        Some(VictimInfo {
                            alloc: g.alloc,
                            user: g.user,
                            class: g.class,
                            model: g.model,
                            vfpga: v,
                            fpga: f,
                            started_ns: g.started_ns,
                        })
                    } else {
                        None
                    }
                }
                GrantTarget::Physical(_, _) => None,
            })
            .collect();
        for victim in victim_order(&candidates) {
            // Win the quiesce first — or skip the victim. All further
            // state changes happen under the guard.
            let Some(guard) = self.hv.try_quiesce_region(victim.vfpga)
            else {
                continue;
            };
            // Policy-ordered target on a *different* device serving
            // the victim's own model (a same-device move frees
            // nothing net — useless for preemption).
            let Some(target) = self.preempt_target_locked(
                policy,
                victim.model,
                &[victim.fpga],
            ) else {
                continue;
            };
            match self.hv.migrate_quiesced(
                victim.alloc,
                victim.user,
                Some(target),
                guard,
            ) {
                Ok(report) => {
                    self.settle_preemption_locked(
                        st, preemptor, &victim, &report,
                    );
                    log::info!(
                        "preempted {} ({} -> {}) for an incoming {} request",
                        victim.alloc,
                        report.from,
                        report.to,
                        class.name()
                    );
                    return true;
                }
                Err(e) => {
                    log::debug!(
                        "preemption candidate {} not movable: {e}",
                        victim.alloc
                    );
                }
            }
        }
        self.try_preempt_gang_locked(st, preemptor, model, class, policy)
    }

    /// Post-migration bookkeeping for one displaced member: rebind
    /// the tracked grant, skip the victim's accrual clock over the
    /// outage (the migration advanced the virtual clock, so the lease
    /// would otherwise be billed for time it was dark), and charge
    /// the downtime to the preemptor.
    fn settle_preemption_locked(
        &self,
        st: &mut SchedState,
        preemptor: UserId,
        victim: &VictimInfo,
        report: &MigrationReport,
    ) {
        self.rebind_grant_locked(st, victim.alloc, report.to);
        let now_ns = self.hv.clock.now().0;
        let mut victim_rate_w = 0.0;
        let mut victim_units = 1u64;
        if let Some(g) = st.grants.get_mut(&victim.alloc) {
            g.started_ns = g
                .started_ns
                .saturating_add(report.downtime.0)
                .min(now_ns);
            victim_rate_w = g.charge_w;
            victim_units = g.units;
        }
        st.ledger.charge_preemption(
            preemptor,
            report.downtime.as_secs_f64() * victim_units as f64,
            victim_rate_w,
        );
        st.ledger.row_mut(victim.user).preempted += 1;
        self.hv.metrics.counter("sched.preemptions").inc();
    }

    /// Policy-ordered relocation target for a displaced design: a
    /// free region on a device serving the victim's own model,
    /// excluding the `avoid` devices being vacated (the displacement
    /// must free capacity there, not shuffle it).
    fn preempt_target_locked(
        &self,
        policy: PreemptPolicy,
        victim_model: ServiceModel,
        avoid: &[FpgaId],
    ) -> Option<VfpgaId> {
        let db = self.hv.db.lock().unwrap();
        let rows: Vec<(FpgaId, Vec<VfpgaId>)> = self
            .devices
            .iter()
            .filter(|d| {
                !avoid.contains(&d.fpga)
                    && d.models.contains(&victim_model)
            })
            .map(|d| (d.fpga, db.free_regions(d.fpga)))
            .collect();
        choose_target(policy, &rows)
    }

    /// Relocate a whole lower-class gang lease atomically so capacity
    /// on `model`'s devices frees up. Every member is quiesced
    /// two-phase in the fixed `(fpga, vfpga)` order, then migrated
    /// all-or-nothing with rollback (see [`Self::relocate_members`]).
    fn try_preempt_gang_locked(
        &self,
        st: &mut SchedState,
        preemptor: UserId,
        model: ServiceModel,
        class: RequestClass,
        policy: PreemptPolicy,
    ) -> bool {
        let mut gangs: Vec<(u64, bool, Vec<VictimInfo>)> = Vec::new();
        for meta in st.leases.values() {
            if meta.members.len() < 2 || meta.class >= class {
                continue;
            }
            let mut members = Vec::with_capacity(meta.members.len());
            let mut frees_for_model = false;
            for alloc in &meta.members {
                let Some(g) = st.grants.get(alloc) else { break };
                let GrantTarget::Vfpga(v, f, _) = g.target else {
                    break;
                };
                if self
                    .devices
                    .iter()
                    .any(|d| d.fpga == f && d.models.contains(&model))
                {
                    frees_for_model = true;
                }
                members.push(VictimInfo {
                    alloc: g.alloc,
                    user: g.user,
                    class: g.class,
                    model: g.model,
                    vfpga: v,
                    fpga: f,
                    started_ns: g.started_ns,
                });
            }
            if members.len() == meta.members.len() && frees_for_model {
                let youngest = members
                    .iter()
                    .map(|m| m.started_ns)
                    .max()
                    .unwrap_or(0);
                gangs.push((youngest, meta.co_located, members));
            }
        }
        // Youngest gang first: least accumulated work is displaced.
        gangs.sort_by_key(|(youngest, _, _)| std::cmp::Reverse(*youngest));
        for (_, co_located, members) in gangs {
            match self.relocate_members(&members, policy, co_located) {
                Ok(done) => {
                    for (victim, report) in &done {
                        self.settle_preemption_locked(
                            st, preemptor, victim, report,
                        );
                    }
                    self.hv.metrics.counter("sched.preempt.gang").inc();
                    log::info!(
                        "atomically relocated a {}-member gang for an \
                         incoming {} request",
                        done.len(),
                        class.name()
                    );
                    return true;
                }
                Err(e) => {
                    log::debug!("gang not relocatable: {e}");
                }
            }
        }
        false
    }

    /// Atomically relocate a set of lease members: phase 1 wins a
    /// non-blocking quiesce on every region in ascending
    /// `(fpga, vfpga)` order — the same fixed global order gang
    /// admission claims in, so concurrent relocations never
    /// hold-and-wait in conflicting orders; phase 2 migrates each
    /// member to a policy-chosen target off the vacated devices,
    /// rolling every completed move back on the first failure so no
    /// partial relocation is ever observable.
    fn relocate_members(
        &self,
        members: &[VictimInfo],
        policy: PreemptPolicy,
        co_located: bool,
    ) -> Result<Vec<(VictimInfo, MigrationReport)>, SchedError> {
        let mut ordered: Vec<VictimInfo> = members.to_vec();
        ordered.sort_by_key(|m| (m.fpga, m.vfpga));
        // Phase 1: all quiesces or nothing (guards release on drop).
        let mut guards = Vec::with_capacity(ordered.len());
        for m in &ordered {
            match self.hv.try_quiesce_region(m.vfpga) {
                Some(g) => guards.push(g),
                None => return Err(SchedError::NoCapacity),
            }
        }
        // The vacated devices must end up net-free.
        let avoid: Vec<FpgaId> =
            ordered.iter().map(|m| m.fpga).collect();
        // A co-located gang must land co-located: pre-pick one device
        // with room for the whole gang and hand out its free regions
        // in order (a scattered multi-core design would be broken,
        // not relocated).
        let fixed_targets: Option<Vec<VfpgaId>> = if co_located
            && !ordered.is_empty()
        {
            let model = ordered[0].model;
            let rows: Vec<(FpgaId, Vec<VfpgaId>)> = {
                let db = self.hv.db.lock().unwrap();
                self.devices
                    .iter()
                    .filter(|d| {
                        !avoid.contains(&d.fpga)
                            && d.models.contains(&model)
                    })
                    .map(|d| (d.fpga, db.free_regions(d.fpga)))
                    .filter(|(_, free)| free.len() >= ordered.len())
                    .collect()
            };
            let Some(first) = choose_target(policy, &rows) else {
                return Err(SchedError::NoCapacity);
            };
            let row = rows
                .into_iter()
                .find(|(_, free)| free.contains(&first))
                .expect("chosen target came from these rows");
            Some(row.1.into_iter().take(ordered.len()).collect())
        } else {
            None
        };
        // Phase 2: migrate under the held guards.
        let mut done: Vec<(VictimInfo, MigrationReport)> = Vec::new();
        for (i, (m, guard)) in
            ordered.iter().zip(guards).enumerate()
        {
            let target = match &fixed_targets {
                Some(targets) => Some(targets[i]),
                None => {
                    self.preempt_target_locked(policy, m.model, &avoid)
                }
            };
            let Some(target) = target else {
                self.rollback_relocations(&done);
                return Err(SchedError::NoCapacity);
            };
            match self.hv.migrate_quiesced(
                m.alloc,
                m.user,
                Some(target),
                guard,
            ) {
                Ok(report) => done.push((m.clone(), report)),
                Err(e) => {
                    log::debug!(
                        "gang member {} not movable: {e}",
                        m.alloc
                    );
                    self.rollback_relocations(&done);
                    return Err(SchedError::NoCapacity);
                }
            }
        }
        Ok(done)
    }

    /// Best-effort rollback of a partial gang relocation: move the
    /// already-relocated members home, newest first. Quiesce
    /// acquisition is bounded (the caller holds the scheduler state
    /// lock — parking it on an arbitrary-length stream pin would
    /// stall every admission); a member whose quiesce never frees up
    /// stays at its new — still valid — placement, logged loudly.
    fn rollback_relocations(
        &self,
        done: &[(VictimInfo, MigrationReport)],
    ) {
        for (m, report) in done.iter().rev() {
            let mut guard = None;
            for _ in 0..256 {
                match self.hv.try_quiesce_region(report.to) {
                    Some(g) => {
                        guard = Some(g);
                        break;
                    }
                    None => std::thread::yield_now(),
                }
            }
            let Some(guard) = guard else {
                log::warn!(
                    "gang rollback of {} skipped: {} stayed pinned; \
                     the member remains at its new placement",
                    m.alloc,
                    report.to
                );
                continue;
            };
            if let Err(e) = self.hv.migrate_quiesced(
                m.alloc,
                m.user,
                Some(report.from),
                guard,
            ) {
                log::warn!(
                    "gang rollback of {} to {} failed: {e}",
                    m.alloc,
                    report.from
                );
            }
        }
    }

    /// Atomically relocate every member of a lease (gang or single)
    /// to new regions — two-phase quiesce in the fixed
    /// `(fpga, vfpga)` order, all-or-nothing. Operator surface for
    /// draining a device; preemption uses the same machinery
    /// internally. The lease and its token survive; only placements
    /// change (and the members' migration counters advance).
    pub fn relocate_gang(
        &self,
        token: LeaseToken,
    ) -> Result<Vec<MigrationReport>, SchedError> {
        let policy = self.preempt_policy();
        let mut st = self.state.lock().unwrap();
        let meta = st
            .leases
            .get(&token)
            .cloned()
            .ok_or(SchedError::UnknownLease)?;
        let mut members = Vec::with_capacity(meta.members.len());
        for alloc in &meta.members {
            let g = st
                .grants
                .get(alloc)
                .ok_or(SchedError::UnknownGrant(*alloc))?;
            match g.target {
                GrantTarget::Vfpga(v, f, _) => members.push(VictimInfo {
                    alloc: g.alloc,
                    user: g.user,
                    class: g.class,
                    model: g.model,
                    vfpga: v,
                    fpga: f,
                    started_ns: g.started_ns,
                }),
                GrantTarget::Physical(_, _) => {
                    return Err(SchedError::Unsatisfiable(
                        "physical leases do not relocate".to_string(),
                    ))
                }
            }
        }
        let done =
            self.relocate_members(&members, policy, meta.co_located)?;
        for (m, report) in &done {
            self.rebind_grant_locked(&mut st, m.alloc, report.to);
        }
        self.update_gauges_locked(&st);
        Ok(done.into_iter().map(|(_, r)| r).collect())
    }

    /// Grant queued requests while capacity and quotas allow,
    /// fair-share order. Tenants at quota are skipped; budget-
    /// exhausted requests fail terminally.
    fn pump_locked(&self, st: &mut SchedState) {
        self.reap_locked(st);
        // Budget exhaustion never recovers: fail those tickets now.
        // (Skipped entirely while no tenant has a budget configured —
        // the common case.)
        if st.quotas.has_budgets() {
            let scan_now_ns = self.hv.clock.now().0;
            let terminal: Vec<(TicketId, QuotaDenial)> = st
                .queue
                .snapshot()
                .into_iter()
                .filter_map(|e| {
                    match st.quotas.admissible(
                        e.user,
                        e.regions,
                        used_device_seconds(
                            &st.ledger,
                            &st.grants,
                            e.user,
                            scan_now_ns,
                        ),
                    ) {
                        Err(d @ QuotaDenial::Budget { .. }) => {
                            Some((e.ticket, d))
                        }
                        _ => None,
                    }
                })
                .collect();
            for (ticket, denial) in terminal {
                st.queue.remove(ticket);
                self.wal_append_locked(&WalRecord::Dequeue { ticket });
                st.ready.insert(ticket, Err(self.deny(denial)));
            }
        }
        // A queued gang wider than its tenant's concurrency cap can
        // never admit however much is released — fail it terminally
        // (covers caps lowered after enqueue; enqueue_locked already
        // rejects the common case up front).
        if !st.queue.is_empty() {
            let oversized: Vec<(TicketId, u64, u64)> = st
                .queue
                .snapshot()
                .into_iter()
                .filter_map(|e| {
                    let cap = st.quotas.quota(e.user).max_concurrent;
                    (e.regions > cap)
                        .then_some((e.ticket, e.regions, cap))
                })
                .collect();
            for (ticket, regions, cap) in oversized {
                st.queue.remove(ticket);
                self.wal_append_locked(&WalRecord::Dequeue { ticket });
                st.ready.insert(
                    ticket,
                    Err(SchedError::Unsatisfiable(format!(
                        "gang of {regions} exceeds the tenant's \
                         concurrency quota of {cap}"
                    ))),
                );
            }
        }
        loop {
            let now_ns = self.hv.clock.now().0;
            // Snapshot physical free counts once per iteration (they
            // only change when a grant lands) so the pop predicate
            // does not lock the device DB per queued entry.
            let free_by_device: Vec<u64> = {
                let db = self.hv.db.lock().unwrap();
                self.devices
                    .iter()
                    .map(|d| db.free_regions(d.fpga).len() as u64)
                    .collect()
            };
            let popped = {
                let SchedState {
                    queue,
                    quotas,
                    reservations,
                    ledger,
                    grants,
                    ..
                } = st;
                let quotas_ro: &QuotaBook = quotas;
                let reservations_ro: &ReservationBook = reservations;
                let ledger_ro: &UsageLedger = ledger;
                let grants_ro: &BTreeMap<AllocationId, SchedGrant> = grants;
                // Does the entry's whole shape fit free capacity:
                // enough matching free regions after model-aware
                // withholdings, on one device if co-located?
                let fits = |e: &QueueEntry| -> bool {
                    let mut free = 0u64;
                    let mut best_single = 0u64;
                    for (i, d) in self.devices.iter().enumerate() {
                        if d.matches(e.model, e.board) {
                            free += free_by_device[i];
                            best_single =
                                best_single.max(free_by_device[i]);
                        }
                    }
                    let withheld = reservations_ro.withheld_from(
                        e.user,
                        now_ns,
                        |rm| self.models_share_device(rm, e.model),
                    );
                    free.saturating_sub(withheld) >= e.regions
                        && (!e.co_located || best_single >= e.regions)
                };
                queue.pop_best(
                    now_ns,
                    |u| quotas_ro.weight(u),
                    |e| {
                        quotas_ro
                            .admissible(
                                e.user,
                                e.regions,
                                used_device_seconds(
                                    ledger_ro, grants_ro, e.user, now_ns,
                                ),
                            )
                            .is_ok()
                            && fits(e)
                    },
                )
            };
            let Some(entry) = popped else {
                // Nothing admits into free capacity — but a queued
                // interactive request may still land by preempting a
                // batch lease, exactly like the fast path does.
                if self.pump_preempt_locked(st) {
                    continue;
                }
                break;
            };
            let spec = AdmitSpec::of_entry(&entry);
            match self.try_admit_locked(st, &spec) {
                Ok(token) => {
                    self.wal_append_locked(&WalRecord::Dequeue {
                        ticket: entry.ticket,
                    });
                    st.ready.insert(entry.ticket, Ok(token));
                }
                Err(SchedError::NoCapacity)
                | Err(SchedError::QuotaConcurrency(_)) => {
                    // Raced with an out-of-band allocation (or the
                    // per-member claims disagreed with the snapshot):
                    // put the entry back unchanged (refunding the
                    // fair-share pass charge pop_best took) and stop
                    // pumping.
                    let weight = st.quotas.weight(entry.user);
                    st.queue.refund(entry.user, weight);
                    st.queue.requeue(entry);
                    break;
                }
                Err(e) => {
                    // Terminal failure: refund the fair-share charge
                    // (the tenant received nothing) and fail the
                    // ticket.
                    let weight = st.quotas.weight(entry.user);
                    st.queue.refund(entry.user, weight);
                    self.wal_append_locked(&WalRecord::Dequeue {
                        ticket: entry.ticket,
                    });
                    st.ready.insert(entry.ticket, Err(e));
                }
            }
        }
        self.update_gauges_locked(st);
    }

    /// Preempt on behalf of the first queued interactive request
    /// whose tenant quota admits and whose model's devices are
    /// physically full. Returns true when a victim was relocated (the
    /// pump loop then re-runs and the interactive entry wins the pop
    /// by class).
    fn pump_preempt_locked(&self, st: &mut SchedState) -> bool {
        let now_ns = self.hv.clock.now().0;
        let mut candidates: Vec<QueueEntry> = st
            .queue
            .snapshot()
            .into_iter()
            // Only genuinely-interactive single-region entries earn a
            // preemption — aging promotes queue *order*, not the
            // right to migrate someone else's lease, and gangs never
            // preempt.
            .filter(|e| {
                e.class == RequestClass::Interactive && e.regions == 1
            })
            .filter(|e| {
                st.quotas
                    .admissible(
                        e.user,
                        1,
                        used_device_seconds(
                            &st.ledger,
                            &st.grants,
                            e.user,
                            now_ns,
                        ),
                    )
                    .is_ok()
            })
            .collect();
        candidates.sort_by_key(|e| e.seq);
        for entry in candidates {
            if self.raw_free(entry.model, entry.board) > 0
                || self.withheld_for(st, entry.user, entry.model, now_ns)
                    > 0
            {
                // Capacity exists but is reservation-withheld, or the
                // vacated region would be owed to a reservation
                // holder; migrating a victim cannot help this entry
                // (see try_admit_locked) — but a later queued
                // interactive entry for another model still might.
                continue;
            }
            if self.try_preempt_locked(
                st,
                entry.user,
                entry.model,
                entry.class,
            ) {
                return true;
            }
        }
        false
    }

    fn update_gauges_locked(&self, st: &SchedState) {
        let depth = st.queue.len() as i64;
        self.hv.metrics.gauge("sched.queue.depth").set(depth);
        self.hv
            .metrics
            .gauge("sched.active_grants")
            .set(st.grants.len() as i64);
        // Queue-depth events fire on change only (the gauges refresh
        // far more often than the depth moves).
        if self.last_queue_depth.swap(depth, Ordering::SeqCst) != depth {
            self.emit(SchedEvent::QueueDepth {
                depth: depth as u64,
            });
        }
    }

    // ------------------------------------------------------- status

    /// Queue/quota/reservation snapshot for the `sched_status` RPC.
    pub fn status_json(&self) -> Json {
        let now_ns = self.hv.clock.now().0;
        let st = self.state.lock().unwrap();
        let entries = st.queue.snapshot();
        let per_class = |c: RequestClass| {
            entries.iter().filter(|e| e.class == c).count()
        };
        let mut tenants: BTreeMap<UserId, u64> = BTreeMap::new();
        for e in &entries {
            *tenants.entry(e.user).or_insert(0) += 1;
        }
        Json::obj(vec![
            ("queue_depth", Json::from(entries.len())),
            (
                "queued_interactive",
                Json::from(per_class(RequestClass::Interactive)),
            ),
            (
                "queued_normal",
                Json::from(per_class(RequestClass::Normal)),
            ),
            ("queued_batch", Json::from(per_class(RequestClass::Batch))),
            ("active_grants", Json::from(st.grants.len())),
            ("active_leases", Json::from(st.leases.len())),
            (
                "queued_by_tenant",
                Json::Obj(
                    tenants
                        .iter()
                        .map(|(u, n)| (u.to_string(), Json::from(*n)))
                        .collect(),
                ),
            ),
            (
                "reservations",
                Json::Arr(
                    st.reservations
                        .snapshot(now_ns)
                        .into_iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::from(r.id.to_string())),
                                ("user", Json::from(r.user.to_string())),
                                ("regions", Json::from(r.regions)),
                                ("claimed", Json::from(r.claimed)),
                                (
                                    "model",
                                    match r.model {
                                        Some(m) => Json::from(m.name()),
                                        None => Json::Null,
                                    },
                                ),
                                (
                                    "start_s",
                                    Json::from(
                                        VirtualTime(r.start_ns)
                                            .as_secs_f64(),
                                    ),
                                ),
                                (
                                    "end_s",
                                    Json::from(
                                        VirtualTime(r.end_ns).as_secs_f64(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Operator usage table (CLI `rc3e usage`).
    pub fn usage_report(&self) -> String {
        let names: BTreeMap<UserId, String> = {
            let db = self.hv.db.lock().unwrap();
            db.users
                .iter()
                .map(|(id, name)| (*id, name.clone()))
                .collect()
        };
        self.state.lock().unwrap().ledger.report(&names)
    }

    /// Usage rows for the `usage_report` RPC.
    pub fn usage_json(&self) -> Json {
        self.state.lock().unwrap().ledger.to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, FpgaConfig, NodeConfig};
    use crate::hypervisor::PlacementPolicy;
    use crate::util::clock::VirtualClock;

    fn sched() -> Arc<Scheduler> {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        Scheduler::new(hv)
    }

    fn sched_on(config: &ClusterConfig) -> Arc<Scheduler> {
        let hv = Arc::new(
            Hypervisor::boot(
                config,
                VirtualClock::new(),
                PlacementPolicy::ConsolidateFirst,
            )
            .unwrap(),
        );
        Scheduler::new(hv)
    }

    fn one(
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> AdmissionRequest {
        AdmissionRequest::new(user, model, class)
    }

    #[test]
    fn admit_and_release_roundtrip() {
        let s = sched();
        let user = s.hv().add_user("alice");
        let lease = s
            .admit(&one(user, ServiceModel::RAaaS, RequestClass::Interactive))
            .unwrap();
        assert_eq!(s.in_use(user), 1);
        assert!(lease.vfpga().is_some());
        assert_eq!(lease.regions(), 1);
        let alloc = lease.alloc();
        lease.release().unwrap();
        assert_eq!(s.in_use(user), 0);
        assert_eq!(s.usage(user).released, 1);
        assert!(s.usage(user).device_seconds >= 0.0);
        // Releasing a dead member is an UnknownGrant error.
        assert!(matches!(
            s.release(alloc),
            Err(SchedError::UnknownGrant(_))
        ));
    }

    #[test]
    fn concurrency_quota_blocks_fast_path() {
        let s = sched();
        let user = s.hv().add_user("bounded");
        s.set_quota(
            user,
            TenantQuota {
                max_concurrent: 2,
                ..TenantQuota::default()
            },
        );
        let g0 = s
            .admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        let _g1 = s
            .admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        assert!(matches!(
            s.admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal)),
            Err(SchedError::QuotaConcurrency(_))
        ));
        g0.release().unwrap();
        assert!(s
            .admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal))
            .is_ok());
    }

    #[test]
    fn gang_counts_whole_gang_against_quota() {
        let s = sched();
        let user = s.hv().add_user("capped");
        s.set_quota(
            user,
            TenantQuota {
                max_concurrent: 2,
                ..TenantQuota::default()
            },
        );
        // A 3-gang is 3 units at once — denied even with 16 free
        // regions.
        assert!(matches!(
            s.admit(
                &one(user, ServiceModel::RAaaS, RequestClass::Normal)
                    .gang(3)
            ),
            Err(SchedError::QuotaConcurrency(_))
        ));
        let gang = s
            .admit(
                &one(user, ServiceModel::RAaaS, RequestClass::Normal)
                    .gang(2),
            )
            .unwrap();
        assert_eq!(s.in_use(user), 2);
        gang.release().unwrap();
        assert_eq!(s.in_use(user), 0);
    }

    #[test]
    fn budget_quota_is_terminal() {
        let s = sched();
        let user = s.hv().add_user("broke");
        s.set_quota(
            user,
            TenantQuota {
                device_seconds_budget: Some(10.0),
                ..TenantQuota::default()
            },
        );
        let g = s
            .admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        // Hold the lease for 60 virtual seconds — way over budget.
        s.hv().clock.advance(VirtualTime::from_secs_f64(60.0));
        g.release().unwrap();
        assert!(s.usage(user).device_seconds > 10.0);
        assert!(matches!(
            s.admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal)),
            Err(SchedError::QuotaBudget(_))
        ));
    }

    #[test]
    fn queue_grants_on_release_in_fair_order() {
        let s = sched();
        let users: Vec<UserId> =
            (0..4).map(|i| s.hv().add_user(&format!("u{i}"))).collect();
        // Fill all 16 regions with user 0.
        let mut held = Vec::new();
        for _ in 0..16 {
            held.push(
                s.admit(&one(
                    users[0],
                    ServiceModel::RAaaS,
                    RequestClass::Normal,
                ))
                .unwrap(),
            );
        }
        // Queue one request per other tenant.
        let tickets: Vec<TicketId> = users[1..]
            .iter()
            .map(|u| {
                s.enqueue(&one(
                    *u,
                    ServiceModel::RAaaS,
                    RequestClass::Batch,
                ))
            })
            .collect();
        assert!(s.poll_ticket(tickets[0]).is_none());
        // Three releases admit all three queued tenants (leases drop
        // on drain, which releases them through the scheduler).
        held.drain(..3);
        for t in &tickets {
            let res = s.poll_ticket(*t).expect("granted after release");
            assert!(res.is_ok());
        }
    }

    #[test]
    fn gang_admission_is_atomic_all_or_nothing() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let u = s.hv().add_user("gang");
        let other = s.hv().add_user("other");
        let gang = s
            .admit(&one(u, ServiceModel::RAaaS, RequestClass::Normal).gang(3))
            .unwrap();
        assert_eq!(gang.regions(), 3);
        assert_eq!(s.in_use(u), 3);
        assert_eq!(gang.placements().len(), 3);
        // One region left: a 2-gang must not partially grant.
        assert!(matches!(
            s.admit(
                &one(other, ServiceModel::RAaaS, RequestClass::Normal)
                    .gang(2)
            ),
            Err(SchedError::NoCapacity)
        ));
        assert_eq!(s.in_use(other), 0, "no partial grant observable");
        // A single still fits the leftover region.
        let single = s
            .admit(&one(other, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        single.release().unwrap();
        gang.release().unwrap();
        assert_eq!(s.in_use(u), 0);
        // Whole-device gang once everything is free.
        let all = s
            .admit(&one(u, ServiceModel::RAaaS, RequestClass::Normal).gang(4))
            .unwrap();
        assert_eq!(all.placements().len(), 4);
        all.release().unwrap();
    }

    #[test]
    fn gang_queues_until_enough_capacity_frees() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        let b = s.hv().add_user("b");
        let mut held: Vec<Lease> = (0..4)
            .map(|_| {
                s.admit(&one(a, ServiceModel::RAaaS, RequestClass::Normal))
                    .unwrap()
            })
            .collect();
        let t = s.enqueue(
            &one(b, ServiceModel::RAaaS, RequestClass::Batch).gang(2),
        );
        assert!(s.poll_ticket(t).is_none());
        // One freed region is not enough for the 2-gang.
        held.pop().unwrap().release().unwrap();
        assert!(s.poll_ticket(t).is_none(), "2-gang must not half-grant");
        held.pop().unwrap().release().unwrap();
        let lease = s
            .poll_ticket(t)
            .expect("2-gang granted once 2 regions free")
            .unwrap();
        assert_eq!(lease.regions(), 2);
        assert_eq!(lease.tenant(), b);
        lease.release().unwrap();
    }

    #[test]
    fn impossible_requests_fail_terminally_not_queue_forever() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let u = s.hv().add_user("dreamer");
        // 5 regions on a 4-region cluster can never be granted.
        let t = s.enqueue(
            &one(u, ServiceModel::RAaaS, RequestClass::Batch).gang(5),
        );
        assert!(matches!(
            s.poll_ticket(t),
            Some(Err(SchedError::Unsatisfiable(_)))
        ));
        // Physical requests do not queue either.
        let t2 = s.enqueue(&AdmissionRequest::physical(
            u,
            RequestClass::Interactive,
        ));
        assert!(matches!(
            s.poll_ticket(t2),
            Some(Err(SchedError::Unsatisfiable(_)))
        ));
        // A gang larger than one device cannot be co-located.
        let t3 = s.enqueue(
            &one(u, ServiceModel::RAaaS, RequestClass::Batch)
                .gang(4)
                .co_located(),
        );
        assert!(s.poll_ticket(t3).expect("resolved").is_ok());
        // A gang wider than the tenant's concurrency cap can never
        // admit — terminal error, not an eternal queue entry.
        s.set_quota(
            u,
            TenantQuota {
                max_concurrent: 2,
                ..TenantQuota::default()
            },
        );
        let t4 = s.enqueue(
            &one(u, ServiceModel::RAaaS, RequestClass::Batch).gang(3),
        );
        assert!(matches!(
            s.poll_ticket(t4),
            Some(Err(SchedError::Unsatisfiable(_)))
        ));
    }

    #[test]
    fn co_located_gang_lands_on_one_device() {
        // sched_testbed: fpga-0 (RAaaS+BAaaS) + fpga-1 (BAaaS only).
        let s = sched_on(&ClusterConfig::sched_testbed());
        let u = s.hv().add_user("multicore");
        // Take 2 regions on fpga-0 so a spread gang would straddle.
        let pins: Vec<Lease> = (0..2)
            .map(|_| {
                s.admit(&one(u, ServiceModel::BAaaS, RequestClass::Normal))
                    .unwrap()
            })
            .collect();
        let gang = s
            .admit(
                &one(u, ServiceModel::BAaaS, RequestClass::Normal)
                    .gang(3)
                    .co_located(),
            )
            .unwrap();
        let fpgas: std::collections::BTreeSet<FpgaId> = gang
            .placements()
            .iter()
            .map(|p| match p.target {
                GrantTarget::Vfpga(_, f, _)
                | GrantTarget::Physical(f, _) => f,
            })
            .collect();
        assert_eq!(fpgas.len(), 1, "co-located gang split across devices");
        assert_eq!(fpgas.into_iter().next(), Some(FpgaId(1)));
        gang.release().unwrap();
        drop(pins);
    }

    #[test]
    fn board_constraint_restricts_devices() {
        // paper_testbed: fpga-0/1 are VC707, fpga-2/3 are ML605.
        let s = sched();
        let u = s.hv().add_user("picky");
        let lease = s
            .admit(
                &one(u, ServiceModel::RAaaS, RequestClass::Normal)
                    .on_board(BoardKind::Ml605),
            )
            .unwrap();
        assert_eq!(lease.fpga(), Some(FpgaId(2)));
        lease.release().unwrap();
    }

    #[test]
    fn interactive_preempts_batch_via_migration() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let batcher = s.hv().add_user("batcher");
        let vip = s.hv().add_user("vip");
        // Fill the RAaaS-capable device (fpga-0, consolidate-first
        // packs it first) with programmed batch leases; the BAaaS-only
        // device keeps free regions.
        let batch_grants = crate::testing::fill_batch_leases(&s, batcher, 4);
        // All four batch leases landed on the RAaaS-capable device.
        assert!(batch_grants.iter().all(|g| g.fpga() == FpgaId(0)));
        // A batch-class RAaaS request has no free RAaaS region —
        // without preemption this is NoCapacity.
        assert!(matches!(
            s.admit(&one(vip, ServiceModel::RAaaS, RequestClass::Batch)),
            Err(SchedError::NoCapacity)
        ));
        // Interactive class preempts: one batch lease migrates to the
        // BAaaS-only device and the vip lands on fpga-0.
        let g = s
            .admit(&one(vip, ServiceModel::RAaaS, RequestClass::Interactive))
            .unwrap();
        assert_eq!(g.fpga(), Some(FpgaId(0)));
        assert_eq!(s.hv().metrics.counter("sched.preemptions").get(), 1);
        assert_eq!(s.usage(batcher).preempted, 1);
        // The victim's grant now points at the other device, counted
        // a migration, and is still releasable.
        let moved = s
            .active_grants()
            .into_iter()
            .filter(|g| g.user == batcher)
            .find(|g| g.fpga() != FpgaId(0))
            .expect("one batch lease migrated");
        assert_eq!(moved.migrations, 1);
        s.release(moved.alloc).unwrap();
    }

    #[test]
    fn preemption_downtime_charged_to_preemptor() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let batcher = s.hv().add_user("batcher");
        let vip = s.hv().add_user("vip");
        // Fill the RAaaS-capable device with programmed batch leases
        // so the vip's interactive request must preempt.
        let _grants = crate::testing::fill_batch_leases(&s, batcher, 4);
        let _g = s
            .admit(&one(vip, ServiceModel::RAaaS, RequestClass::Interactive))
            .unwrap();
        // The migration outage lands on the preemptor's bill...
        let vip_row = s.usage(vip);
        assert!(
            vip_row.preempt_downtime_s > 0.0,
            "preemptor not charged: {vip_row:?}"
        );
        assert!(vip_row.device_seconds >= vip_row.preempt_downtime_s);
        assert!(vip_row.energy_joules > 0.0);
        // ...and not on the victim's.
        let batcher_row = s.usage(batcher);
        assert_eq!(batcher_row.preempted, 1);
        assert_eq!(batcher_row.preempt_downtime_s, 0.0);
        // The victim's accrual clock skipped the outage: its grant
        // now starts at (or after) the pre-preemption timestamps.
        let moved = s
            .active_grants()
            .into_iter()
            .filter(|g| g.user == batcher)
            .max_by_key(|g| g.started_ns)
            .unwrap();
        assert!(moved.started_ns <= s.hv().clock.now().0);
    }

    #[test]
    fn persistence_survives_restart() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e-sched-persist-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let db_path = dir.join("devices.json");
        let state_path = persist::sched_state_path(&db_path);
        let _ = std::fs::remove_file(&state_path);
        let _ = std::fs::remove_dir_all(persist::sched_wal_dir(&db_path));
        let user;
        {
            let s = sched();
            s.attach_persistence(&db_path).unwrap();
            user = s.hv().add_user("durable");
            s.set_quota(
                user,
                TenantQuota {
                    max_concurrent: 3,
                    device_seconds_budget: Some(500.0),
                    weight: 2,
                },
            );
            let g = s
                .admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal))
                .unwrap();
            s.hv().clock.advance(VirtualTime::from_secs_f64(5.0));
            g.release().unwrap();
        }
        assert!(state_path.exists());
        // "Restart": a fresh hypervisor + scheduler reload the
        // accounting from disk.
        let s2 = Scheduler::new_persistent(
            Arc::new(
                Hypervisor::boot_paper_testbed(VirtualClock::new())
                    .unwrap(),
            ),
            &db_path,
        )
        .unwrap();
        let q = s2.quota(user);
        assert_eq!(q.max_concurrent, 3);
        assert_eq!(q.device_seconds_budget, Some(500.0));
        assert_eq!(q.weight, 2);
        let usage = s2.usage(user);
        assert_eq!(usage.released, 1);
        assert!(usage.device_seconds >= 5.0, "{usage:?}");
        std::fs::remove_file(&state_path).unwrap();
        let _ = std::fs::remove_dir_all(persist::sched_wal_dir(&db_path));
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn recovery_readopts_live_leases_and_queue() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e-sched-recover-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let db_path = dir.join("devices.json");
        let (user, token, ticket);
        {
            let s = sched();
            s.attach_persistence(&db_path).unwrap();
            user = s.hv().add_user("alice");
            s.set_quota(
                user,
                TenantQuota {
                    max_concurrent: 2,
                    ..TenantQuota::default()
                },
            );
            // A live gang of 2 fills the quota...
            let lease = s
                .admit(
                    &one(user, ServiceModel::RAaaS, RequestClass::Normal)
                        .gang(2),
                )
                .unwrap();
            // ...so this one queues behind it.
            ticket = s.enqueue(&one(
                user,
                ServiceModel::RAaaS,
                RequestClass::Normal,
            ));
            assert!(s.poll_ticket(ticket).is_none());
            // "Crash": the process dies holding the lease (into_token
            // disarms the drop-release).
            token = lease.into_token();
        }
        // Second life: fresh hypervisor + scheduler over the same
        // state dir. The same tenant name yields the same UserId.
        let hv2 = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        assert_eq!(hv2.add_user("alice"), user);
        let s2 = Scheduler::new_persistent(hv2, &db_path).unwrap();
        // The pre-crash token still validates and the gang is whole.
        let handle = s2.lease_handle(token).expect("lease re-adopted");
        assert_eq!(handle.regions(), 2);
        assert_eq!(s2.in_use(user), 2);
        assert_eq!(s2.active_grants().len(), 2);
        // The placements are real again: the hypervisor DB owns them.
        for g in s2.active_grants() {
            assert!(s2.hv().db.lock().unwrap().allocation(g.alloc).is_some());
        }
        // The queued admission survived and resolves once capacity
        // frees up.
        assert!(s2.poll_ticket(ticket).is_none());
        s2.release_token(token).unwrap();
        let waited = s2.poll_ticket(ticket).expect("ticket resolved");
        let granted = waited.unwrap();
        assert_eq!(granted.tenant(), user);
        granted.release().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reservation_withholds_capacity_until_expiry() {
        // Single device, 4 regions.
        let s = sched_on(&ClusterConfig::single_vc707());
        let holder = s.hv().add_user("holder");
        let other = s.hv().add_user("other");
        let now = s.hv().clock.now();
        s.reserve(holder, 2, None, now, VirtualTime::from_secs_f64(100.0));
        // Other tenant can only take the 2 unreserved regions.
        let _a = s
            .admit(&one(other, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        let _b = s
            .admit(&one(other, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        assert!(matches!(
            s.admit(&one(other, ServiceModel::RAaaS, RequestClass::Normal)),
            Err(SchedError::NoCapacity)
        ));
        // The holder draws from its reservation.
        let _h = s
            .admit(&one(holder, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        // Window expires: remaining reserved capacity is reclaimed.
        s.hv().clock.advance(VirtualTime::from_secs_f64(200.0));
        assert!(s
            .admit(&one(other, ServiceModel::RAaaS, RequestClass::Normal))
            .is_ok());
        assert_eq!(
            s.hv().metrics.counter("sched.reservations.expired").get(),
            1
        );
    }

    #[test]
    fn model_pinned_reservation_spares_disjoint_models() {
        // Two devices with disjoint model sets: reserving the RAaaS
        // pool must not wall off the BAaaS-only device (the ROADMAP's
        // heterogeneous-config complaint).
        let config = ClusterConfig {
            nodes: vec![NodeConfig {
                name: "n".to_string(),
                fpgas: vec![
                    FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 4,
                        models: vec![ServiceModel::RAaaS],
                    },
                    FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 4,
                        models: vec![ServiceModel::BAaaS],
                    },
                ],
            }],
            require_signatures: false,
            rpc_overhead_ms: 69.0,
        };
        let s = sched_on(&config);
        let holder = s.hv().add_user("holder");
        let other = s.hv().add_user("other");
        let now = s.hv().clock.now();
        // Over-ask clamps to the model's own pool (4), not the
        // cluster (8).
        s.reserve(
            holder,
            99,
            Some(ServiceModel::RAaaS),
            now,
            VirtualTime::from_secs_f64(100.0),
        );
        let status = s.status_json();
        let rsv = &status.get("reservations").as_arr().unwrap()[0];
        assert_eq!(rsv.get("regions").as_u64(), Some(4));
        assert_eq!(rsv.get("model").as_str(), Some("raaas"));
        // RAaaS capacity is fully withheld from others...
        assert!(matches!(
            s.admit(&one(other, ServiceModel::RAaaS, RequestClass::Normal)),
            Err(SchedError::NoCapacity)
        ));
        // ...but the disjoint BAaaS pool stays usable.
        let l = s
            .admit(&one(other, ServiceModel::BAaaS, RequestClass::Normal))
            .unwrap();
        l.release().unwrap();
        // The holder draws down its own pinned reservation.
        let h = s
            .admit(&one(holder, ServiceModel::RAaaS, RequestClass::Normal))
            .unwrap();
        h.release().unwrap();
    }

    #[test]
    fn blocking_admit_waits_for_release() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        let b = s.hv().add_user("b");
        let mut held = Vec::new();
        for _ in 0..4 {
            held.push(
                s.admit(&one(a, ServiceModel::RAaaS, RequestClass::Normal))
                    .unwrap(),
            );
        }
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || {
            s2.admit_blocking(&one(
                b,
                ServiceModel::RAaaS,
                RequestClass::Batch,
            ))
        });
        // Give the waiter time to enqueue, then free a region.
        while s.hv().metrics.counter("sched.enqueued").get() == 0 {
            std::thread::yield_now();
        }
        held.pop().unwrap().release().unwrap();
        let lease = waiter.join().unwrap().unwrap();
        assert_eq!(lease.tenant(), b);
        lease.release().unwrap();
    }

    #[test]
    fn cancel_resolves_waiters() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        let b = s.hv().add_user("b");
        let mut held = Vec::new();
        for _ in 0..4 {
            held.push(
                s.admit(&one(a, ServiceModel::RAaaS, RequestClass::Normal))
                    .unwrap(),
            );
        }
        let t = s.enqueue(&one(b, ServiceModel::RAaaS, RequestClass::Batch));
        assert!(s.cancel_ticket(t));
        assert!(matches!(
            s.wait_ticket(t),
            Err(SchedError::Cancelled)
        ));
        assert!(!s.cancel_ticket(t));
    }

    #[test]
    fn lease_tokens_gate_member_operations() {
        let s = sched();
        let user = s.hv().add_user("cap");
        let lease = s
            .admit(&one(user, ServiceModel::RAaaS, RequestClass::Normal).gang(2))
            .unwrap();
        let token = lease.token();
        let second = lease.members()[1];
        // The real token owns every member.
        assert!(s.verify_member(token, lease.alloc()).is_ok());
        assert!(s.verify_member(token, second).is_ok());
        // A forged token is UnknownLease on a live grant...
        assert!(matches!(
            s.verify_member(LeaseToken(0xBAD), lease.alloc()),
            Err(SchedError::UnknownLease)
        ));
        // ...and a dead allocation is UnknownGrant whatever the token.
        assert!(matches!(
            s.verify_member(token, AllocationId(9_999)),
            Err(SchedError::UnknownGrant(_))
        ));
        // release_token tears down the whole gang.
        s.release_token(token).unwrap();
        assert_eq!(s.in_use(user), 0);
        assert!(matches!(
            s.release_token(token),
            Err(SchedError::UnknownLease)
        ));
        assert!(s.lease_handle(token).is_none());
        let _keepalive = lease.into_token();
    }

    #[test]
    fn pinned_victims_are_skipped_never_raced() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let batcher = s.hv().add_user("batcher");
        let vip = s.hv().add_user("vip");
        let grants = crate::testing::fill_batch_leases(&s, batcher, 4);
        // Pin every victim region: all of them are mid-"setup" as far
        // as the quiesce layer is concerned.
        let mut pins: Vec<_> = grants
            .iter()
            .map(|g| s.hv().guards().pin(g.vfpga().unwrap()))
            .collect();
        // No quiescable victim -> the interactive request fails fast
        // instead of racing anyone.
        assert!(matches!(
            s.admit(&one(vip, ServiceModel::RAaaS, RequestClass::Interactive)),
            Err(SchedError::NoCapacity)
        ));
        assert_eq!(s.hv().metrics.counter("sched.preemptions").get(), 0);
        // Unpin one region: exactly that victim is now displaceable.
        let free_region = pins[2].region();
        drop(pins.remove(2));
        let g = s
            .admit(&one(vip, ServiceModel::RAaaS, RequestClass::Interactive))
            .unwrap();
        assert_eq!(g.vfpga(), Some(free_region));
        assert_eq!(s.hv().metrics.counter("sched.preemptions").get(), 1);
        assert_eq!(
            s.hv().metrics.counter("sched.preempt.raced").get(),
            0,
            "quiesce makes the setup race structurally impossible"
        );
    }

    #[test]
    fn gang_victims_relocate_atomically() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let batcher = s.hv().add_user("batcher");
        let vip = s.hv().add_user("vip");
        // One 4-member batch gang fills the RAaaS-capable device.
        let gang = s
            .admit(
                &one(batcher, ServiceModel::BAaaS, RequestClass::Batch)
                    .gang(4)
                    .co_located(),
            )
            .unwrap();
        assert!(gang
            .placements()
            .iter()
            .all(|p| matches!(p.target, GrantTarget::Vfpga(_, f, _) if f == FpgaId(0))));
        for i in 0..4 {
            gang.program_member(i, &crate::testing::mm16_partial(0))
                .unwrap();
        }
        let token = gang.into_token();
        // No single victim exists (all grants belong to the gang), so
        // the interactive request relocates the whole gang to the
        // BAaaS-only device — atomically.
        let g = s
            .admit(&one(vip, ServiceModel::RAaaS, RequestClass::Interactive))
            .unwrap();
        assert_eq!(g.fpga(), Some(FpgaId(0)));
        assert_eq!(
            s.hv().metrics.counter("sched.preempt.gang").get(),
            1
        );
        let handle = s.lease_handle(token).expect("gang lease survives");
        let placements = handle.placements();
        assert_eq!(placements.len(), 4);
        assert!(
            placements.iter().all(|p| matches!(
                p.target,
                GrantTarget::Vfpga(_, f, _) if f == FpgaId(1)
            )),
            "all members moved together: {placements:?}"
        );
        assert_eq!(handle.migrations(), 4);
        assert_eq!(s.hv().metrics.counter("sched.preempt.raced").get(), 0);
    }

    #[test]
    fn preempt_policy_steers_victim_landing() {
        // Three devices: A serves RAaaS+BAaaS (the contended one),
        // B and C serve BAaaS only. B is left with fewer free
        // regions than C, so Pack lands the victim on B and Spread
        // on C.
        let config = || ClusterConfig {
            nodes: vec![NodeConfig {
                name: "n".to_string(),
                fpgas: vec![
                    FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 4,
                        models: vec![
                            ServiceModel::RAaaS,
                            ServiceModel::BAaaS,
                        ],
                    },
                    FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 2,
                        models: vec![ServiceModel::BAaaS],
                    },
                    FpgaConfig {
                        board: BoardKind::Vc707,
                        vfpgas: 4,
                        models: vec![ServiceModel::BAaaS],
                    },
                ],
            }],
            require_signatures: false,
            rpc_overhead_ms: 69.0,
        };
        let run = |policy: PreemptPolicy| -> FpgaId {
            let s = sched_on(&config());
            s.set_preempt_policy(policy);
            assert_eq!(s.preempt_policy(), policy);
            let batcher = s.hv().add_user("batcher");
            let vip = s.hv().add_user("vip");
            let _grants =
                crate::testing::fill_batch_leases(&s, batcher, 4);
            let _vip_lease = s
                .admit(&one(
                    vip,
                    ServiceModel::RAaaS,
                    RequestClass::Interactive,
                ))
                .unwrap();
            let moved = s
                .active_grants()
                .into_iter()
                .filter(|g| g.user == batcher)
                .find(|g| g.fpga() != FpgaId(0))
                .expect("one batch lease displaced");
            moved.fpga()
        };
        // Pack: fewest free regions (B = fpga-1, 2 regions).
        assert_eq!(run(PreemptPolicy::Pack), FpgaId(1));
        // Spread: most free regions (C = fpga-2, 4 regions).
        assert_eq!(run(PreemptPolicy::Spread), FpgaId(2));
    }

    #[test]
    fn relocate_gang_moves_every_member_or_none() {
        let s = sched_on(&ClusterConfig::sched_testbed());
        let u = s.hv().add_user("gang");
        let gang = s
            .admit(
                &one(u, ServiceModel::BAaaS, RequestClass::Normal)
                    .gang(2)
                    .co_located(),
            )
            .unwrap();
        for i in 0..2 {
            gang.program_member(i, &crate::testing::mm16_partial(0))
                .unwrap();
        }
        let before: Vec<_> = gang.placements();
        let token = gang.token();
        let reports = s.relocate_gang(token).unwrap();
        assert_eq!(reports.len(), 2);
        let after = s.lease_handle(token).unwrap().placements();
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(b.alloc, a.alloc);
            assert_ne!(b.target, a.target, "member did not move");
        }
        // Members stay programmed and the lease still releases whole.
        assert_eq!(s.in_use(u), 2);
        gang.release().unwrap();
        assert_eq!(s.in_use(u), 0);
        // A stale token no longer relocates.
        assert!(matches!(
            s.relocate_gang(token),
            Err(SchedError::UnknownLease)
        ));
    }

    #[test]
    fn status_json_reports_queue_shape() {
        let s = sched_on(&ClusterConfig::single_vc707());
        let a = s.hv().add_user("a");
        let mut held = Vec::new();
        for _ in 0..4 {
            held.push(
                s.admit(&one(a, ServiceModel::RAaaS, RequestClass::Normal))
                    .unwrap(),
            );
        }
        s.enqueue(&one(a, ServiceModel::RAaaS, RequestClass::Batch));
        s.reserve(
            a,
            1,
            None,
            s.hv().clock.now(),
            VirtualTime::from_secs_f64(10.0),
        );
        let j = s.status_json();
        assert_eq!(j.get("queue_depth").as_u64(), Some(1));
        assert_eq!(j.get("queued_batch").as_u64(), Some(1));
        assert_eq!(j.get("active_grants").as_u64(), Some(4));
        assert_eq!(j.get("active_leases").as_u64(), Some(4));
        assert_eq!(j.get("reservations").as_arr().unwrap().len(), 1);
        let report = s.usage_report();
        assert!(report.contains("tenant"), "{report}");
    }
}
