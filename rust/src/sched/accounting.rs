//! Per-tenant usage accounting: device-seconds and energy.
//!
//! Every release (and preemption) flows through the scheduler, which
//! charges the tenant's row here: lease duration in device-seconds
//! (vFPGA-equivalents × virtual seconds held) and the energy those
//! regions drew, priced from the board's per-region active power
//! ([`crate::fpga::power`] model). The ledger feeds three consumers:
//! the device-second *budget* check in [`super::quota`], the
//! `usage_report` middleware RPC, and the operator table rendered
//! with [`crate::util::table`].
//!
//! Preemption cost model: the migration outage a preemption causes is
//! billed to the *preemptor's* tenant
//! ([`UsageLedger::charge_preemption`]) — the victim's accrual clock
//! skips the downtime. The tenant whose interactive burst displaced a
//! batch lease pays for the displacement, not the tenant that was
//! displaced.
//!
//! The ledger serializes to/from JSON ([`UsageLedger::to_json`] /
//! [`UsageLedger::from_json`]) so accounting survives a
//! management-node restart (see [`super::persist`]).

use std::collections::BTreeMap;

use crate::util::ids::UserId;
use crate::util::json::Json;
use crate::util::table::Table;

/// One tenant's accumulated usage.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantUsage {
    /// Admissions granted (fast path + queue).
    pub granted: u64,
    /// Leases released back.
    pub released: u64,
    /// Times one of this tenant's leases was relocated by preemption.
    pub preempted: u64,
    /// Requests that went through the admission queue.
    pub queued: u64,
    /// Accumulated device-seconds (vFPGA-equivalents × seconds).
    pub device_seconds: f64,
    /// Accumulated energy in joules.
    pub energy_joules: f64,
    /// Longest admission wait seen (virtual ms).
    pub max_wait_ms: f64,
    /// Migration downtime this tenant *caused* by preempting others
    /// (device-seconds; also included in `device_seconds`).
    pub preempt_downtime_s: f64,
}

/// The usage ledger.
#[derive(Debug, Default)]
pub struct UsageLedger {
    rows: BTreeMap<UserId, TenantUsage>,
}

impl UsageLedger {
    pub fn new() -> UsageLedger {
        UsageLedger::default()
    }

    pub fn row_mut(&mut self, user: UserId) -> &mut TenantUsage {
        self.rows.entry(user).or_default()
    }

    pub fn usage(&self, user: UserId) -> TenantUsage {
        self.rows.get(&user).cloned().unwrap_or_default()
    }

    pub fn device_seconds(&self, user: UserId) -> f64 {
        self.rows
            .get(&user)
            .map(|r| r.device_seconds)
            .unwrap_or(0.0)
    }

    /// Charge a finished lease: `unit_seconds` device-seconds at
    /// `watts` per vFPGA-equivalent.
    pub fn charge_release(
        &mut self,
        user: UserId,
        unit_seconds: f64,
        watts: f64,
    ) {
        self.charge_accrual(user, unit_seconds, watts);
        self.row_mut(user).released += 1;
    }

    /// Charge accrued-but-unreleased device-seconds at a job
    /// boundary (the pipelined batch mode's accrual split): same
    /// billing as [`UsageLedger::charge_release`] minus the release
    /// count — the lease is still live.
    pub fn charge_accrual(
        &mut self,
        user: UserId,
        unit_seconds: f64,
        watts: f64,
    ) {
        let row = self.row_mut(user);
        row.device_seconds += unit_seconds;
        row.energy_joules += unit_seconds * watts;
    }

    /// Charge a preemption's migration downtime to the *preemptor*:
    /// `unit_seconds` of victim downtime (device-seconds) at the
    /// victim's per-unit power. The victim's own accrual clock skips
    /// this window, so the cost lands exactly once — on the tenant
    /// that caused it.
    pub fn charge_preemption(
        &mut self,
        preemptor: UserId,
        unit_seconds: f64,
        watts: f64,
    ) {
        let row = self.row_mut(preemptor);
        row.preempt_downtime_s += unit_seconds;
        row.device_seconds += unit_seconds;
        row.energy_joules += unit_seconds * watts;
    }

    pub fn tenants(&self) -> Vec<UserId> {
        self.rows.keys().copied().collect()
    }

    /// Render the operator report. `names` maps tenant ids to display
    /// names (unknown tenants render as their id).
    pub fn report(&self, names: &BTreeMap<UserId, String>) -> String {
        let mut table = Table::new(
            "Per-tenant usage (cluster scheduler accounting)",
            &[
                "tenant",
                "granted",
                "queued",
                "preempted",
                "device-s",
                "energy J",
                "max wait ms",
                "preempt-s",
            ],
        );
        for (user, row) in &self.rows {
            let name = names
                .get(user)
                .cloned()
                .unwrap_or_else(|| user.to_string());
            table.row(&[
                name,
                row.granted.to_string(),
                row.queued.to_string(),
                row.preempted.to_string(),
                format!("{:.1}", row.device_seconds),
                format!("{:.1}", row.energy_joules),
                format!("{:.1}", row.max_wait_ms),
                format!("{:.1}", row.preempt_downtime_s),
            ]);
        }
        table.render()
    }

    /// JSON rows for the `usage_report` RPC.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.rows
                .iter()
                .map(|(user, row)| {
                    Json::obj(vec![
                        ("user", Json::from(user.to_string())),
                        ("granted", Json::from(row.granted)),
                        ("released", Json::from(row.released)),
                        ("queued", Json::from(row.queued)),
                        ("preempted", Json::from(row.preempted)),
                        (
                            "device_seconds",
                            Json::from(row.device_seconds),
                        ),
                        (
                            "energy_joules",
                            Json::from(row.energy_joules),
                        ),
                        ("max_wait_ms", Json::from(row.max_wait_ms)),
                        (
                            "preempt_downtime_s",
                            Json::from(row.preempt_downtime_s),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// Restore from [`UsageLedger::to_json`] output (management-node
    /// restart). Unknown fields are ignored; missing numeric fields
    /// read as zero so older state files stay loadable.
    pub fn from_json(v: &Json) -> Result<UsageLedger, String> {
        let rows = v
            .as_arr()
            .ok_or("usage ledger must be a JSON array")?;
        let mut ledger = UsageLedger::new();
        for r in rows {
            let user = UserId::parse(r.str_field("user")?)
                .ok_or("bad user id in usage ledger")?;
            let row = ledger.row_mut(user);
            row.granted = r.get("granted").as_u64().unwrap_or(0);
            row.released = r.get("released").as_u64().unwrap_or(0);
            row.preempted = r.get("preempted").as_u64().unwrap_or(0);
            row.queued = r.get("queued").as_u64().unwrap_or(0);
            row.device_seconds =
                r.get("device_seconds").as_f64().unwrap_or(0.0);
            row.energy_joules =
                r.get("energy_joules").as_f64().unwrap_or(0.0);
            row.max_wait_ms =
                r.get("max_wait_ms").as_f64().unwrap_or(0.0);
            row.preempt_downtime_s =
                r.get("preempt_downtime_s").as_f64().unwrap_or(0.0);
        }
        Ok(ledger)
    }

    /// Replace this ledger's rows with a reloaded snapshot.
    pub fn restore(&mut self, other: UsageLedger) {
        self.rows = other.rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_accumulate() {
        let mut ledger = UsageLedger::new();
        let u = UserId(0);
        ledger.row_mut(u).granted += 1;
        ledger.charge_release(u, 10.0, 4.0);
        ledger.charge_release(u, 5.0, 4.0);
        let row = ledger.usage(u);
        assert_eq!(row.granted, 1);
        assert_eq!(row.released, 2);
        assert!((row.device_seconds - 15.0).abs() < 1e-9);
        assert!((row.energy_joules - 60.0).abs() < 1e-9);
        assert!((ledger.device_seconds(u) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn unknown_tenant_reads_zero() {
        let ledger = UsageLedger::new();
        assert_eq!(ledger.usage(UserId(9)), TenantUsage::default());
        assert_eq!(ledger.device_seconds(UserId(9)), 0.0);
        assert!(ledger.tenants().is_empty());
    }

    #[test]
    fn report_renders_named_rows() {
        let mut ledger = UsageLedger::new();
        let alice = UserId(0);
        let ghost = UserId(7);
        ledger.charge_release(alice, 2.0, 1.0);
        ledger.row_mut(ghost).preempted = 3;
        let mut names = BTreeMap::new();
        names.insert(alice, "alice".to_string());
        let report = ledger.report(&names);
        assert!(report.contains("alice"), "{report}");
        assert!(report.contains("user-7"), "{report}");
        assert!(report.contains("tenant"), "{report}");
    }

    #[test]
    fn json_rows_roundtrip_fields() {
        let mut ledger = UsageLedger::new();
        let u = UserId(1);
        ledger.row_mut(u).queued = 4;
        ledger.charge_release(u, 1.5, 2.0);
        let j = ledger.to_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("user").as_str(), Some("user-1"));
        assert_eq!(rows[0].get("queued").as_u64(), Some(4));
        assert!(
            (rows[0].get("energy_joules").as_f64().unwrap() - 3.0).abs()
                < 1e-9
        );
    }

    #[test]
    fn preemption_charge_bills_preemptor() {
        let mut ledger = UsageLedger::new();
        let vip = UserId(0);
        ledger.charge_preemption(vip, 0.25, 4.0);
        let row = ledger.usage(vip);
        assert!((row.preempt_downtime_s - 0.25).abs() < 1e-9);
        assert!((row.device_seconds - 0.25).abs() < 1e-9);
        assert!((row.energy_joules - 1.0).abs() < 1e-9);
        // The charge counts against the preemptor's budgetable usage.
        assert!((ledger.device_seconds(vip) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn ledger_serialization_roundtrip() {
        let mut ledger = UsageLedger::new();
        let a = UserId(0);
        let b = UserId(3);
        ledger.row_mut(a).granted = 5;
        ledger.row_mut(a).queued = 2;
        ledger.row_mut(a).max_wait_ms = 12.5;
        ledger.charge_release(a, 10.0, 4.0);
        ledger.charge_preemption(b, 0.5, 2.0);
        ledger.row_mut(b).preempted = 1;
        let back = UsageLedger::from_json(&ledger.to_json()).unwrap();
        assert_eq!(back.usage(a), ledger.usage(a));
        assert_eq!(back.usage(b), ledger.usage(b));
        // Bad payloads are typed errors, not panics.
        assert!(UsageLedger::from_json(&Json::from(3u64)).is_err());
        let bad = Json::Arr(vec![Json::obj(vec![(
            "user",
            Json::from("not-an-id"),
        )])]);
        assert!(UsageLedger::from_json(&bad).is_err());
    }
}
