//! Per-tenant quotas and admission control.
//!
//! Two independent limits per tenant:
//! * **concurrency** — max vFPGA-equivalents held at once (a physical
//!   RSaaS device counts as [`PHYSICAL_EQUIV_UNITS`]); recoverable:
//!   a request blocked on concurrency queues and is retried when the
//!   tenant releases;
//! * **device-second budget** — total accumulated device-seconds the
//!   tenant may consume over the cluster's lifetime; *not*
//!   recoverable (usage only grows), so a budget denial is a hard
//!   error, never a queue.
//!
//! The scheduler consults [`QuotaBook::admissible`] on every
//! admission (fast path *and* queue pump), so quotas hold under any
//! interleaving — the property test in `tests/sched_invariants.rs`
//! hammers exactly this.

use std::collections::BTreeMap;

use crate::util::ids::UserId;
use crate::util::json::Json;

/// vFPGA-equivalents charged for a whole physical device (Section I /
/// IV-A: up to four vFPGAs per device).
pub const PHYSICAL_EQUIV_UNITS: u64 = crate::paper::MAX_VFPGAS as u64;

/// One tenant's limits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantQuota {
    /// Max concurrently-held vFPGA-equivalents.
    pub max_concurrent: u64,
    /// Lifetime device-second budget (`None` = unmetered).
    pub device_seconds_budget: Option<f64>,
    /// Fair-share weight (≥ 1).
    pub weight: u64,
}

impl Default for TenantQuota {
    fn default() -> TenantQuota {
        TenantQuota {
            max_concurrent: u64::MAX,
            device_seconds_budget: None,
            weight: 1,
        }
    }
}

/// Why a request was denied.
#[derive(Debug, Clone, PartialEq)]
pub enum QuotaDenial {
    /// Tenant is at its concurrency cap — recoverable, queue it.
    Concurrency { in_use: u64, max: u64 },
    /// Tenant exhausted its device-second budget — terminal.
    Budget { used_s: f64, budget_s: f64 },
}

impl std::fmt::Display for QuotaDenial {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QuotaDenial::Concurrency { in_use, max } => write!(
                f,
                "{in_use} of {max} concurrent vFPGAs held"
            ),
            QuotaDenial::Budget { used_s, budget_s } => write!(
                f,
                "device-second budget exhausted ({used_s:.1} of {budget_s:.1} s)"
            ),
        }
    }
}

/// The quota ledger: limits + live concurrency per tenant.
#[derive(Debug, Default)]
pub struct QuotaBook {
    quotas: BTreeMap<UserId, TenantQuota>,
    in_use: BTreeMap<UserId, u64>,
}

impl QuotaBook {
    pub fn new() -> QuotaBook {
        QuotaBook::default()
    }

    /// Effective quota (explicit or default-unlimited).
    pub fn quota(&self, user: UserId) -> TenantQuota {
        self.quotas.get(&user).copied().unwrap_or_default()
    }

    pub fn set(&mut self, user: UserId, quota: TenantQuota) {
        self.quotas.insert(user, quota);
    }

    /// Currently-held vFPGA-equivalents.
    pub fn in_use(&self, user: UserId) -> u64 {
        self.in_use.get(&user).copied().unwrap_or(0)
    }

    pub fn weight(&self, user: UserId) -> u64 {
        self.quota(user).weight.max(1)
    }

    /// Would granting `units` more keep `user` within quota?
    /// `used_device_seconds` comes from the usage ledger.
    pub fn admissible(
        &self,
        user: UserId,
        units: u64,
        used_device_seconds: f64,
    ) -> Result<(), QuotaDenial> {
        let q = self.quota(user);
        if let Some(budget) = q.device_seconds_budget {
            if used_device_seconds >= budget {
                return Err(QuotaDenial::Budget {
                    used_s: used_device_seconds,
                    budget_s: budget,
                });
            }
        }
        let in_use = self.in_use(user);
        if in_use.saturating_add(units) > q.max_concurrent {
            return Err(QuotaDenial::Concurrency {
                in_use,
                max: q.max_concurrent,
            });
        }
        Ok(())
    }

    /// Record a grant.
    pub fn charge(&mut self, user: UserId, units: u64) {
        *self.in_use.entry(user).or_insert(0) += units;
    }

    /// Record a release.
    pub fn credit(&mut self, user: UserId, units: u64) {
        if let Some(n) = self.in_use.get_mut(&user) {
            *n = n.saturating_sub(units);
            if *n == 0 {
                self.in_use.remove(&user);
            }
        }
    }

    /// Whether any tenant has a device-second budget configured (the
    /// scheduler skips the terminal-budget queue scan otherwise).
    pub fn has_budgets(&self) -> bool {
        self.quotas
            .values()
            .any(|q| q.device_seconds_budget.is_some())
    }

    /// All explicitly-configured quotas (RPC status).
    pub fn snapshot(&self) -> Vec<(UserId, TenantQuota)> {
        self.quotas.iter().map(|(u, q)| (*u, *q)).collect()
    }

    /// Serialize the configured limits (not the live `in_use` state,
    /// which belongs to leases that do not survive a restart).
    /// `max_concurrent: null` encodes unlimited — `u64::MAX` would
    /// lose precision through the f64-backed [`Json`] number.
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.quotas
                .iter()
                .map(|(user, q)| {
                    Json::obj(vec![
                        ("user", Json::from(user.to_string())),
                        (
                            "max_concurrent",
                            if q.max_concurrent == u64::MAX {
                                Json::Null
                            } else {
                                Json::from(q.max_concurrent)
                            },
                        ),
                        (
                            "budget_s",
                            match q.device_seconds_budget {
                                Some(b) => Json::from(b),
                                None => Json::Null,
                            },
                        ),
                        ("weight", Json::from(q.weight)),
                    ])
                })
                .collect(),
        )
    }

    /// Restore limits from [`QuotaBook::to_json`] output. The
    /// returned book has no live concurrency state.
    pub fn from_json(v: &Json) -> Result<QuotaBook, String> {
        let rows =
            v.as_arr().ok_or("quota book must be a JSON array")?;
        let mut book = QuotaBook::new();
        for r in rows {
            let user = UserId::parse(r.str_field("user")?)
                .ok_or("bad user id in quota book")?;
            book.set(
                user,
                TenantQuota {
                    max_concurrent: r
                        .get("max_concurrent")
                        .as_u64()
                        .unwrap_or(u64::MAX),
                    device_seconds_budget: r.get("budget_s").as_f64(),
                    weight: r
                        .get("weight")
                        .as_u64()
                        .unwrap_or(1)
                        .max(1),
                },
            );
        }
        Ok(book)
    }

    /// Replace the configured limits with a reloaded snapshot,
    /// keeping this book's live concurrency state.
    pub fn restore_limits(&mut self, other: QuotaBook) {
        self.quotas = other.quotas;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_unmetered() {
        let book = QuotaBook::new();
        let u = UserId(0);
        assert!(book.admissible(u, 1, 1e12).is_ok());
        assert_eq!(book.quota(u).weight, 1);
    }

    #[test]
    fn concurrency_cap_enforced_and_recovers() {
        let mut book = QuotaBook::new();
        let u = UserId(0);
        book.set(
            u,
            TenantQuota {
                max_concurrent: 2,
                ..TenantQuota::default()
            },
        );
        book.charge(u, 2);
        assert!(matches!(
            book.admissible(u, 1, 0.0),
            Err(QuotaDenial::Concurrency { in_use: 2, max: 2 })
        ));
        book.credit(u, 1);
        assert!(book.admissible(u, 1, 0.0).is_ok());
        assert_eq!(book.in_use(u), 1);
    }

    #[test]
    fn budget_denial_is_terminal_shape() {
        let mut book = QuotaBook::new();
        let u = UserId(3);
        book.set(
            u,
            TenantQuota {
                device_seconds_budget: Some(100.0),
                ..TenantQuota::default()
            },
        );
        assert!(book.admissible(u, 1, 99.0).is_ok());
        let denial = book.admissible(u, 1, 100.0).unwrap_err();
        assert!(matches!(denial, QuotaDenial::Budget { .. }));
        assert!(denial.to_string().contains("budget"));
    }

    #[test]
    fn credit_never_underflows() {
        let mut book = QuotaBook::new();
        let u = UserId(1);
        book.credit(u, 5);
        assert_eq!(book.in_use(u), 0);
        book.charge(u, 4);
        book.credit(u, 2);
        book.credit(u, 99);
        assert_eq!(book.in_use(u), 0);
    }

    #[test]
    fn quota_book_serialization_roundtrip() {
        let mut book = QuotaBook::new();
        book.set(
            UserId(0),
            TenantQuota {
                max_concurrent: 3,
                device_seconds_budget: Some(120.0),
                weight: 4,
            },
        );
        book.set(UserId(5), TenantQuota::default());
        book.charge(UserId(0), 2); // live state must NOT serialize
        let back = QuotaBook::from_json(&book.to_json()).unwrap();
        assert_eq!(back.quota(UserId(0)), book.quota(UserId(0)));
        assert_eq!(back.quota(UserId(5)), TenantQuota::default());
        assert_eq!(back.in_use(UserId(0)), 0);
        // restore_limits keeps live concurrency.
        book.restore_limits(back);
        assert_eq!(book.in_use(UserId(0)), 2);
        assert_eq!(book.quota(UserId(0)).max_concurrent, 3);
        assert!(QuotaBook::from_json(&Json::from(1u64)).is_err());
    }

    #[test]
    fn physical_units_count_against_concurrency() {
        let mut book = QuotaBook::new();
        let u = UserId(2);
        book.set(
            u,
            TenantQuota {
                max_concurrent: PHYSICAL_EQUIV_UNITS,
                ..TenantQuota::default()
            },
        );
        assert!(book.admissible(u, PHYSICAL_EQUIV_UNITS, 0.0).is_ok());
        book.charge(u, PHYSICAL_EQUIV_UNITS);
        assert!(book.admissible(u, 1, 0.0).is_err());
    }
}
