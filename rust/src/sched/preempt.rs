//! Preemption of lower-class leases for interactive requests.
//!
//! When an interactive request finds no free region on any device
//! serving its model, the scheduler looks for a *victim*: a running
//! lower-class (batch/BAaaS) lease on such a device. The victim is
//! not killed — its design is relocated with the hypervisor's
//! migration path ([`crate::hypervisor::migration`]), which retargets
//! the relocatable bitfile and rebinds the lease, typically onto a
//! device the interactive model cannot use (that asymmetry is why
//! migration helps at all: if a region free for the requester
//! existed, plain placement would have found it). The freed region
//! then takes the interactive lease.
//!
//! Only *quiescable* victims are eligible: the scheduler wins a
//! non-blocking region quiesce ([`crate::hypervisor::guard`]) before
//! touching any state, so a victim with an in-flight setup or stream
//! pin is skipped, never raced. Gang leases are relocated atomically
//! — every member quiesced two-phase in the fixed `(fpga, vfpga)`
//! order, then migrated all-or-nothing.
//!
//! Victim selection is deterministic and pure (unit-testable):
//! 1. lowest request class first (batch before normal);
//! 2. youngest lease first — the least accumulated work is lost to
//!    the migration downtime;
//! 3. ties break on the highest allocation id (the most recent grant).
//!
//! Where a displaced design lands is a policy knob
//! ([`PreemptPolicy`]): `Pack` consolidates victims onto the fullest
//! eligible device (protecting big free blocks for future gangs),
//! `Spread` balances them onto the emptiest one (minimizing link
//! contention with co-located tenants).
//!
//! Cost model: the migration downtime is charged to the *preemptor's*
//! tenant, not the victim's — the scheduler bills the outage via
//! [`super::accounting::UsageLedger::charge_preemption`] and advances
//! the victim's accrual clock past it, so displacing someone costs
//! the tenant who asked for it.

use crate::config::ServiceModel;
use crate::util::ids::{AllocationId, FpgaId, UserId, VfpgaId};

use super::RequestClass;

/// Where a preemption relocates its victim (spread-vs-pack knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptPolicy {
    /// Consolidate displaced designs onto the eligible device with
    /// the *fewest* free regions (keeps big free blocks intact for
    /// gangs; matches the paper's consolidate-first energy rule).
    #[default]
    Pack,
    /// Balance displaced designs onto the eligible device with the
    /// *most* free regions (minimizes per-device link contention).
    Spread,
}

impl PreemptPolicy {
    pub fn name(self) -> &'static str {
        match self {
            PreemptPolicy::Pack => "pack",
            PreemptPolicy::Spread => "spread",
        }
    }

    pub fn parse(s: &str) -> Option<PreemptPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "pack" => Some(PreemptPolicy::Pack),
            "spread" => Some(PreemptPolicy::Spread),
            _ => None,
        }
    }
}

/// Pick a relocation target among `(device, free regions)` candidate
/// rows under `policy`. Rows with no free region are ignored; ties
/// break on the lowest device id, and the lowest free region of the
/// chosen device wins. Pure (unit-testable).
pub fn choose_target(
    policy: PreemptPolicy,
    candidates: &[(FpgaId, Vec<VfpgaId>)],
) -> Option<VfpgaId> {
    let mut best: Option<(FpgaId, &Vec<VfpgaId>)> = None;
    for (fpga, free) in candidates {
        if free.is_empty() {
            continue;
        }
        let better = match &best {
            None => true,
            Some((bf, bfree)) => {
                let (n, bn) = (free.len(), bfree.len());
                match policy {
                    PreemptPolicy::Pack => {
                        n < bn || (n == bn && fpga < bf)
                    }
                    PreemptPolicy::Spread => {
                        n > bn || (n == bn && fpga < bf)
                    }
                }
            }
        };
        if better {
            best = Some((*fpga, free));
        }
    }
    best.and_then(|(_, free)| free.iter().min().copied())
}

/// A preemptable running lease.
#[derive(Debug, Clone, PartialEq)]
pub struct VictimInfo {
    pub alloc: AllocationId,
    pub user: UserId,
    pub class: RequestClass,
    /// Service model of the victim's own lease — the migration
    /// target must sit on a device serving it.
    pub model: ServiceModel,
    pub vfpga: VfpgaId,
    pub fpga: FpgaId,
    /// Virtual timestamp the lease was granted.
    pub started_ns: u64,
}

/// The victim-ranking key: lowest class, then youngest lease, then
/// highest allocation id.
fn victim_key(
    v: &VictimInfo,
) -> (RequestClass, std::cmp::Reverse<u64>, std::cmp::Reverse<u64>) {
    (
        v.class,
        std::cmp::Reverse(v.started_ns),
        std::cmp::Reverse(v.alloc.0),
    )
}

/// Pick the victim to relocate among `candidates`, all of which must
/// already be below the requester's class and on a device serving the
/// requested model. Returns `None` when the slice is empty.
pub fn select_victim(candidates: &[VictimInfo]) -> Option<VictimInfo> {
    victim_order(candidates).into_iter().next()
}

/// Order all candidates best-victim-first (the scheduler walks this
/// list, skipping victims whose migration fails).
pub fn victim_order(candidates: &[VictimInfo]) -> Vec<VictimInfo> {
    let mut ordered = candidates.to_vec();
    ordered.sort_by_key(victim_key);
    ordered
}

#[cfg(test)]
mod tests {
    use super::*;

    fn victim(
        alloc: u64,
        class: RequestClass,
        started_ns: u64,
    ) -> VictimInfo {
        VictimInfo {
            alloc: AllocationId(alloc),
            user: UserId(0),
            class,
            model: ServiceModel::BAaaS,
            vfpga: VfpgaId(alloc),
            fpga: FpgaId(0),
            started_ns,
        }
    }

    #[test]
    fn empty_slice_has_no_victim() {
        assert_eq!(select_victim(&[]), None);
    }

    #[test]
    fn lowest_class_goes_first() {
        let cands = vec![
            victim(0, RequestClass::Normal, 100),
            victim(1, RequestClass::Batch, 0),
        ];
        assert_eq!(select_victim(&cands).unwrap().alloc, AllocationId(1));
    }

    #[test]
    fn youngest_lease_within_class() {
        let cands = vec![
            victim(0, RequestClass::Batch, 10),
            victim(1, RequestClass::Batch, 500),
            victim(2, RequestClass::Batch, 200),
        ];
        // alloc-1 started last → least work lost.
        assert_eq!(select_victim(&cands).unwrap().alloc, AllocationId(1));
    }

    #[test]
    fn tie_breaks_on_highest_alloc_id() {
        let cands = vec![
            victim(3, RequestClass::Batch, 42),
            victim(7, RequestClass::Batch, 42),
        ];
        assert_eq!(select_victim(&cands).unwrap().alloc, AllocationId(7));
    }

    #[test]
    fn pack_targets_the_fullest_device_spread_the_emptiest() {
        let candidates = vec![
            (FpgaId(0), vec![VfpgaId(2), VfpgaId(1)]),
            (FpgaId(1), vec![]),
            (FpgaId(2), vec![VfpgaId(9)]),
            (FpgaId(3), vec![VfpgaId(12), VfpgaId(13), VfpgaId(14)]),
        ];
        // Pack: fewest free regions (fpga-2), lowest region.
        assert_eq!(
            choose_target(PreemptPolicy::Pack, &candidates),
            Some(VfpgaId(9))
        );
        // Spread: most free regions (fpga-3), lowest region.
        assert_eq!(
            choose_target(PreemptPolicy::Spread, &candidates),
            Some(VfpgaId(12))
        );
        // Ties break on the lowest device id.
        let tied = vec![
            (FpgaId(5), vec![VfpgaId(21)]),
            (FpgaId(4), vec![VfpgaId(20)]),
        ];
        assert_eq!(
            choose_target(PreemptPolicy::Pack, &tied),
            Some(VfpgaId(20))
        );
        assert_eq!(
            choose_target(PreemptPolicy::Spread, &tied),
            Some(VfpgaId(20))
        );
        // Nothing free anywhere.
        assert_eq!(
            choose_target(PreemptPolicy::Pack, &[(FpgaId(0), vec![])]),
            None
        );
        assert_eq!(choose_target(PreemptPolicy::Spread, &[]), None);
    }

    #[test]
    fn policy_names_roundtrip() {
        assert_eq!(PreemptPolicy::default(), PreemptPolicy::Pack);
        for p in [PreemptPolicy::Pack, PreemptPolicy::Spread] {
            assert_eq!(PreemptPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PreemptPolicy::parse("random"), None);
    }

    #[test]
    fn victim_order_is_total_and_deterministic() {
        let cands = vec![
            victim(0, RequestClass::Normal, 0),
            victim(1, RequestClass::Batch, 5),
            victim(2, RequestClass::Batch, 9),
        ];
        let order: Vec<u64> =
            victim_order(&cands).iter().map(|v| v.alloc.0).collect();
        // batch-youngest (alloc 2), batch-older (alloc 1), then normal.
        assert_eq!(order, vec![2, 1, 0]);
        // First of the order == select_victim.
        assert_eq!(
            victim_order(&cands)[0].alloc,
            select_victim(&cands).unwrap().alloc
        );
    }
}
