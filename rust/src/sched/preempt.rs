//! Preemption of lower-class leases for interactive requests.
//!
//! When an interactive request finds no free region on any device
//! serving its model, the scheduler looks for a *victim*: a running
//! lower-class (batch/BAaaS) lease on such a device. The victim is
//! not killed — its design is relocated with the hypervisor's
//! migration path ([`crate::hypervisor::migration`]), which retargets
//! the relocatable bitfile and rebinds the lease, typically onto a
//! device the interactive model cannot use (that asymmetry is why
//! migration helps at all: if a region free for the requester
//! existed, plain placement would have found it). The freed region
//! then takes the interactive lease.
//!
//! Victim selection is deterministic and pure (unit-testable):
//! 1. lowest request class first (batch before normal);
//! 2. youngest lease first — the least accumulated work is lost to
//!    the migration downtime;
//! 3. ties break on the highest allocation id (the most recent grant).
//!
//! Cost model: the migration downtime is charged to the *preemptor's*
//! tenant, not the victim's — the scheduler bills the outage via
//! [`super::accounting::UsageLedger::charge_preemption`] and advances
//! the victim's accrual clock past it, so displacing someone costs
//! the tenant who asked for it.

use crate::config::ServiceModel;
use crate::util::ids::{AllocationId, FpgaId, UserId, VfpgaId};

use super::RequestClass;

/// A preemptable running lease.
#[derive(Debug, Clone, PartialEq)]
pub struct VictimInfo {
    pub alloc: AllocationId,
    pub user: UserId,
    pub class: RequestClass,
    /// Service model of the victim's own lease — the migration
    /// target must sit on a device serving it.
    pub model: ServiceModel,
    pub vfpga: VfpgaId,
    pub fpga: FpgaId,
    /// Virtual timestamp the lease was granted.
    pub started_ns: u64,
}

/// The victim-ranking key: lowest class, then youngest lease, then
/// highest allocation id.
fn victim_key(
    v: &VictimInfo,
) -> (RequestClass, std::cmp::Reverse<u64>, std::cmp::Reverse<u64>) {
    (
        v.class,
        std::cmp::Reverse(v.started_ns),
        std::cmp::Reverse(v.alloc.0),
    )
}

/// Pick the victim to relocate among `candidates`, all of which must
/// already be below the requester's class and on a device serving the
/// requested model. Returns `None` when the slice is empty.
pub fn select_victim(candidates: &[VictimInfo]) -> Option<VictimInfo> {
    victim_order(candidates).into_iter().next()
}

/// Order all candidates best-victim-first (the scheduler walks this
/// list, skipping victims whose migration fails).
pub fn victim_order(candidates: &[VictimInfo]) -> Vec<VictimInfo> {
    let mut ordered = candidates.to_vec();
    ordered.sort_by_key(victim_key);
    ordered
}

#[cfg(test)]
mod tests {
    use super::*;

    fn victim(
        alloc: u64,
        class: RequestClass,
        started_ns: u64,
    ) -> VictimInfo {
        VictimInfo {
            alloc: AllocationId(alloc),
            user: UserId(0),
            class,
            model: ServiceModel::BAaaS,
            vfpga: VfpgaId(alloc),
            fpga: FpgaId(0),
            started_ns,
        }
    }

    #[test]
    fn empty_slice_has_no_victim() {
        assert_eq!(select_victim(&[]), None);
    }

    #[test]
    fn lowest_class_goes_first() {
        let cands = vec![
            victim(0, RequestClass::Normal, 100),
            victim(1, RequestClass::Batch, 0),
        ];
        assert_eq!(select_victim(&cands).unwrap().alloc, AllocationId(1));
    }

    #[test]
    fn youngest_lease_within_class() {
        let cands = vec![
            victim(0, RequestClass::Batch, 10),
            victim(1, RequestClass::Batch, 500),
            victim(2, RequestClass::Batch, 200),
        ];
        // alloc-1 started last → least work lost.
        assert_eq!(select_victim(&cands).unwrap().alloc, AllocationId(1));
    }

    #[test]
    fn tie_breaks_on_highest_alloc_id() {
        let cands = vec![
            victim(3, RequestClass::Batch, 42),
            victim(7, RequestClass::Batch, 42),
        ];
        assert_eq!(select_victim(&cands).unwrap().alloc, AllocationId(7));
    }

    #[test]
    fn victim_order_is_total_and_deterministic() {
        let cands = vec![
            victim(0, RequestClass::Normal, 0),
            victim(1, RequestClass::Batch, 5),
            victim(2, RequestClass::Batch, 9),
        ];
        let order: Vec<u64> =
            victim_order(&cands).iter().map(|v| v.alloc.0).collect();
        // batch-youngest (alloc 2), batch-older (alloc 1), then normal.
        assert_eq!(order, vec![2, 1, 0]);
        // First of the order == select_victim.
        assert_eq!(
            victim_order(&cands)[0].alloc,
            select_victim(&cands).unwrap().alloc
        );
    }
}
