//! The unified admission request and the capability lease handle.
//!
//! [`AdmissionRequest`] is the single typed entry point for every
//! allocation in the system — one vFPGA, a gang of N regions for a
//! multi-core design, or a whole physical device (RSaaS) — replacing
//! the old `acquire_vfpga` / `acquire_vfpga_blocking` /
//! `acquire_physical` trio.
//!
//! [`Lease`] is what an admission returns: a capability-style RAII
//! handle carrying an unguessable [`LeaseToken`]. Holding the token
//! *is* the authorization — the middleware validates it on every
//! mutating RPC instead of trusting a caller-supplied `user` field.
//! The lease knows its current placement (the scheduler rebinds
//! grants on migration, so the handle always answers with where the
//! lease lives *now*), exposes `program` / `stream` / `release`
//! itself, and returns the grant to the scheduler on drop.

use std::num::NonZeroU32;
use std::sync::Arc;

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::fpga::board::BoardKind;
use crate::hypervisor::HypervisorError;
use crate::rc2f::stream::{ChunkSink, StreamConfig, StreamOutcome};
use crate::util::clock::VirtualTime;
use crate::util::ids::{
    AllocationId, FpgaId, LeaseToken, NodeId, UserId, VfpgaId, VmId,
};
use crate::util::trace;

use super::{GrantTarget, RequestClass, SchedError, Scheduler};

/// Placement constraints on an admission.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Constraints {
    /// Restrict to devices of this board model.
    pub board: Option<BoardKind>,
    /// All gang members must land on one device.
    pub co_located: bool,
    /// Physical admissions only: pass the device into this VM.
    pub vm: Option<VmId>,
}

/// A typed admission request — the single allocation entry point.
///
/// `model == RSaaS` admits a whole physical device (never queues);
/// any other model admits `regions` vFPGAs atomically (all-or-nothing
/// gang grant via deadlock-free two-phase reservation of candidate
/// regions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionRequest {
    pub tenant: UserId,
    pub model: ServiceModel,
    pub class: RequestClass,
    /// Regions to grant atomically (gang size); 1 for the common case.
    pub regions: NonZeroU32,
    pub constraints: Constraints,
    /// Max queue wait (relative virtual time) before the entry is
    /// deadline-boosted to interactive priority.
    pub deadline: Option<VirtualTime>,
}

impl AdmissionRequest {
    pub fn new(
        tenant: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> AdmissionRequest {
        AdmissionRequest {
            tenant,
            model,
            class,
            regions: NonZeroU32::new(1).expect("1 is non-zero"),
            constraints: Constraints::default(),
            deadline: None,
        }
    }

    /// Whole-device (RSaaS) admission.
    pub fn physical(
        tenant: UserId,
        class: RequestClass,
    ) -> AdmissionRequest {
        AdmissionRequest::new(tenant, ServiceModel::RSaaS, class)
    }

    /// Request `n` regions granted atomically (clamped to ≥ 1).
    pub fn gang(mut self, n: u32) -> AdmissionRequest {
        self.regions = NonZeroU32::new(n.max(1)).expect("clamped ≥ 1");
        self
    }

    pub fn co_located(mut self) -> AdmissionRequest {
        self.constraints.co_located = true;
        self
    }

    pub fn on_board(mut self, board: BoardKind) -> AdmissionRequest {
        self.constraints.board = Some(board);
        self
    }

    pub fn vm(mut self, vm: VmId) -> AdmissionRequest {
        self.constraints.vm = Some(vm);
        self
    }

    pub fn deadline(mut self, d: VirtualTime) -> AdmissionRequest {
        self.deadline = Some(d);
        self
    }
}

/// A live member of a lease and where it currently sits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemberPlacement {
    pub alloc: AllocationId,
    pub target: GrantTarget,
}

/// A granted lease: the capability handle over one admission.
///
/// Dropping an armed lease returns every member grant to the
/// scheduler; [`Lease::into_token`] disarms it (the middleware server
/// keeps leases alive across RPCs that way and re-materializes
/// handles with [`Scheduler::lease_handle`]).
pub struct Lease {
    sched: Arc<Scheduler>,
    token: LeaseToken,
    tenant: UserId,
    model: ServiceModel,
    class: RequestClass,
    /// Member allocations, primary first (stable over the lease's
    /// lifetime; placements are looked up live).
    members: Vec<AllocationId>,
    wait: VirtualTime,
    armed: bool,
}

impl Lease {
    /// Internal constructor (the scheduler builds leases).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        sched: Arc<Scheduler>,
        token: LeaseToken,
        tenant: UserId,
        model: ServiceModel,
        class: RequestClass,
        members: Vec<AllocationId>,
        wait: VirtualTime,
        armed: bool,
    ) -> Lease {
        Lease {
            sched,
            token,
            tenant,
            model,
            class,
            members,
            wait,
            armed,
        }
    }

    pub fn token(&self) -> LeaseToken {
        self.token
    }

    pub fn tenant(&self) -> UserId {
        self.tenant
    }

    pub fn model(&self) -> ServiceModel {
        self.model
    }

    pub fn class(&self) -> RequestClass {
        self.class
    }

    /// Member allocations, primary first.
    pub fn members(&self) -> &[AllocationId] {
        &self.members
    }

    /// The primary member's allocation id.
    pub fn alloc(&self) -> AllocationId {
        self.members[0]
    }

    /// Gang size.
    pub fn regions(&self) -> usize {
        self.members.len()
    }

    /// Virtual time this admission spent queued.
    pub fn wait(&self) -> VirtualTime {
        self.wait
    }

    /// Live placement of every member, in member order (members whose
    /// grants were released out-of-band are omitted).
    pub fn placements(&self) -> Vec<MemberPlacement> {
        self.members
            .iter()
            .filter_map(|a| {
                self.sched.grant(*a).map(|g| MemberPlacement {
                    alloc: *a,
                    target: g.target,
                })
            })
            .collect()
    }

    /// Current vFPGA of the primary member (None for physical leases
    /// or after an out-of-band release).
    pub fn vfpga(&self) -> Option<VfpgaId> {
        self.sched.grant(self.alloc()).and_then(|g| g.vfpga())
    }

    /// Current device of the primary member.
    pub fn fpga(&self) -> Option<FpgaId> {
        self.sched.grant(self.alloc()).map(|g| g.fpga())
    }

    /// Current node of the primary member.
    pub fn node(&self) -> Option<NodeId> {
        self.sched.grant(self.alloc()).map(|g| g.node())
    }

    /// Total migrations (preemptions + explicit moves) the lease's
    /// members have undergone — the signal the preemption-retry
    /// helpers use to tell a clean mid-setup race from a real fault.
    pub fn migrations(&self) -> u64 {
        self.members
            .iter()
            .filter_map(|a| self.sched.grant(*a))
            .map(|g| g.migrations)
            .sum()
    }

    /// Program the primary member with a relocatable partial bitfile
    /// (retargeted to wherever the lease currently sits).
    pub fn program(
        &self,
        bitfile: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        self.program_member(0, bitfile)
    }

    /// Program gang member `idx`. The member's region is pinned for
    /// the whole retarget + PR span, so a quiesce-based relocation
    /// (preemption, explicit migrate, release) cannot interleave —
    /// the placement resolved here is the placement programmed.
    pub fn program_member(
        &self,
        idx: usize,
        bitfile: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        let alloc = *self.members.get(idx).ok_or_else(|| {
            HypervisorError::Db(format!("lease has no member {idx}"))
        })?;
        let hv = self.sched.hv();
        let (_pin, vfpga) = hv.pin_current(alloc, self.tenant)?;
        let placed = hv.retarget_for(vfpga, bitfile)?;
        hv.program_vfpga(alloc, self.tenant, &placed)
    }

    /// Write a full user bitstream to a physically-held device
    /// (RSaaS leases only).
    pub fn program_full(
        &self,
        bs: &Bitstream,
    ) -> Result<VirtualTime, HypervisorError> {
        self.sched.hv().program_full(self.alloc(), self.tenant, bs)
    }

    /// Stream a workload through the primary member via the RC2F host
    /// API (the user-visible RAaaS path: session open + framework
    /// streaming charges apply).
    pub fn stream(
        &self,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        self.stream_member(0, cfg)
    }

    /// Stream through gang member `idx` via the RC2F host API. The
    /// region is pinned for the whole session, so the lease cannot be
    /// relocated out from under the stream — preemption skips pinned
    /// victims instead of racing them.
    pub fn stream_member(
        &self,
        idx: usize,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        self.stream_member_body(idx, cfg, None)
    }

    /// [`Lease::stream_member`] with a chunk sink: each consumed
    /// output chunk is lent to `sink` before its buffer is recycled,
    /// so callers (the protocol-4 data plane) can forward payload
    /// bytes without a server-side copy of the whole output.
    pub fn stream_member_sink(
        &self,
        idx: usize,
        cfg: &StreamConfig,
        sink: ChunkSink<'_>,
    ) -> Result<StreamOutcome, HypervisorError> {
        self.stream_member_body(idx, cfg, Some(sink))
    }

    fn stream_member_body(
        &self,
        idx: usize,
        cfg: &StreamConfig,
        sink: Option<ChunkSink<'_>>,
    ) -> Result<StreamOutcome, HypervisorError> {
        let alloc = *self.members.get(idx).ok_or_else(|| {
            HypervisorError::Db(format!("lease has no member {idx}"))
        })?;
        let sp = trace::span("rc2f.stream");
        sp.attr("alloc", alloc);
        let hv = self.sched.hv();
        let (_pin, vfpga) = hv.pin_current(alloc, self.tenant)?;
        let fpga = {
            let db = hv.db.lock().unwrap();
            db.device_of_vfpga(vfpga)
                .ok_or(HypervisorError::BadAllocation(alloc))?
                .id
        };
        let api = hv.host_api(fpga)?;
        let session = api
            .open_session(self.tenant, vfpga)
            .map_err(|e| HypervisorError::Db(e.to_string()))?;
        let out = match sink {
            Some(cb) => session.stream_with_sink(cfg, cb),
            None => session.stream(cfg),
        }
        .map_err(|e| HypervisorError::Db(e.to_string()));
        if let Err(e) = &out {
            sp.fail(e);
        }
        out
    }

    /// Stream through the primary member's device link directly (the
    /// provider-side path BAaaS invocations and batch workers use).
    /// Placement is resolved through the lease and the region pinned
    /// for the whole stream: a migration can no longer slip between
    /// resolution and streaming.
    pub fn stream_direct(
        &self,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HypervisorError> {
        let sp = trace::span("rc2f.stream");
        sp.attr("alloc", self.alloc());
        let hv = self.sched.hv();
        let (_pin, vfpga) =
            hv.pin_current(self.alloc(), self.tenant)?;
        let out = hv
            .stream_runner_for(vfpga)?
            .run(cfg)
            .map_err(HypervisorError::Db);
        if let Err(e) = &out {
            sp.fail(e);
        }
        out
    }

    /// Return every member grant to the scheduler.
    pub fn release(mut self) -> Result<(), SchedError> {
        self.armed = false;
        self.sched.release_token(self.token)
    }

    /// Disarm the handle and hand back the bare capability token —
    /// the lease stays live in the scheduler (server-side retention
    /// across RPCs; re-materialize with [`Scheduler::lease_handle`]).
    pub fn into_token(mut self) -> LeaseToken {
        self.armed = false;
        self.token
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.armed {
            // Best-effort: the lease may already have been released
            // through the token or a member-level release.
            let _ = self.sched.release_token(self.token);
        }
    }
}

/// Run `attempt`; if it fails with the *clean* failure signature a
/// preemption race leaves behind (sanity rejection / device or
/// device-file error) **and** the lease was migrated while the
/// attempt ran, retry exactly once. Any other failure — or a clean
/// failure without a migration — propagates unchanged.
///
/// **Defense in depth only.** Since the region lifecycle refactor,
/// setup and streaming hold a region pin and every relocation must
/// win a quiesce first, so the race this helper absorbs is
/// structurally impossible — a triggered retry means the pin/quiesce
/// invariant broke somewhere. Each trigger bumps the
/// `sched.preempt.raced` counter, which the tier-1 invariants suite
/// asserts stays 0.
pub fn with_preemption_retry<T>(
    lease: &Lease,
    mut attempt: impl FnMut() -> Result<T, HypervisorError>,
) -> Result<T, HypervisorError> {
    let migrations_before = lease.migrations();
    match attempt() {
        Err(e)
            if is_clean_setup_failure(&e)
                && lease.migrations() > migrations_before =>
        {
            // Should be unreachable: count it loudly.
            lease
                .sched
                .hv()
                .metrics
                .counter("sched.preempt.raced")
                .inc();
            log::warn!(
                "lease {} raced a relocation mid-setup ({e}) despite \
                 the pin/quiesce guards; retrying once",
                lease.token()
            );
            attempt()
        }
        other => other,
    }
}

/// The error shapes a preemption race is known to surface as (sanity
/// check against the relocated region, device/device-file access).
fn is_clean_setup_failure(e: &HypervisorError) -> bool {
    matches!(
        e,
        HypervisorError::Sanity(_)
            | HypervisorError::Device(_)
            | HypervisorError::Db(_)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn sched() -> Arc<Scheduler> {
        let hv = Arc::new(
            crate::hypervisor::Hypervisor::boot_paper_testbed(
                VirtualClock::new(),
            )
            .unwrap(),
        );
        Scheduler::new(hv)
    }

    #[test]
    fn request_builder_shapes() {
        let u = UserId(0);
        let r = AdmissionRequest::new(
            u,
            ServiceModel::RAaaS,
            RequestClass::Normal,
        )
        .gang(4)
        .co_located()
        .on_board(BoardKind::Vc707)
        .deadline(VirtualTime::from_secs_f64(5.0));
        assert_eq!(r.regions.get(), 4);
        assert!(r.constraints.co_located);
        assert_eq!(r.constraints.board, Some(BoardKind::Vc707));
        assert!(r.deadline.is_some());
        let p = AdmissionRequest::physical(u, RequestClass::Interactive);
        assert_eq!(p.model, ServiceModel::RSaaS);
        assert_eq!(p.regions.get(), 1);
        // gang(0) clamps instead of panicking.
        let z = AdmissionRequest::new(
            u,
            ServiceModel::RAaaS,
            RequestClass::Batch,
        )
        .gang(0);
        assert_eq!(z.regions.get(), 1);
    }

    #[test]
    fn lease_drop_returns_the_grant() {
        let s = sched();
        let user = s.hv().add_user("raii");
        {
            let _lease = s
                .admit(&AdmissionRequest::new(
                    user,
                    ServiceModel::RAaaS,
                    RequestClass::Normal,
                ))
                .unwrap();
            assert_eq!(s.in_use(user), 1);
        }
        // Dropped without an explicit release: grant returned.
        assert_eq!(s.in_use(user), 0);
        assert_eq!(s.usage(user).released, 1);
    }

    #[test]
    fn into_token_keeps_the_lease_alive() {
        let s = sched();
        let user = s.hv().add_user("server");
        let lease = s
            .admit(&AdmissionRequest::new(
                user,
                ServiceModel::RAaaS,
                RequestClass::Normal,
            ))
            .unwrap();
        let token = lease.into_token();
        assert_eq!(s.in_use(user), 1, "disarmed handle must not release");
        // Re-materialize and release through the capability.
        let handle = s.lease_handle(token).expect("token resolves");
        assert_eq!(handle.tenant(), user);
        handle.release().unwrap();
        assert_eq!(s.in_use(user), 0);
        assert!(s.lease_handle(token).is_none(), "token is now stale");
    }

    #[test]
    fn preemption_retry_helper_retries_exactly_once_after_migration() {
        let s = sched();
        let user = s.hv().add_user("retrier");
        let lease = s
            .admit(&AdmissionRequest::new(
                user,
                ServiceModel::BAaaS,
                RequestClass::Batch,
            ))
            .unwrap();
        // Clean failure without a migration: propagates.
        let mut calls = 0;
        let r: Result<(), _> = with_preemption_retry(&lease, || {
            calls += 1;
            Err(HypervisorError::Device("sanity race".into()))
        });
        assert!(r.is_err());
        assert_eq!(calls, 1, "no migration -> no retry");
        // Simulate a preemption racing the first attempt: the grant's
        // migration counter moves, the retry then succeeds.
        let mut calls = 0;
        let r = with_preemption_retry(&lease, || {
            calls += 1;
            if calls == 1 {
                s.bump_migrations_for_test(lease.alloc());
                Err(HypervisorError::Device("files vanished".into()))
            } else {
                Ok(42u32)
            }
        });
        assert_eq!(r.unwrap(), 42);
        assert_eq!(calls, 2, "exactly one retry");
        // The (simulated) race is counted — real runs keep this at 0.
        assert_eq!(
            s.hv().metrics.counter("sched.preempt.raced").get(),
            1
        );
        // A terminal (non-clean) failure never retries.
        let mut calls = 0;
        let r: Result<(), _> = with_preemption_retry(&lease, || {
            calls += 1;
            s.bump_migrations_for_test(lease.alloc());
            Err(HypervisorError::UnknownService("nope".into()))
        });
        assert!(r.is_err());
        assert_eq!(calls, 1);
        lease.release().unwrap();
    }
}
