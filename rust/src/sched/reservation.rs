//! Time-boxed, model-aware capacity reservations with virtual-clock
//! expiry.
//!
//! A reservation withholds `regions` vFPGAs of capacity for one
//! tenant over a window `[start, start + duration)` of *virtual*
//! time. A reservation may be pinned to a service model: it then
//! only withholds capacity from requests whose device set overlaps
//! that model's device set — on a heterogeneous config, reserving
//! RAaaS-capable regions no longer walls off devices that cannot
//! serve RAaaS at all (the old cluster-wide-count limitation the
//! ROADMAP called out). A model-less reservation behaves as before
//! (cluster-wide).
//!
//! While the window is active, other tenants can only be admitted
//! into capacity beyond the reserved-but-unclaimed total; the holder
//! draws its own admissions down from the reservation first. When the
//! window ends, whatever was never claimed is reclaimed for general
//! use — the scheduler calls [`reap`] lazily on every admission
//! attempt, so expiry needs no timer thread.
//!
//! The scheduler supplies the device-topology knowledge: every
//! model-filtered query takes an `overlaps` predicate answering "does
//! a reservation pinned to model `m` share devices with the request
//! at hand?" (`None` = cluster-wide, always overlapping).
//!
//! [`reap`]: ReservationBook::reap

use std::collections::BTreeMap;

use crate::config::ServiceModel;
use crate::util::clock::VirtualTime;
use crate::util::ids::{ReservationId, UserId};

/// One reservation.
#[derive(Debug, Clone, PartialEq)]
pub struct Reservation {
    pub id: ReservationId,
    pub user: UserId,
    /// Capacity reserved, in vFPGA regions.
    pub regions: u64,
    /// Service model the reservation is pinned to (`None` =
    /// cluster-wide, withholds from every model).
    pub model: Option<ServiceModel>,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Admissions already drawn from this reservation.
    pub claimed: u64,
}

impl Reservation {
    pub fn active_at(&self, now_ns: u64) -> bool {
        self.start_ns <= now_ns && now_ns < self.end_ns
    }

    pub fn unclaimed(&self) -> u64 {
        self.regions.saturating_sub(self.claimed)
    }
}

/// The reservation book.
#[derive(Debug, Default)]
pub struct ReservationBook {
    reservations: BTreeMap<ReservationId, Reservation>,
    next_id: u64,
    expired_total: u64,
}

impl ReservationBook {
    pub fn new() -> ReservationBook {
        ReservationBook::default()
    }

    /// Book `regions` vFPGAs for `user` starting at `start` for
    /// `duration` of virtual time, optionally pinned to a model.
    pub fn reserve(
        &mut self,
        user: UserId,
        regions: u64,
        model: Option<ServiceModel>,
        start: VirtualTime,
        duration: VirtualTime,
    ) -> ReservationId {
        let id = ReservationId(self.next_id);
        self.next_id += 1;
        self.reservations.insert(
            id,
            Reservation {
                id,
                user,
                regions,
                model,
                start_ns: start.0,
                end_ns: (start + duration).0,
                claimed: 0,
            },
        );
        id
    }

    pub fn cancel(&mut self, id: ReservationId) -> bool {
        self.reservations.remove(&id).is_some()
    }

    pub fn get(&self, id: ReservationId) -> Option<&Reservation> {
        self.reservations.get(&id)
    }

    /// Drop reservations whose window has passed; returns how many
    /// expired this sweep.
    pub fn reap(&mut self, now_ns: u64) -> usize {
        let before = self.reservations.len();
        self.reservations.retain(|_, r| r.end_ns > now_ns);
        let expired = before - self.reservations.len();
        self.expired_total += expired as u64;
        expired
    }

    /// Reservations ever reclaimed by expiry.
    pub fn expired_total(&self) -> u64 {
        self.expired_total
    }

    /// Capacity currently withheld from `user` for a request whose
    /// device set the `overlaps` predicate describes: the unclaimed
    /// regions of every *other* tenant's active reservation whose
    /// model overlaps the request's.
    pub fn withheld_from(
        &self,
        user: UserId,
        now_ns: u64,
        overlaps: impl Fn(Option<ServiceModel>) -> bool,
    ) -> u64 {
        self.reservations
            .values()
            .filter(|r| {
                r.user != user
                    && r.active_at(now_ns)
                    && overlaps(r.model)
            })
            .map(|r| r.unclaimed())
            .sum()
    }

    /// Capacity withheld from `user` by *any* active reservation,
    /// regardless of model (the conservative check exclusive physical
    /// admissions use — taking a whole device can strand any model's
    /// reservation).
    pub fn withheld_from_any(&self, user: UserId, now_ns: u64) -> u64 {
        self.withheld_from(user, now_ns, |_| true)
    }

    /// Unclaimed capacity of every active reservation overlapping the
    /// request's device set (the scheduler uses this to decide
    /// whether an admission actually drew on reserved headroom).
    pub fn withheld_total(
        &self,
        now_ns: u64,
        overlaps: impl Fn(Option<ServiceModel>) -> bool,
    ) -> u64 {
        self.reservations
            .values()
            .filter(|r| r.active_at(now_ns) && overlaps(r.model))
            .map(|r| r.unclaimed())
            .sum()
    }

    /// Unclaimed capacity of every reservation whose window overlaps
    /// `[start_ns, end_ns)` and whose model overlaps per the
    /// predicate — the overbooking check for new reservations.
    pub fn reserved_overlapping(
        &self,
        start_ns: u64,
        end_ns: u64,
        overlaps: impl Fn(Option<ServiceModel>) -> bool,
    ) -> u64 {
        self.reservations
            .values()
            .filter(|r| {
                r.start_ns < end_ns
                    && start_ns < r.end_ns
                    && overlaps(r.model)
            })
            .map(|r| r.unclaimed())
            .sum()
    }

    /// Draw one admission from `user`'s active reservation with claim
    /// headroom, if any. Prefers a reservation pinned to the
    /// requested model, falling back to a cluster-wide one. Returns
    /// the reservation drawn from so the claim can be credited back
    /// when that lease is released (reservations guarantee
    /// *concurrent* regions, not a count of admissions).
    pub fn consume(
        &mut self,
        user: UserId,
        model: ServiceModel,
        now_ns: u64,
    ) -> Option<ReservationId> {
        let usable = |r: &Reservation| {
            r.user == user && r.active_at(now_ns) && r.unclaimed() > 0
        };
        let id = self
            .reservations
            .values()
            .find(|r| usable(r) && r.model == Some(model))
            .or_else(|| {
                self.reservations
                    .values()
                    .find(|r| usable(r) && r.model.is_none())
            })
            .map(|r| r.id)?;
        let r = self.reservations.get_mut(&id).expect("found above");
        r.claimed += 1;
        Some(id)
    }

    /// Return one claim to a reservation (its lease was released
    /// inside the window). No-op if the reservation already expired.
    pub fn release_claim(&mut self, id: ReservationId) {
        if let Some(r) = self.reservations.get_mut(&id) {
            r.claimed = r.claimed.saturating_sub(1);
        }
    }

    /// Active reservations (RPC status).
    pub fn snapshot(&self, now_ns: u64) -> Vec<Reservation> {
        self.reservations
            .values()
            .filter(|r| r.end_ns > now_ns)
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs_f64(s)
    }

    /// Cluster-wide predicate (the homogeneous-config behavior).
    fn any(_: Option<ServiceModel>) -> bool {
        true
    }

    #[test]
    fn active_window_withholds_from_others() {
        let mut book = ReservationBook::new();
        let holder = UserId(0);
        let other = UserId(1);
        book.reserve(holder, 2, None, t(10.0), t(30.0));
        // Before the window: nothing withheld.
        assert_eq!(book.withheld_from(other, t(5.0).0, any), 0);
        // Inside: two regions withheld from others, none from holder.
        assert_eq!(book.withheld_from(other, t(20.0).0, any), 2);
        assert_eq!(book.withheld_from(holder, t(20.0).0, any), 0);
        // After: expired (even before reap runs, window checks apply).
        assert_eq!(book.withheld_from(other, t(40.0).0, any), 0);
    }

    #[test]
    fn model_pinned_reservation_only_withholds_overlapping_models() {
        let mut book = ReservationBook::new();
        let holder = UserId(0);
        let other = UserId(1);
        book.reserve(
            holder,
            3,
            Some(ServiceModel::RAaaS),
            t(0.0),
            t(100.0),
        );
        // The caller's `overlaps` predicate encodes the topology: a
        // BAaaS-only device set does not overlap the RAaaS pool.
        let disjoint = |m: Option<ServiceModel>| m.is_none();
        let shared = any;
        assert_eq!(book.withheld_from(other, t(1.0).0, disjoint), 0);
        assert_eq!(book.withheld_from(other, t(1.0).0, shared), 3);
        // A conservative any-model query still sees it.
        assert_eq!(book.withheld_from_any(other, t(1.0).0), 3);
    }

    #[test]
    fn holder_claims_draw_down_the_reservation() {
        let mut book = ReservationBook::new();
        let holder = UserId(0);
        let other = UserId(1);
        let id = book.reserve(holder, 2, None, t(0.0), t(100.0));
        assert_eq!(
            book.consume(holder, ServiceModel::RAaaS, t(1.0).0),
            Some(id)
        );
        assert_eq!(book.withheld_from(other, t(1.0).0, any), 1);
        assert_eq!(
            book.consume(holder, ServiceModel::RAaaS, t(2.0).0),
            Some(id)
        );
        assert_eq!(book.withheld_from(other, t(2.0).0, any), 0);
        // Fully claimed: no more draws.
        assert_eq!(
            book.consume(holder, ServiceModel::RAaaS, t(3.0).0),
            None
        );
        // Releasing a claimed lease restores the guarantee.
        book.release_claim(id);
        assert_eq!(book.withheld_from(other, t(4.0).0, any), 1);
        assert_eq!(
            book.consume(holder, ServiceModel::RAaaS, t(5.0).0),
            Some(id)
        );
        // Crediting an expired/cancelled reservation is a no-op.
        assert!(book.cancel(id));
        book.release_claim(id);
        assert_eq!(book.withheld_total(t(6.0).0, any), 0);
    }

    #[test]
    fn consume_prefers_model_pinned_reservation() {
        let mut book = ReservationBook::new();
        let holder = UserId(0);
        let wide = book.reserve(holder, 1, None, t(0.0), t(100.0));
        let pinned = book.reserve(
            holder,
            1,
            Some(ServiceModel::BAaaS),
            t(0.0),
            t(100.0),
        );
        // A BAaaS admission draws the pinned reservation first.
        assert_eq!(
            book.consume(holder, ServiceModel::BAaaS, t(1.0).0),
            Some(pinned)
        );
        // An RAaaS admission cannot use the BAaaS pin; it falls back
        // to the cluster-wide one.
        assert_eq!(
            book.consume(holder, ServiceModel::RAaaS, t(2.0).0),
            Some(wide)
        );
        assert_eq!(
            book.consume(holder, ServiceModel::RAaaS, t(3.0).0),
            None
        );
    }

    #[test]
    fn non_holder_cannot_consume() {
        let mut book = ReservationBook::new();
        book.reserve(UserId(0), 1, None, t(0.0), t(10.0));
        assert_eq!(
            book.consume(UserId(1), ServiceModel::RAaaS, t(1.0).0),
            None
        );
        // Outside the window the holder cannot consume either.
        assert_eq!(
            book.consume(UserId(0), ServiceModel::RAaaS, t(11.0).0),
            None
        );
    }

    #[test]
    fn reap_reclaims_expired_windows() {
        let mut book = ReservationBook::new();
        let a = book.reserve(UserId(0), 1, None, t(0.0), t(10.0));
        book.reserve(UserId(1), 1, None, t(0.0), t(50.0));
        assert_eq!(book.reap(t(20.0).0), 1);
        assert!(book.get(a).is_none());
        assert_eq!(book.expired_total(), 1);
        assert_eq!(book.snapshot(t(20.0).0).len(), 1);
        assert_eq!(book.reap(t(20.0).0), 0);
    }

    #[test]
    fn cancel_frees_capacity_immediately() {
        let mut book = ReservationBook::new();
        let id = book.reserve(UserId(0), 3, None, t(0.0), t(100.0));
        assert_eq!(book.withheld_from(UserId(1), t(1.0).0, any), 3);
        assert!(book.cancel(id));
        assert!(!book.cancel(id));
        assert_eq!(book.withheld_from(UserId(1), t(1.0).0, any), 0);
    }
}
