//! Time-boxed capacity reservations with virtual-clock expiry.
//!
//! A reservation withholds `regions` vFPGAs of cluster capacity for
//! one tenant over a window `[start, start + duration)` of *virtual*
//! time. While the window is active, other tenants can only be
//! admitted into capacity beyond the reserved-but-unclaimed total;
//! the holder draws its own admissions down from the reservation
//! first. When the window ends, whatever was never claimed is
//! reclaimed for general use — the scheduler calls [`reap`] lazily on
//! every admission attempt, so expiry needs no timer thread.
//!
//! **Known limitation:** reservations are cluster-wide *region
//! counts*, not bound to a service model or device set. On a
//! heterogeneous config (devices serving different model sets),
//! traffic for another model can still consume the only devices able
//! to serve the holder's model while the count-based guarantee looks
//! intact. Region-count-aware reservations per model are a ROADMAP
//! open item.
//!
//! [`reap`]: ReservationBook::reap

use std::collections::BTreeMap;

use crate::util::clock::VirtualTime;
use crate::util::ids::{ReservationId, UserId};

/// One reservation.
#[derive(Debug, Clone, PartialEq)]
pub struct Reservation {
    pub id: ReservationId,
    pub user: UserId,
    /// Capacity reserved, in vFPGA regions.
    pub regions: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Admissions already drawn from this reservation.
    pub claimed: u64,
}

impl Reservation {
    pub fn active_at(&self, now_ns: u64) -> bool {
        self.start_ns <= now_ns && now_ns < self.end_ns
    }

    pub fn unclaimed(&self) -> u64 {
        self.regions.saturating_sub(self.claimed)
    }
}

/// The reservation book.
#[derive(Debug, Default)]
pub struct ReservationBook {
    reservations: BTreeMap<ReservationId, Reservation>,
    next_id: u64,
    expired_total: u64,
}

impl ReservationBook {
    pub fn new() -> ReservationBook {
        ReservationBook::default()
    }

    /// Book `regions` vFPGAs for `user` starting at `start` for
    /// `duration` of virtual time.
    pub fn reserve(
        &mut self,
        user: UserId,
        regions: u64,
        start: VirtualTime,
        duration: VirtualTime,
    ) -> ReservationId {
        let id = ReservationId(self.next_id);
        self.next_id += 1;
        self.reservations.insert(
            id,
            Reservation {
                id,
                user,
                regions,
                start_ns: start.0,
                end_ns: (start + duration).0,
                claimed: 0,
            },
        );
        id
    }

    pub fn cancel(&mut self, id: ReservationId) -> bool {
        self.reservations.remove(&id).is_some()
    }

    pub fn get(&self, id: ReservationId) -> Option<&Reservation> {
        self.reservations.get(&id)
    }

    /// Drop reservations whose window has passed; returns how many
    /// expired this sweep.
    pub fn reap(&mut self, now_ns: u64) -> usize {
        let before = self.reservations.len();
        self.reservations.retain(|_, r| r.end_ns > now_ns);
        let expired = before - self.reservations.len();
        self.expired_total += expired as u64;
        expired
    }

    /// Reservations ever reclaimed by expiry.
    pub fn expired_total(&self) -> u64 {
        self.expired_total
    }

    /// Capacity currently withheld from `user`: the unclaimed regions
    /// of every *other* tenant's active reservation.
    pub fn withheld_from(&self, user: UserId, now_ns: u64) -> u64 {
        self.reservations
            .values()
            .filter(|r| r.user != user && r.active_at(now_ns))
            .map(|r| r.unclaimed())
            .sum()
    }

    /// Unclaimed capacity of *every* active reservation (the
    /// scheduler uses this to decide whether an admission actually
    /// drew on reserved headroom).
    pub fn withheld_total(&self, now_ns: u64) -> u64 {
        self.reservations
            .values()
            .filter(|r| r.active_at(now_ns))
            .map(|r| r.unclaimed())
            .sum()
    }

    /// Unclaimed capacity of every reservation whose window overlaps
    /// `[start_ns, end_ns)` — the overbooking check for new
    /// reservations.
    pub fn reserved_overlapping(&self, start_ns: u64, end_ns: u64) -> u64 {
        self.reservations
            .values()
            .filter(|r| r.start_ns < end_ns && start_ns < r.end_ns)
            .map(|r| r.unclaimed())
            .sum()
    }

    /// Draw one admission from `user`'s active reservation with claim
    /// headroom, if any. Returns the reservation drawn from so the
    /// claim can be credited back when that lease is released
    /// (reservations guarantee *concurrent* regions, not a count of
    /// admissions).
    pub fn consume(
        &mut self,
        user: UserId,
        now_ns: u64,
    ) -> Option<ReservationId> {
        if let Some(r) = self
            .reservations
            .values_mut()
            .find(|r| r.user == user && r.active_at(now_ns) && r.unclaimed() > 0)
        {
            r.claimed += 1;
            Some(r.id)
        } else {
            None
        }
    }

    /// Return one claim to a reservation (its lease was released
    /// inside the window). No-op if the reservation already expired.
    pub fn release_claim(&mut self, id: ReservationId) {
        if let Some(r) = self.reservations.get_mut(&id) {
            r.claimed = r.claimed.saturating_sub(1);
        }
    }

    /// Active reservations (RPC status).
    pub fn snapshot(&self, now_ns: u64) -> Vec<Reservation> {
        self.reservations
            .values()
            .filter(|r| r.end_ns > now_ns)
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(s: f64) -> VirtualTime {
        VirtualTime::from_secs_f64(s)
    }

    #[test]
    fn active_window_withholds_from_others() {
        let mut book = ReservationBook::new();
        let holder = UserId(0);
        let other = UserId(1);
        book.reserve(holder, 2, t(10.0), t(30.0));
        // Before the window: nothing withheld.
        assert_eq!(book.withheld_from(other, t(5.0).0), 0);
        // Inside: two regions withheld from others, none from holder.
        assert_eq!(book.withheld_from(other, t(20.0).0), 2);
        assert_eq!(book.withheld_from(holder, t(20.0).0), 0);
        // After: expired (even before reap runs, window checks apply).
        assert_eq!(book.withheld_from(other, t(40.0).0), 0);
    }

    #[test]
    fn holder_claims_draw_down_the_reservation() {
        let mut book = ReservationBook::new();
        let holder = UserId(0);
        let other = UserId(1);
        let id = book.reserve(holder, 2, t(0.0), t(100.0));
        assert_eq!(book.consume(holder, t(1.0).0), Some(id));
        assert_eq!(book.withheld_from(other, t(1.0).0), 1);
        assert_eq!(book.consume(holder, t(2.0).0), Some(id));
        assert_eq!(book.withheld_from(other, t(2.0).0), 0);
        // Fully claimed: no more draws.
        assert_eq!(book.consume(holder, t(3.0).0), None);
        // Releasing a claimed lease restores the guarantee.
        book.release_claim(id);
        assert_eq!(book.withheld_from(other, t(4.0).0), 1);
        assert_eq!(book.consume(holder, t(5.0).0), Some(id));
        // Crediting an expired/cancelled reservation is a no-op.
        assert!(book.cancel(id));
        book.release_claim(id);
        assert_eq!(book.withheld_total(t(6.0).0), 0);
    }

    #[test]
    fn non_holder_cannot_consume() {
        let mut book = ReservationBook::new();
        book.reserve(UserId(0), 1, t(0.0), t(10.0));
        assert_eq!(book.consume(UserId(1), t(1.0).0), None);
        // Outside the window the holder cannot consume either.
        assert_eq!(book.consume(UserId(0), t(11.0).0), None);
    }

    #[test]
    fn reap_reclaims_expired_windows() {
        let mut book = ReservationBook::new();
        let a = book.reserve(UserId(0), 1, t(0.0), t(10.0));
        book.reserve(UserId(1), 1, t(0.0), t(50.0));
        assert_eq!(book.reap(t(20.0).0), 1);
        assert!(book.get(a).is_none());
        assert_eq!(book.expired_total(), 1);
        assert_eq!(book.snapshot(t(20.0).0).len(), 1);
        assert_eq!(book.reap(t(20.0).0), 0);
    }

    #[test]
    fn cancel_frees_capacity_immediately() {
        let mut book = ReservationBook::new();
        let id = book.reserve(UserId(0), 3, t(0.0), t(100.0));
        assert_eq!(book.withheld_from(UserId(1), t(1.0).0), 3);
        assert!(book.cancel(id));
        assert!(!book.cancel(id));
        assert_eq!(book.withheld_from(UserId(1), t(1.0).0), 0);
    }
}
