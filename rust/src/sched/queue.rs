//! Priority admission queue with weighted fair-share across tenants
//! and starvation-bounding aging.
//!
//! Ordering is two-level:
//! 1. *effective* request class — `Interactive > Normal > Batch`,
//!    strict, where the effective class of a waiting entry rises with
//!    queue age: every [`AGING_BOOST_GRANTS`] grants that pass over a
//!    still-queued entry promote it one class, and an entry past its
//!    admission deadline is boosted straight to interactive. A
//!    saturating interactive storm therefore cannot starve batch
//!    work indefinitely — a batch ticket is admitted within a bounded
//!    number of grants (see `aging_bounds_batch_starvation` below).
//! 2. within a class, *stride scheduling* over tenants: every tenant
//!    carries a `pass` value that grows by `STRIDE_SCALE / weight`
//!    each time one of its requests is admitted, and the tenant with
//!    the smallest pass goes first. A tenant with weight 2 therefore
//!    receives twice the admissions of a weight-1 tenant over any
//!    contended window. Ties break on submission order (FIFO), which
//!    also keeps a single tenant's requests in order.
//!
//! Entries carry the full admission shape (gang size, co-location,
//! board constraint, deadline) so the scheduler's pump can re-attempt
//! the exact request. The queue never decides *admissibility* itself
//! — the scheduler passes an `admissible` predicate (quota headroom +
//! free capacity for the requested shape) into
//! [`AdmissionQueue::pop_best`], and blocked entries are skipped
//! without losing their place. That is what prevents one tenant
//! sitting at its quota from starving every other tenant behind it.

use std::collections::BTreeMap;

use crate::config::ServiceModel;
use crate::fpga::board::BoardKind;
use crate::util::ids::{TicketId, UserId};

use super::{AdmissionRequest, RequestClass};

/// Pass increment for a weight-1 tenant; a tenant of weight `w`
/// advances by `STRIDE_SCALE / w` per admission.
pub const STRIDE_SCALE: u64 = 1 << 20;

/// Grants that may pass over a waiting entry before its effective
/// class is promoted one step (aging). Batch reaches interactive
/// after `2 * AGING_BOOST_GRANTS` skips, bounding starvation.
pub const AGING_BOOST_GRANTS: u64 = 16;

/// One queued admission request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QueueEntry {
    pub ticket: TicketId,
    pub user: UserId,
    pub model: ServiceModel,
    pub class: RequestClass,
    /// Gang size: regions to grant atomically (all-or-nothing).
    pub regions: u64,
    /// All gang members must land on one device.
    pub co_located: bool,
    /// Restrict to devices of this board model.
    pub board: Option<BoardKind>,
    /// Absolute virtual deadline; past it the entry is boosted to
    /// interactive (deadline boost).
    pub deadline_ns: Option<u64>,
    /// Virtual timestamp of submission (wait-time accounting).
    pub enqueued_ns: u64,
    /// Global submission sequence (FIFO tie-break).
    pub seq: u64,
    /// Grants that popped past this entry while it waited (aging).
    pub skipped: u64,
}

impl QueueEntry {
    /// The class this entry competes at *now*: the submitted class
    /// promoted once per [`AGING_BOOST_GRANTS`] skipped grants, and
    /// all the way to interactive past the deadline.
    pub fn effective_class(&self, now_ns: u64) -> RequestClass {
        if let Some(d) = self.deadline_ns {
            if now_ns >= d {
                return RequestClass::Interactive;
            }
        }
        let mut class = self.class;
        for _ in 0..(self.skipped / AGING_BOOST_GRANTS) {
            class = class.promote();
        }
        class
    }
}

/// The admission queue.
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    entries: Vec<QueueEntry>,
    /// Tenant pass values (persist across pops so fairness holds over
    /// the whole run, not just one backlog).
    passes: BTreeMap<UserId, u64>,
    /// High-water mark of scheduled passes — the queue's virtual
    /// time. Newcomers join here when the queue is empty, so a tenant
    /// arriving after a drain cannot replay the veterans' entire
    /// history of admissions against them.
    pass_floor: u64,
    next_seq: u64,
    next_ticket: u64,
}

impl AdmissionQueue {
    pub fn new() -> AdmissionQueue {
        AdmissionQueue::default()
    }

    /// Enqueue a request; returns its ticket. A relative deadline in
    /// the request becomes an absolute virtual timestamp here.
    pub fn push(
        &mut self,
        req: &AdmissionRequest,
        now_ns: u64,
    ) -> TicketId {
        let ticket = TicketId(self.next_ticket);
        self.next_ticket += 1;
        let seq = self.next_seq;
        self.next_seq += 1;
        // A tenant first seen now starts at the smallest live pass so
        // it cannot leapfrog tenants that have been waiting (nor be
        // penalized for arriving late).
        let floor = self.min_live_pass();
        let pass = self.passes.entry(req.tenant).or_insert(floor);
        *pass = (*pass).max(floor);
        self.entries.push(QueueEntry {
            ticket,
            user: req.tenant,
            model: req.model,
            class: req.class,
            regions: u64::from(req.regions.get()),
            co_located: req.constraints.co_located,
            board: req.constraints.board,
            deadline_ns: req
                .deadline
                .map(|d| now_ns.saturating_add(d.0)),
            enqueued_ns: now_ns,
            seq,
            skipped: 0,
        });
        ticket
    }

    fn min_live_pass(&self) -> u64 {
        self.entries
            .iter()
            .filter_map(|e| self.passes.get(&e.user).copied())
            .min()
            .unwrap_or(self.pass_floor)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Queued requests of one tenant.
    pub fn depth_for(&self, user: UserId) -> usize {
        self.entries.iter().filter(|e| e.user == user).count()
    }

    /// Any queued request effectively at or above `class`?
    pub fn has_class_at_or_above(
        &self,
        class: RequestClass,
        now_ns: u64,
    ) -> bool {
        self.entries
            .iter()
            .any(|e| e.effective_class(now_ns) >= class)
    }

    /// Any queued request effectively strictly above `class`?
    pub fn has_class_above(
        &self,
        class: RequestClass,
        now_ns: u64,
    ) -> bool {
        self.entries
            .iter()
            .any(|e| e.effective_class(now_ns) > class)
    }

    /// Remove a queued request (cancellation). Returns the entry if it
    /// was still queued.
    pub fn remove(&mut self, ticket: TicketId) -> Option<QueueEntry> {
        let idx = self.entries.iter().position(|e| e.ticket == ticket)?;
        Some(self.entries.remove(idx))
    }

    /// Reinsert a previously-popped entry unchanged (same ticket, seq
    /// and enqueue time) — used when an admission raced with an
    /// out-of-band allocation and must go back to the queue.
    pub fn requeue(&mut self, entry: QueueEntry) {
        self.entries.push(entry);
    }

    /// Re-insert an entry recovered from a persisted snapshot or the
    /// write-ahead log after a restart, preserving its ticket and
    /// sequence and bumping the generators past them so fresh
    /// submissions never collide with recovered ones. The tenant's
    /// pass restarts at the smallest live pass (pass history is
    /// in-memory fairness state and does not survive a crash).
    pub fn adopt(&mut self, entry: QueueEntry) {
        self.next_ticket = self.next_ticket.max(entry.ticket.0 + 1);
        self.next_seq = self.next_seq.max(entry.seq + 1);
        let floor = self.min_live_pass();
        let pass = self.passes.entry(entry.user).or_insert(floor);
        *pass = (*pass).max(floor);
        self.entries.push(entry);
    }

    /// A queued entry by ticket (the scheduler journals the full
    /// entry document on enqueue).
    pub fn entry(&self, ticket: TicketId) -> Option<&QueueEntry> {
        self.entries.iter().find(|e| e.ticket == ticket)
    }

    /// Pop the best admissible request: highest *effective* class,
    /// then smallest tenant pass, then FIFO. Advances the winner's
    /// pass by its stride (`STRIDE_SCALE / weight`) and counts one
    /// skipped grant against every entry left waiting (aging).
    /// Entries failing `admissible` keep their place.
    pub fn pop_best(
        &mut self,
        now_ns: u64,
        weight_of: impl Fn(UserId) -> u64,
        admissible: impl Fn(&QueueEntry) -> bool,
    ) -> Option<QueueEntry> {
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            if !admissible(e) {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => {
                    let cur = &self.entries[b];
                    let e_pass =
                        self.passes.get(&e.user).copied().unwrap_or(0);
                    let b_pass =
                        self.passes.get(&cur.user).copied().unwrap_or(0);
                    (
                        std::cmp::Reverse(e.effective_class(now_ns)),
                        e_pass,
                        e.seq,
                    ) < (
                        std::cmp::Reverse(cur.effective_class(now_ns)),
                        b_pass,
                        cur.seq,
                    )
                }
            };
            if better {
                best = Some(i);
            }
        }
        let entry = self.entries.remove(best?);
        for waiting in &mut self.entries {
            waiting.skipped += 1;
        }
        let stride = Self::stride(weight_of(entry.user));
        let pass = self.passes.entry(entry.user).or_insert(0);
        // The winner's pass is the queue's current virtual time.
        self.pass_floor = self.pass_floor.max(*pass);
        *pass += stride;
        Some(entry)
    }

    /// Pass increment for one admission at `weight`. Clamped to ≥ 1
    /// so an absurdly large weight cannot yield a zero stride and
    /// monopolize the queue forever.
    fn stride(weight: u64) -> u64 {
        (STRIDE_SCALE / weight.max(1)).max(1)
    }

    /// Roll back one admission's pass charge (the admission raced
    /// with an out-of-band allocation and was requeued).
    pub fn refund(&mut self, user: UserId, weight: u64) {
        if let Some(pass) = self.passes.get_mut(&user) {
            *pass = pass.saturating_sub(Self::stride(weight));
        }
    }

    /// Immutable view for status RPCs.
    pub fn snapshot(&self) -> Vec<QueueEntry> {
        self.entries.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualTime;

    fn q() -> AdmissionQueue {
        AdmissionQueue::new()
    }

    fn req(
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
    ) -> AdmissionRequest {
        AdmissionRequest::new(user, model, class)
    }

    #[test]
    fn fifo_within_one_tenant() {
        let mut q = q();
        let u = UserId(0);
        let t0 =
            q.push(&req(u, ServiceModel::RAaaS, RequestClass::Batch), 0);
        let t1 =
            q.push(&req(u, ServiceModel::RAaaS, RequestClass::Batch), 0);
        let a = q.pop_best(0, |_| 1, |_| true).unwrap();
        let b = q.pop_best(0, |_| 1, |_| true).unwrap();
        assert_eq!(a.ticket, t0);
        assert_eq!(b.ticket, t1);
        assert!(q.pop_best(0, |_| 1, |_| true).is_none());
    }

    #[test]
    fn adopt_preserves_ticket_and_bumps_generators() {
        let mut q = q();
        let u = UserId(0);
        q.adopt(QueueEntry {
            ticket: TicketId(9),
            user: u,
            model: ServiceModel::RAaaS,
            class: RequestClass::Batch,
            regions: 1,
            co_located: false,
            board: None,
            deadline_ns: None,
            enqueued_ns: 5,
            seq: 4,
            skipped: 0,
        });
        assert_eq!(q.entry(TicketId(9)).unwrap().enqueued_ns, 5);
        // A fresh submission mints past the adopted ticket and seq.
        let fresh =
            q.push(&req(u, ServiceModel::RAaaS, RequestClass::Batch), 6);
        assert!(fresh.0 > 9);
        assert!(q.entry(fresh).unwrap().seq > 4);
        // Both still pop in FIFO order within the tenant.
        let first = q.pop_best(6, |_| 1, |_| true).unwrap();
        assert_eq!(first.ticket, TicketId(9));
    }

    #[test]
    fn higher_class_preempts_queue_order() {
        let mut q = q();
        let u = UserId(0);
        q.push(&req(u, ServiceModel::RAaaS, RequestClass::Batch), 0);
        let hi = q.push(
            &req(u, ServiceModel::RAaaS, RequestClass::Interactive),
            0,
        );
        let first = q.pop_best(0, |_| 1, |_| true).unwrap();
        assert_eq!(first.ticket, hi);
        assert_eq!(first.class, RequestClass::Interactive);
    }

    #[test]
    fn weighted_fair_share_ratio() {
        let mut q = q();
        let heavy = UserId(0);
        let light = UserId(1);
        for _ in 0..30 {
            q.push(&req(heavy, ServiceModel::RAaaS, RequestClass::Batch), 0);
            q.push(&req(light, ServiceModel::RAaaS, RequestClass::Batch), 0);
        }
        let weight = |u: UserId| if u == heavy { 2 } else { 1 };
        // First 12 admissions: heavy should get ~2x light's share.
        let mut heavy_n = 0;
        let mut light_n = 0;
        for _ in 0..12 {
            let e = q.pop_best(0, weight, |_| true).unwrap();
            if e.user == heavy {
                heavy_n += 1;
            } else {
                light_n += 1;
            }
        }
        assert_eq!(heavy_n, 8, "heavy {heavy_n} vs light {light_n}");
        assert_eq!(light_n, 4);
    }

    #[test]
    fn blocked_tenant_does_not_starve_others() {
        let mut q = q();
        let stuck = UserId(0);
        let ok = UserId(1);
        q.push(&req(stuck, ServiceModel::RAaaS, RequestClass::Batch), 0);
        let t =
            q.push(&req(ok, ServiceModel::RAaaS, RequestClass::Batch), 0);
        // `stuck` is at quota: the predicate rejects it.
        let e = q.pop_best(0, |_| 1, |e| e.user != stuck).unwrap();
        assert_eq!(e.ticket, t);
        // The blocked entry kept its place.
        assert_eq!(q.depth_for(stuck), 1);
    }

    #[test]
    fn late_arriving_tenant_cannot_leapfrog() {
        let mut q = q();
        let a = UserId(0);
        let b = UserId(1);
        // a gets two admissions first (its pass advances).
        q.push(&req(a, ServiceModel::RAaaS, RequestClass::Batch), 0);
        q.push(&req(a, ServiceModel::RAaaS, RequestClass::Batch), 0);
        q.pop_best(0, |_| 1, |_| true).unwrap();
        q.pop_best(0, |_| 1, |_| true).unwrap();
        // Now both queue one request: b is new but starts at the live
        // pass floor (a's pass), NOT at zero — so b cannot leapfrog
        // the backlog; the tie breaks FIFO to a, then b goes next once
        // a's pass has advanced past the floor.
        q.push(&req(a, ServiceModel::RAaaS, RequestClass::Batch), 0);
        q.push(&req(b, ServiceModel::RAaaS, RequestClass::Batch), 0);
        let first = q.pop_best(0, |_| 1, |_| true).unwrap();
        let second = q.pop_best(0, |_| 1, |_| true).unwrap();
        assert_eq!(first.user, a, "tie at the floor breaks FIFO");
        assert_eq!(second.user, b, "then the newcomer's floor pass wins");
    }

    #[test]
    fn drain_does_not_reset_the_pass_floor() {
        let mut q = q();
        let veteran = UserId(0);
        let newbie = UserId(1);
        // The veteran accumulates pass through many admissions.
        for _ in 0..50 {
            q.push(
                &req(veteran, ServiceModel::RAaaS, RequestClass::Batch),
                0,
            );
        }
        for _ in 0..50 {
            q.pop_best(0, |_| 1, |_| true).unwrap();
        }
        // Queue drained. A newcomer submitting now starts at the
        // floor, not zero — so the veteran's next request loses at
        // most one round, not fifty.
        q.push(&req(newbie, ServiceModel::RAaaS, RequestClass::Batch), 0);
        q.push(&req(veteran, ServiceModel::RAaaS, RequestClass::Batch), 0);
        let first = q.pop_best(0, |_| 1, |_| true).unwrap();
        let second = q.pop_best(0, |_| 1, |_| true).unwrap();
        assert_eq!(first.user, newbie, "newcomer is at most one stride behind");
        assert_eq!(second.user, veteran);
    }

    #[test]
    fn remove_cancels_a_ticket() {
        let mut q = q();
        let u = UserId(0);
        let t =
            q.push(&req(u, ServiceModel::RAaaS, RequestClass::Batch), 0);
        assert_eq!(q.len(), 1);
        assert!(q.remove(t).is_some());
        assert!(q.remove(t).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn class_visibility_helpers() {
        let mut q = q();
        let u = UserId(0);
        q.push(&req(u, ServiceModel::BAaaS, RequestClass::Batch), 0);
        assert!(q.has_class_at_or_above(RequestClass::Batch, 0));
        assert!(!q.has_class_at_or_above(RequestClass::Interactive, 0));
        q.push(&req(u, ServiceModel::RAaaS, RequestClass::Interactive), 0);
        assert!(q.has_class_at_or_above(RequestClass::Interactive, 0));
        assert_eq!(q.depth_for(u), 2);
        assert_eq!(q.snapshot().len(), 2);
    }

    #[test]
    fn gang_shape_is_preserved_on_the_entry() {
        let mut q = q();
        let u = UserId(0);
        let r = req(u, ServiceModel::RAaaS, RequestClass::Normal)
            .gang(4)
            .co_located()
            .on_board(BoardKind::Vc707);
        let t = q.push(&r, 7);
        let e = q.remove(t).unwrap();
        assert_eq!(e.regions, 4);
        assert!(e.co_located);
        assert_eq!(e.board, Some(BoardKind::Vc707));
        assert_eq!(e.enqueued_ns, 7);
    }

    #[test]
    fn aging_bounds_batch_starvation() {
        // Satellite invariant: a saturating interactive storm still
        // lets a batch ticket through within a bounded number of
        // grants (2 * AGING_BOOST_GRANTS promotions + one stride
        // round once it competes at interactive class).
        let mut q = q();
        let storm = UserId(0);
        let batcher = UserId(1);
        let batch_ticket = q.push(
            &req(batcher, ServiceModel::RAaaS, RequestClass::Batch),
            0,
        );
        let bound = (2 * AGING_BOOST_GRANTS + 4) as usize;
        let mut admitted_after = None;
        for round in 0..(bound + 10) {
            // The storm always has an interactive request waiting.
            q.push(
                &req(storm, ServiceModel::RAaaS, RequestClass::Interactive),
                0,
            );
            let e = q.pop_best(0, |_| 1, |_| true).unwrap();
            if e.ticket == batch_ticket {
                admitted_after = Some(round);
                break;
            }
        }
        let after = admitted_after.expect("batch ticket starved");
        assert!(
            after <= bound,
            "batch admitted only after {after} grants (bound {bound})"
        );
    }

    #[test]
    fn deadline_boosts_to_interactive() {
        let mut q = q();
        let storm = UserId(0);
        let dl = UserId(1);
        // Deadline entry: boosted once the clock passes 100.
        let r = req(dl, ServiceModel::RAaaS, RequestClass::Batch)
            .deadline(VirtualTime(100));
        let t = q.push(&r, 0);
        q.push(&req(storm, ServiceModel::RAaaS, RequestClass::Normal), 0);
        // Before the deadline the normal-class storm wins...
        let first = q.pop_best(50, |_| 1, |_| true).unwrap();
        assert_eq!(first.user, storm);
        // ...after it, the deadline entry competes at interactive.
        q.push(&req(storm, ServiceModel::RAaaS, RequestClass::Normal), 0);
        let second = q.pop_best(150, |_| 1, |_| true).unwrap();
        assert_eq!(second.ticket, t);
    }
}
