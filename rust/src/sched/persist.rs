//! Scheduler state persistence: quotas + usage ledger on disk.
//!
//! The device database already persists as pretty-printed JSON
//! ([`crate::hypervisor::DeviceDb::save`]); this module puts the
//! scheduler's durable accounting — configured tenant quotas and the
//! usage ledger — in a sibling file (`<db-stem>.sched.json`) so a
//! management-node restart cannot reset budgets or forget consumed
//! device-seconds (ROADMAP item). Live state (grants, queue,
//! reservations, in-use concurrency) deliberately does *not*
//! persist: those belong to leases that die with the process.
//!
//! [`crate::sched::Scheduler::attach_persistence`] loads a state file
//! when present and re-saves at every accounting boundary —
//! admissions (which include preemption-downtime charges), releases
//! and quota updates. Queue-pump grants triggered from the blocking
//! wait path's fallback tick persist at the next boundary operation.
//! Writes are sequence-guarded so concurrent snapshots cannot land on
//! disk out of order.

use std::path::{Path, PathBuf};

use super::accounting::UsageLedger;
use super::quota::QuotaBook;
use crate::util::json::Json;

/// Format version stamped into the state file.
pub const STATE_VERSION: u64 = 1;

/// The durable scheduler state.
#[derive(Debug, Default)]
pub struct PersistedState {
    pub quotas: QuotaBook,
    pub usage: UsageLedger,
}

/// Where the scheduler state lives for a device DB at `db_path`:
/// a sibling file named `<stem>.sched.json`.
pub fn sched_state_path(db_path: &Path) -> PathBuf {
    let stem = db_path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("state");
    db_path.with_file_name(format!("{stem}.sched.json"))
}

/// Render the state document (pretty-printed, like the device DB, so
/// operators can inspect it and tests can diff it).
pub fn render(quotas: &QuotaBook, usage: &UsageLedger) -> String {
    Json::obj(vec![
        ("version", Json::from(STATE_VERSION)),
        ("quotas", quotas.to_json()),
        ("usage", usage.to_json()),
    ])
    .to_pretty()
}

/// Parse a state document produced by [`render`].
pub fn parse(text: &str) -> Result<PersistedState, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let version = v.get("version").as_u64().unwrap_or(0);
    if version > STATE_VERSION {
        return Err(format!(
            "sched state version {version} is newer than supported \
             {STATE_VERSION}"
        ));
    }
    Ok(PersistedState {
        quotas: QuotaBook::from_json(v.get("quotas"))?,
        usage: UsageLedger::from_json(v.get("usage"))?,
    })
}

/// Load a state file.
pub fn load(path: &Path) -> Result<PersistedState, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::TenantQuota;
    use crate::util::ids::UserId;

    #[test]
    fn state_path_sits_next_to_db() {
        let p = sched_state_path(Path::new("/var/rc3e/devices.json"));
        assert_eq!(p, PathBuf::from("/var/rc3e/devices.sched.json"));
        let p = sched_state_path(Path::new("cluster.json"));
        assert_eq!(p, PathBuf::from("cluster.sched.json"));
    }

    #[test]
    fn render_parse_roundtrip() {
        let mut quotas = QuotaBook::new();
        quotas.set(
            UserId(2),
            TenantQuota {
                max_concurrent: 4,
                device_seconds_budget: Some(50.0),
                weight: 2,
            },
        );
        let mut usage = UsageLedger::new();
        usage.charge_release(UserId(2), 12.0, 4.0);
        usage.row_mut(UserId(2)).granted = 3;
        let text = render(&quotas, &usage);
        let state = parse(&text).unwrap();
        assert_eq!(
            state.quotas.quota(UserId(2)),
            quotas.quota(UserId(2))
        );
        assert_eq!(state.usage.usage(UserId(2)), usage.usage(UserId(2)));
    }

    #[test]
    fn future_version_is_rejected() {
        let doc = Json::obj(vec![
            ("version", Json::from(STATE_VERSION + 1)),
            ("quotas", Json::Arr(vec![])),
            ("usage", Json::Arr(vec![])),
        ]);
        assert!(parse(&doc.to_string()).is_err());
    }

    #[test]
    fn missing_file_is_typed_error() {
        let err =
            load(Path::new("/nonexistent/rc3e.sched.json")).unwrap_err();
        assert!(err.contains("rc3e.sched.json"), "{err}");
    }
}
