//! Scheduler state persistence: snapshot + write-ahead log on disk.
//!
//! The device database already persists as pretty-printed JSON
//! ([`crate::hypervisor::DeviceDb::save`]); this module puts the
//! scheduler's durable state in a sibling file (`<db-stem>.sched.json`)
//! plus a sibling WAL directory (`<db-stem>.sched.wal/`, see
//! [`crate::journal::SchedWal`]).
//!
//! Format v1 persisted accounting only (quotas + usage ledger); live
//! leases died with the process. Format v2 extends the snapshot with
//! the live control-plane state needed for crash recovery:
//!
//! - `leases` — every active lease as a [`LeaseRecord`] (token, gang
//!   members with placements, accounting inputs),
//! - `queue` — pending admission tickets as [`QueueEntry`] documents,
//! - `wal_cursor` — the last WAL sequence folded into this snapshot;
//!   recovery replays the WAL strictly after this cursor and
//!   compaction drops segments at or before it.
//!
//! [`crate::sched::Scheduler::attach_persistence`] loads snapshot +
//! WAL on boot, re-adopts live leases against the hypervisor, and
//! re-saves at every accounting boundary — admissions (which include
//! preemption-downtime charges), releases and quota updates.
//! Queue-pump grants triggered from the blocking wait path's fallback
//! tick persist at the next boundary operation. Writes are
//! sequence-guarded so concurrent snapshots cannot land on disk out
//! of order, and go through [`crate::util::fsx::write_atomic`] so a
//! crash mid-write can never leave a torn snapshot.

use std::path::{Path, PathBuf};

use super::accounting::UsageLedger;
use super::quota::QuotaBook;
use super::queue::QueueEntry;
use crate::journal::walsched::{
    lease_from_json, lease_to_json, queue_entry_from_json, queue_entry_to_json,
};
use crate::journal::LeaseRecord;
use crate::util::json::Json;

/// Format version stamped into the state file.
pub const STATE_VERSION: u64 = 2;

/// The durable scheduler state.
#[derive(Debug, Default)]
pub struct PersistedState {
    pub quotas: QuotaBook,
    pub usage: UsageLedger,
    /// Live leases at snapshot time (v2; empty for v0/v1 files).
    pub leases: Vec<LeaseRecord>,
    /// Pending admission queue at snapshot time (v2).
    pub queue: Vec<QueueEntry>,
    /// Last WAL sequence already folded into this snapshot; replay
    /// resumes at `wal_cursor + 1`. Zero means "nothing folded".
    pub wal_cursor: u64,
}

/// Where the scheduler state lives for a device DB at `db_path`:
/// a sibling file named `<stem>.sched.json`.
pub fn sched_state_path(db_path: &Path) -> PathBuf {
    let stem = db_path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("state");
    db_path.with_file_name(format!("{stem}.sched.json"))
}

/// Where the scheduler WAL lives for a device DB at `db_path`:
/// a sibling directory named `<stem>.sched.wal`.
pub fn sched_wal_dir(db_path: &Path) -> PathBuf {
    let stem = db_path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("state");
    db_path.with_file_name(format!("{stem}.sched.wal"))
}

/// Render the state document (pretty-printed, like the device DB, so
/// operators can inspect it and tests can diff it).
pub fn render(
    quotas: &QuotaBook,
    usage: &UsageLedger,
    leases: &[LeaseRecord],
    queue: &[QueueEntry],
    wal_cursor: u64,
) -> String {
    Json::obj(vec![
        ("version", Json::from(STATE_VERSION)),
        ("quotas", quotas.to_json()),
        ("usage", usage.to_json()),
        (
            "leases",
            Json::Arr(leases.iter().map(lease_to_json).collect()),
        ),
        (
            "queue",
            Json::Arr(queue.iter().map(queue_entry_to_json).collect()),
        ),
        ("wal_cursor", Json::from(wal_cursor)),
    ])
    .to_pretty()
}

/// Parse a state document produced by [`render`] (any version up to
/// [`STATE_VERSION`]; pre-v2 files simply have no live state).
pub fn parse(text: &str) -> Result<PersistedState, String> {
    let v = Json::parse(text).map_err(|e| e.to_string())?;
    let version = v.get("version").as_u64().unwrap_or(0);
    if version > STATE_VERSION {
        return Err(format!(
            "sched state version {version} is newer than supported \
             {STATE_VERSION}"
        ));
    }
    let mut leases = Vec::new();
    if let Some(arr) = v.get("leases").as_arr() {
        for l in arr {
            leases.push(
                lease_from_json(l).ok_or_else(|| "malformed lease record".to_string())?,
            );
        }
    }
    let mut queue = Vec::new();
    if let Some(arr) = v.get("queue").as_arr() {
        for q in arr {
            queue.push(
                queue_entry_from_json(q)
                    .ok_or_else(|| "malformed queue entry".to_string())?,
            );
        }
    }
    Ok(PersistedState {
        quotas: QuotaBook::from_json(v.get("quotas"))?,
        usage: UsageLedger::from_json(v.get("usage"))?,
        leases,
        queue,
        wal_cursor: v.get("wal_cursor").as_u64().unwrap_or(0),
    })
}

/// Load a state file.
pub fn load(path: &Path) -> Result<PersistedState, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    parse(&text).map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServiceModel;
    use crate::fpga::board::BoardKind;
    use crate::journal::MemberRecord;
    use crate::sched::{GrantTarget, RequestClass, TenantQuota};
    use crate::util::ids::{
        AllocationId, FpgaId, LeaseToken, NodeId, TicketId, UserId, VfpgaId,
    };

    #[test]
    fn state_path_sits_next_to_db() {
        let p = sched_state_path(Path::new("/var/rc3e/devices.json"));
        assert_eq!(p, PathBuf::from("/var/rc3e/devices.sched.json"));
        let p = sched_state_path(Path::new("cluster.json"));
        assert_eq!(p, PathBuf::from("cluster.sched.json"));
        let w = sched_wal_dir(Path::new("/var/rc3e/devices.json"));
        assert_eq!(w, PathBuf::from("/var/rc3e/devices.sched.wal"));
    }

    #[test]
    fn render_parse_roundtrip() {
        let mut quotas = QuotaBook::new();
        quotas.set(
            UserId(2),
            TenantQuota {
                max_concurrent: 4,
                device_seconds_budget: Some(50.0),
                weight: 2,
            },
        );
        let mut usage = UsageLedger::new();
        usage.charge_release(UserId(2), 12.0, 4.0);
        usage.row_mut(UserId(2)).granted = 3;
        let leases = vec![LeaseRecord {
            token: LeaseToken::mint(),
            tenant: UserId(2),
            model: ServiceModel::RAaaS,
            class: RequestClass::Batch,
            co_located: false,
            wait_ns: 1_500_000,
            members: vec![MemberRecord {
                alloc: AllocationId(9),
                target: GrantTarget::Vfpga(VfpgaId(3), FpgaId(1), NodeId(0)),
                units: 1,
                started_ns: 77,
                charge_w: 1.0,
                migrations: 2,
            }],
        }];
        let queue = vec![QueueEntry {
            ticket: TicketId(5),
            user: UserId(2),
            model: ServiceModel::RAaaS,
            class: RequestClass::Batch,
            regions: 2,
            co_located: true,
            board: Some(BoardKind::Vc707),
            deadline_ns: Some(9_000),
            enqueued_ns: 4_000,
            seq: 11,
            skipped: 0,
        }];
        let text = render(&quotas, &usage, &leases, &queue, 42);
        let state = parse(&text).unwrap();
        assert_eq!(state.quotas.quota(UserId(2)), quotas.quota(UserId(2)));
        assert_eq!(state.usage.usage(UserId(2)), usage.usage(UserId(2)));
        assert_eq!(state.wal_cursor, 42);
        assert_eq!(state.leases.len(), 1);
        assert_eq!(state.leases[0].token, leases[0].token);
        assert_eq!(state.leases[0].members.len(), 1);
        assert_eq!(state.leases[0].members[0].alloc, AllocationId(9));
        assert_eq!(state.queue.len(), 1);
        assert_eq!(state.queue[0].ticket, TicketId(5));
        assert_eq!(state.queue[0].board, Some(BoardKind::Vc707));
    }

    #[test]
    fn v1_file_parses_with_empty_live_state() {
        let doc = Json::obj(vec![
            ("version", Json::from(1u64)),
            ("quotas", Json::Arr(vec![])),
            ("usage", Json::Arr(vec![])),
        ]);
        let state = parse(&doc.to_string()).unwrap();
        assert!(state.leases.is_empty());
        assert!(state.queue.is_empty());
        assert_eq!(state.wal_cursor, 0);
    }

    #[test]
    fn future_version_is_rejected() {
        let doc = Json::obj(vec![
            ("version", Json::from(STATE_VERSION + 1)),
            ("quotas", Json::Arr(vec![])),
            ("usage", Json::Arr(vec![])),
        ]);
        assert!(parse(&doc.to_string()).is_err());
    }

    #[test]
    fn missing_file_is_typed_error() {
        let err =
            load(Path::new("/nonexistent/rc3e.sched.json")).unwrap_err();
        assert!(err.contains("rc3e.sched.json"), "{err}");
    }
}
