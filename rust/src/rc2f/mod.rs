//! RC2F — the Reconfigurable Cloud Computing Framework.
//!
//! Section IV-D: the static FPGA design every RAaaS/BAaaS device
//! boots: a PCIe endpoint, a controller with a *global configuration
//! space* (gcs), and up to four vFPGA slots, each with a *user
//! configuration space* (ucs, dual-port memory) and an asynchronous
//! FIFO pair crossing from the system clock into the user clock
//! domain. On the host: the CUDA/OpenCL-inspired API (device control,
//! kernel control, data transfer).
//!
//! Submodules:
//! * [`components`] — the Table II resource/latency model of the
//!   framework blocks;
//! * [`controller`] — gcs/ucs memories, control signals, slot state;
//! * [`stream`] — the streaming engine: real threads moving real
//!   data through [`crate::fifo::AsyncFifo`]s into the PJRT engine,
//!   with virtual-time accounting against the shared PCIe link;
//! * [`host_api`] — the user-facing API surface.

pub mod components;
pub mod controller;
pub mod host_api;
pub mod stream;

pub use components::{ComponentModel, Rc2fDesign};
pub use controller::{ControlSignal, Controller, ControllerError, SlotState};
pub use host_api::{HostApi, HostApiError, HostSession};
pub use stream::{StreamConfig, StreamOutcome, StreamRunner};
