//! The RC2F host API — CUDA/OpenCL-inspired (Section IV-D2).
//!
//! "The API calls are inspired by the interaction between host and
//! GPU in the NVIDIA CUDA programming environment or the OpenCL
//! framework. The three basic types are (a) global device control,
//! status query and configuration, (b) user kernel control, status
//! query and reconfiguration and (c) data transfers."
//!
//! A [`HostSession`] is a user's handle onto one allocated vFPGA; it
//! goes through the device-file registry on every operation so the
//! access-rights layer is actually on the path (a user who lost the
//! lease loses API access immediately).

use std::sync::{Arc, Mutex};

use super::controller::{ControlSignal, Controller, SlotState};
use super::stream::{
    ChunkSink, StreamConfig, StreamOutcome, StreamRunner,
};
use crate::pcie::devfile::{DeviceFileKind, DeviceFileRegistry};
use crate::pcie::DeviceLink;
use crate::util::clock::VirtualClock;
use crate::util::ids::{UserId, VfpgaId};

/// API-level errors.
#[derive(Debug, thiserror::Error)]
pub enum HostApiError {
    #[error("access denied: {0}")]
    Access(String),
    #[error("controller: {0}")]
    Controller(#[from] super::controller::ControllerError),
    #[error("stream: {0}")]
    Stream(String),
    #[error("slot {0} has no configured core")]
    NotConfigured(VfpgaId),
}

/// Node-local API endpoint for one FPGA device running RC2F.
pub struct HostApi {
    pub controller: Arc<Mutex<Controller>>,
    pub registry: Arc<DeviceFileRegistry>,
    pub link: Arc<DeviceLink>,
    pub clock: Arc<VirtualClock>,
    artifact_dir: std::path::PathBuf,
}

impl HostApi {
    pub fn new(
        controller: Arc<Mutex<Controller>>,
        registry: Arc<DeviceFileRegistry>,
        link: Arc<DeviceLink>,
        clock: Arc<VirtualClock>,
    ) -> HostApi {
        HostApi {
            controller,
            registry,
            link,
            clock,
            artifact_dir: crate::runtime::artifact_dir(),
        }
    }

    pub fn with_artifact_dir(mut self, dir: &std::path::Path) -> Self {
        self.artifact_dir = dir.to_path_buf();
        self
    }

    /// (a) Global device status — hypervisor-side, no user check.
    /// Charges the gcs access latency.
    pub fn device_status_word(&self) -> Result<u32, HostApiError> {
        Ok(self
            .controller
            .lock()
            .unwrap()
            .gcs_read(super::controller::gcs_reg::STATUS)?)
    }

    /// Open a session on an allocated vFPGA. Verifies the user owns
    /// the slot's device files.
    pub fn open_session(
        self: &Arc<Self>,
        user: UserId,
        vfpga: VfpgaId,
    ) -> Result<HostSession, HostApiError> {
        let path =
            DeviceFileRegistry::vfpga_path(vfpga, DeviceFileKind::FifoIn, 0);
        self.registry
            .open(&path, Some(user))
            .map_err(|e| HostApiError::Access(e.to_string()))?;
        Ok(HostSession {
            api: Arc::clone(self),
            user,
            vfpga,
        })
    }
}

/// A user's bound handle on one vFPGA.
pub struct HostSession {
    api: Arc<HostApi>,
    pub user: UserId,
    pub vfpga: VfpgaId,
}

impl HostSession {
    /// Re-verify the lease (device files still owned by this user).
    fn check_access(&self) -> Result<(), HostApiError> {
        let path = DeviceFileRegistry::vfpga_path(
            self.vfpga,
            DeviceFileKind::FifoIn,
            0,
        );
        self.api
            .registry
            .open(&path, Some(self.user))
            .map_err(|e| HostApiError::Access(e.to_string()))?;
        Ok(())
    }

    /// (b) Kernel status: the configured core's name, if any.
    pub fn kernel_status(&self) -> Result<Option<String>, HostApiError> {
        self.check_access()?;
        let state = self
            .api
            .controller
            .lock()
            .unwrap()
            .state(self.vfpga)?;
        Ok(match state {
            SlotState::Configured { core, .. } => Some(core),
            _ => None,
        })
    }

    /// (b) Write a user-defined command word into the ucs.
    pub fn write_ucs(&self, addr: usize, value: u32) -> Result<(), HostApiError> {
        self.check_access()?;
        Ok(self
            .api
            .controller
            .lock()
            .unwrap()
            .ucs_write(self.vfpga, addr, value)?)
    }

    /// (b) Read a ucs word.
    pub fn read_ucs(&self, addr: usize) -> Result<u32, HostApiError> {
        self.check_access()?;
        Ok(self
            .api
            .controller
            .lock()
            .unwrap()
            .ucs_read(self.vfpga, addr)?)
    }

    /// (b) Reset the user core.
    pub fn user_reset(&self) -> Result<(), HostApiError> {
        self.check_access()?;
        Ok(self.api.controller.lock().unwrap().signal(
            Some(self.vfpga),
            ControlSignal::UserReset,
        )?)
    }

    /// (b) Toggle the test loopback path.
    pub fn set_loopback(&self, on: bool) -> Result<(), HostApiError> {
        self.check_access()?;
        Ok(self.api.controller.lock().unwrap().signal(
            Some(self.vfpga),
            ControlSignal::TestLoopback(on),
        )?)
    }

    /// (c) Data transfer: stream a job through the configured core.
    /// The core must be configured (the hypervisor does PR before the
    /// user can stream).
    pub fn stream(
        &self,
        cfg: &StreamConfig,
    ) -> Result<StreamOutcome, HostApiError> {
        self.runner_for_stream()?.run(cfg).map_err(HostApiError::Stream)
    }

    /// (c) Data transfer with an observer: identical accounting to
    /// [`HostSession::stream`], but every consumed output chunk is
    /// handed (borrowed, zero-copy) to `sink` before its pooled
    /// buffer is recycled. The out-of-band data plane (protocol 4
    /// binary frames) rides this path.
    pub fn stream_with_sink(
        &self,
        cfg: &StreamConfig,
        sink: ChunkSink<'_>,
    ) -> Result<StreamOutcome, HostApiError> {
        self.runner_for_stream()?
            .run_with_sink(cfg, sink)
            .map_err(HostApiError::Stream)
    }

    fn runner_for_stream(&self) -> Result<StreamRunner, HostApiError> {
        self.check_access()?;
        let state = self
            .api
            .controller
            .lock()
            .unwrap()
            .state(self.vfpga)?;
        if !matches!(state, SlotState::Configured { .. }) {
            return Err(HostApiError::NotConfigured(self.vfpga));
        }
        Ok(StreamRunner::new(
            Arc::clone(&self.api.clock),
            Arc::clone(&self.api.link),
        )
        .with_artifact_dir(&self.api.artifact_dir))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pcie::LinkParams;

    fn api() -> Arc<HostApi> {
        let clock = VirtualClock::new();
        let ids: Vec<VfpgaId> = (0..4).map(VfpgaId).collect();
        let controller =
            Arc::new(Mutex::new(Controller::new(Arc::clone(&clock), &ids)));
        let registry = Arc::new(DeviceFileRegistry::new());
        let link = DeviceLink::new(Arc::clone(&clock), LinkParams::gen2_x4());
        Arc::new(HostApi::new(controller, registry, link, clock))
    }

    #[test]
    fn session_requires_device_files() {
        let api = api();
        // No files created yet → access denied.
        assert!(matches!(
            api.open_session(UserId(1), VfpgaId(0)),
            Err(HostApiError::Access(_))
        ));
        api.registry
            .create_vfpga_files(VfpgaId(0), UserId(1))
            .unwrap();
        assert!(api.open_session(UserId(1), VfpgaId(0)).is_ok());
        // A different user is still rejected.
        assert!(matches!(
            api.open_session(UserId(2), VfpgaId(0)),
            Err(HostApiError::Access(_))
        ));
    }

    #[test]
    fn ucs_roundtrip_through_session() {
        let api = api();
        api.registry
            .create_vfpga_files(VfpgaId(1), UserId(5))
            .unwrap();
        let s = api.open_session(UserId(5), VfpgaId(1)).unwrap();
        s.write_ucs(10, 0xCAFE).unwrap();
        assert_eq!(s.read_ucs(10).unwrap(), 0xCAFE);
        s.user_reset().unwrap();
        assert_eq!(s.read_ucs(10).unwrap(), 0);
    }

    #[test]
    fn lease_revocation_cuts_api_access() {
        let api = api();
        api.registry
            .create_vfpga_files(VfpgaId(2), UserId(7))
            .unwrap();
        let s = api.open_session(UserId(7), VfpgaId(2)).unwrap();
        s.write_ucs(0, 1).unwrap();
        // Hypervisor revokes the lease (removes device files).
        api.registry.remove_vfpga_files(VfpgaId(2));
        assert!(matches!(
            s.write_ucs(0, 2),
            Err(HostApiError::Access(_))
        ));
    }

    #[test]
    fn stream_requires_configured_core() {
        let api = api();
        api.registry
            .create_vfpga_files(VfpgaId(0), UserId(1))
            .unwrap();
        let s = api.open_session(UserId(1), VfpgaId(0)).unwrap();
        let err = s
            .stream(&StreamConfig::matmul16(256))
            .unwrap_err();
        assert!(matches!(err, HostApiError::NotConfigured(_)));
    }

    #[test]
    fn kernel_status_reflects_configuration() {
        let api = api();
        api.registry
            .create_vfpga_files(VfpgaId(3), UserId(1))
            .unwrap();
        let s = api.open_session(UserId(1), VfpgaId(3)).unwrap();
        assert_eq!(s.kernel_status().unwrap(), None);
        {
            let mut c = api.controller.lock().unwrap();
            c.allocate(VfpgaId(3), UserId(1)).unwrap();
            c.mark_configured(VfpgaId(3), "matmul16").unwrap();
        }
        assert_eq!(s.kernel_status().unwrap().as_deref(), Some("matmul16"));
    }

    #[test]
    fn loopback_toggle_via_session() {
        let api = api();
        api.registry
            .create_vfpga_files(VfpgaId(1), UserId(1))
            .unwrap();
        let s = api.open_session(UserId(1), VfpgaId(1)).unwrap();
        s.set_loopback(true).unwrap();
        assert!(api
            .controller
            .lock()
            .unwrap()
            .is_loopback(VfpgaId(1))
            .unwrap());
    }
}
