//! Table II component model: resources and latencies of the RC2F
//! static design as a function of the vFPGA count.
//!
//! Measured rows (Xilinx VC707 / XC7VX485T):
//!
//! | Component           | LUT   | FF    | BRAM | latency  | per-core max |
//! |---------------------|-------|-------|------|----------|--------------|
//! | PCIe endpoint       | 3,268 | 3,592 | 8    |          |              |
//! | RC2F control (gcs)  | 125   | 255   | 1    | 0.198 ms |              |
//! | vFPGA iface (n=1)   | 3,689 | 3,127 | 4    | 0.208 ms | ≈798 MB/s    |
//! | vFPGA iface (n=2)   | 4,414 | 3,790 | 8    | 0.221 ms | ≈397 MB/s    |
//! | vFPGA iface (n=4)   | 5,139 | 4,471 | 16   | 0.273 ms | ≈196 MB/s    |
//!
//! The vFPGA interface grows by ~725 LUT / ~670 FF per *doubling*
//! (an arbiter-tree level), and by 4 BRAM per vFPGA (one FIFO pair).

use crate::fpga::resources::Resources;

/// Fixed blocks of the static design.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ComponentModel;

impl ComponentModel {
    /// PCIe endpoint block.
    pub fn pcie_endpoint() -> Resources {
        Resources::new(3_268, 3_592, 8, 0)
    }

    /// RC2F controller with the global configuration space.
    pub fn control_gcs() -> Resources {
        Resources::new(125, 255, 1, 0)
    }

    /// vFPGA interface fabric for `n` slots (FIFOs, ucs memories,
    /// arbiter tree). Exact at the measured n ∈ {1, 2, 4}.
    pub fn vfpga_interface(n: usize) -> Resources {
        assert!(n >= 1);
        match n {
            1 => Resources::new(3_689, 3_127, 4, 0),
            2 => Resources::new(4_414, 3_790, 8, 0),
            4 => Resources::new(5_139, 4_471, 16, 0),
            _ => {
                // Arbiter-tree model: +725 LUT / +672 FF per doubling,
                // +4 BRAM per vFPGA.
                let levels = (n as f64).log2();
                Resources::new(
                    3_689 + (725.0 * levels) as u64,
                    3_127 + (672.0 * levels) as u64,
                    4 * n as u64,
                    0,
                )
            }
        }
    }

    /// gcs access latency (host→controller register read), Table II.
    pub fn gcs_latency_ms() -> f64 {
        crate::paper::GCS_LATENCY_MS
    }

    /// Total configuration-space access latency (gcs in the RC2F
    /// module and ucs in the vFPGAs) for an `n`-slot design.
    pub fn config_space_latency_ms(n: usize) -> f64 {
        match n {
            0 | 1 => crate::paper::UCS_1V_LATENCY_MS,
            2 => crate::paper::UCS_2V_LATENCY_MS,
            3 => {
                // Interpolated between the measured 2- and 4-slot rows.
                (crate::paper::UCS_2V_LATENCY_MS
                    + crate::paper::UCS_4V_LATENCY_MS)
                    / 2.0
            }
            _ => crate::paper::UCS_4V_LATENCY_MS,
        }
    }

    /// ucs-only component of the access latency.
    pub fn ucs_latency_ms(n: usize) -> f64 {
        Self::config_space_latency_ms(n) - Self::gcs_latency_ms()
    }
}

/// A concrete static design for one device.
#[derive(Debug, Clone, PartialEq)]
pub struct Rc2fDesign {
    pub vfpgas: usize,
}

impl Rc2fDesign {
    pub fn new(vfpgas: usize) -> Rc2fDesign {
        assert!(vfpgas >= 1 && vfpgas <= crate::paper::MAX_VFPGAS);
        Rc2fDesign { vfpgas }
    }

    /// Total static-design footprint (the Table II "Total" row).
    pub fn total_resources(&self) -> Resources {
        ComponentModel::pcie_endpoint()
            .plus(ComponentModel::control_gcs())
            .plus(ComponentModel::vfpga_interface(self.vfpgas))
    }

    /// Device utilization of the static design (the "<3 %" claim).
    pub fn utilization_pct(
        &self,
        device: Resources,
    ) -> (f64, f64, f64, f64) {
        self.total_resources().utilization_pct(device)
    }

    /// Per-vFPGA max FIFO throughput (Table II's right column): the
    /// 800 MB/s Xillybus link minus chunking overhead, shared evenly.
    pub fn per_core_max_mbps(&self) -> f64 {
        crate::paper::FIFO_1V_MBPS / self.vfpgas as f64
    }

    /// Bitstream name for this design.
    pub fn name(&self) -> String {
        format!("rc2f_basic_{}v", self.vfpgas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::board::BoardSpec;

    #[test]
    fn totals_match_table2() {
        // Table II "Total" rows.
        assert_eq!(
            Rc2fDesign::new(1).total_resources(),
            Resources::new(7_082, 6_974, 13, 0)
        );
        assert_eq!(
            Rc2fDesign::new(2).total_resources(),
            Resources::new(7_807, 7_637, 17, 0)
        );
        assert_eq!(
            Rc2fDesign::new(4).total_resources(),
            Resources::new(8_532, 8_318, 25, 0)
        );
    }

    #[test]
    fn utilization_below_three_percent() {
        // The paper's headline: "<3 % of a XC7VX485T for 4 vFPGAs".
        let device = BoardSpec::vc707().resources;
        let (lut, ff, bram, _) = Rc2fDesign::new(4).utilization_pct(device);
        assert!(lut < 3.0, "lut {lut}");
        assert!(ff < 3.0, "ff {ff}");
        assert!(bram < 3.0, "bram {bram}");
        // And matches Table II's quoted percentages.
        assert!((lut - 2.8).abs() < 0.1);
        assert!((ff - 1.4).abs() < 0.1);
        assert!((bram - 2.3).abs() < 0.2);
    }

    #[test]
    fn interface_monotone_in_slots() {
        let mut prev = 0;
        for n in [1, 2, 3, 4] {
            let r = ComponentModel::vfpga_interface(n);
            assert!(r.lut > prev);
            prev = r.lut;
        }
    }

    #[test]
    fn three_slot_interpolation_between_neighbors() {
        let two = ComponentModel::vfpga_interface(2);
        let three = ComponentModel::vfpga_interface(3);
        let four = ComponentModel::vfpga_interface(4);
        assert!(two.lut < three.lut && three.lut < four.lut);
        assert_eq!(three.bram, 12);
    }

    #[test]
    fn latencies_match_table2() {
        assert_eq!(ComponentModel::gcs_latency_ms(), 0.198);
        assert_eq!(ComponentModel::config_space_latency_ms(1), 0.208);
        assert_eq!(ComponentModel::config_space_latency_ms(2), 0.221);
        assert_eq!(ComponentModel::config_space_latency_ms(4), 0.273);
        let l3 = ComponentModel::config_space_latency_ms(3);
        assert!(l3 > 0.221 && l3 < 0.273);
    }

    #[test]
    fn per_core_throughput_shares_link() {
        assert!((Rc2fDesign::new(1).per_core_max_mbps() - 798.0).abs() < 1.0);
        assert!((Rc2fDesign::new(2).per_core_max_mbps() - 399.0).abs() < 2.5);
        assert!((Rc2fDesign::new(4).per_core_max_mbps() - 199.5).abs() < 4.0);
    }

    #[test]
    #[should_panic]
    fn more_than_four_slots_rejected() {
        Rc2fDesign::new(5);
    }
}
