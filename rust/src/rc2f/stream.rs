//! The RC2F streaming path: host ⇄ FIFO ⇄ user core.
//!
//! This is the real request path behind the paper's Section-V
//! experiment ("we stream the data necessary for 100,000 matrix
//! multiplications through the core"):
//!
//! ```text
//!   producer thread ──► in-FIFO ──► core thread (PJRT engine)
//!                                        │
//!   consumer (caller) ◄── out-FIFO ◄─────┘
//! ```
//!
//! Data movement and compute are real: byte chunks cross real bounded
//! [`crate::fifo::AsyncFifo`]s with backpressure, and the core thread
//! executes the HLO artifact on PJRT. *Hardware timing* is accounted
//! in virtual time: each chunk charges
//! `max(link-in share, link-out share, core compute model)` to the
//! stream's timeline — the double-buffered pipeline of the paper's
//! asynchronous FIFOs — which is what reproduces Table III's
//! compute-bound → link-bound crossover.
//!
//! Since the descriptor-ring data plane (`docs/DATAPLANE.md`) the
//! pipeline is zero-copy at every FIFO boundary: the producer fills
//! pooled DMA slots in place (zero steady-state allocations,
//! asserted below), chunks move through the FIFOs as
//! [`Chunk::Pooled`] without copying, and each link crossing posts
//! scatter-gather descriptors on a [`DescriptorRing`] whose batched
//! doorbells amortise the per-transfer protocol overhead. An
//! optional per-chunk sink lets the middleware forward result chunks
//! out-of-band of the JSON envelope.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::fifo::{AsyncFifo, Chunk};
use crate::pcie::ring::{BufferPool, DescriptorRing, RingParams};
use crate::pcie::DeviceLink;
use crate::runtime::engine::{matmul_ref, Engine, Tensor};
use crate::util::bytes::{bytes_to_f32, f32_as_bytes};
use crate::util::clock::{VirtualClock, VirtualTime};
use crate::util::rng::Rng;

/// Host-side job setup charge (driver init, buffer allocation, thread
/// start). Calibrated so Table III runtimes line up; reported
/// separately so benches can show time-with and time-without.
pub const STREAM_SETUP_MS: f64 = 200.0;

/// One streaming job description.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// HLO artifact implementing the core (e.g. "matmul16_b256").
    pub artifact: String,
    /// Matrix dimension N.
    pub matrix_n: usize,
    /// Matrix pairs per chunk (must equal the artifact batch).
    pub chunk_batch: usize,
    /// Total multiplications to stream (paper: 100,000).
    pub total_mults: u64,
    /// Core's compute-bound input-side rate in MB/s (synth report).
    pub compute_rate_mbps: f64,
    /// Workload seed (deterministic stream).
    pub seed: u64,
    /// Validate the first chunk against the pure-Rust reference.
    pub validate_first_chunk: bool,
    /// Fixed link-contention degree. `run_concurrent` pins this to
    /// the stream-group size so the model is deterministic even when
    /// wall-clock skew lets one pipeline finish before the others;
    /// `None` samples the live stream count per chunk.
    pub contenders: Option<usize>,
}

impl StreamConfig {
    /// The paper's 16×16 configuration.
    pub fn matmul16(total_mults: u64) -> StreamConfig {
        StreamConfig {
            artifact: "matmul16_b256".to_string(),
            matrix_n: 16,
            chunk_batch: 256,
            total_mults,
            compute_rate_mbps: crate::paper::MM16_1C_MBPS,
            seed: 0x16,
            validate_first_chunk: true,
            contenders: None,
        }
    }

    /// The paper's 32×32 configuration.
    pub fn matmul32(total_mults: u64) -> StreamConfig {
        StreamConfig {
            artifact: "matmul32_b64".to_string(),
            matrix_n: 32,
            chunk_batch: 64,
            total_mults,
            compute_rate_mbps: crate::paper::MM32_1C_MBPS,
            seed: 0x32,
            validate_first_chunk: true,
            contenders: None,
        }
    }

    /// Bytes entering the FPGA per chunk (two input matrices).
    pub fn chunk_in_bytes(&self) -> u64 {
        2 * (self.chunk_batch * self.matrix_n * self.matrix_n * 4) as u64
    }

    /// Bytes leaving the FPGA per chunk (one result matrix).
    pub fn chunk_out_bytes(&self) -> u64 {
        (self.chunk_batch * self.matrix_n * self.matrix_n * 4) as u64
    }

    pub fn chunks(&self) -> u64 {
        self.total_mults.div_ceil(self.chunk_batch as u64)
    }
}

/// Result of one stream.
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    pub artifact: String,
    pub mults: u64,
    pub input_bytes: u64,
    pub output_bytes: u64,
    /// Modeled per-core runtime excluding setup (Table III style).
    pub virtual_stream: VirtualTime,
    /// Modeled runtime including the fixed setup charge.
    pub virtual_total: VirtualTime,
    /// Real wall-clock of the whole pipeline on this machine.
    pub wall_secs: f64,
    /// Real wall-clock spent inside PJRT execute calls.
    pub compute_wall_secs: f64,
    /// Sum over all result elements (cheap integrity signal).
    pub checksum: f64,
    /// Element mismatches in the validated chunk (must be 0).
    pub validation_failures: u64,
}

impl StreamOutcome {
    /// Input-side throughput over the modeled stream time — the
    /// number Table III reports per core.
    pub fn virtual_mbps(&self) -> f64 {
        let s = self.virtual_stream.as_secs_f64();
        if s > 0.0 {
            self.input_bytes as f64 / 1e6 / s
        } else {
            0.0
        }
    }

    /// Input-side throughput over real wall time on this machine.
    pub fn wall_mbps(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.input_bytes as f64 / 1e6 / self.wall_secs
        } else {
            0.0
        }
    }
}

/// Per-chunk result callback for out-of-band delivery: receives each
/// output chunk's bytes in order; returning `false` detaches the sink
/// (the pipeline keeps draining so accounting stays intact).
pub type ChunkSink<'a> = &'a mut dyn FnMut(&[u8]) -> bool;

/// In-flight DMA slots per pool — double buffering on both sides of
/// the FIFO plus one slot in the core.
const POOL_SLOTS: usize = 4;

/// One producer iteration: synthesize `take` matrix pairs into the
/// scratch halves, fill a pooled DMA slot in place and push it
/// downstream without copying. Returns `false` when the consumer
/// side is gone. Steady state performs **zero heap allocations**
/// (asserted by `producer_steady_state_allocates_zero`).
fn produce_one(
    rng: &mut Rng,
    xs: &mut [f32],
    ys: &mut [f32],
    n2: usize,
    take: usize,
    pool: &Arc<BufferPool>,
    fifo: &AsyncFifo,
) -> bool {
    rng.fill_f32(xs, 1.0);
    rng.fill_f32(ys, 1.0);
    // Short final chunk: zero-pad to the artifact batch (the engine
    // contract is fixed-shape).
    if take * n2 < xs.len() {
        xs[take * n2..].fill(0.0);
        ys[take * n2..].fill(0.0);
    }
    let half = xs.len() * 4;
    let mut buf = pool.acquire();
    let slot = buf.slot_mut();
    slot[..half].copy_from_slice(f32_as_bytes(xs));
    slot[half..2 * half].copy_from_slice(f32_as_bytes(ys));
    buf.set_len(2 * half);
    fifo.push_chunk(Chunk::Pooled(buf)).is_ok()
}

/// Runs streaming jobs against one device link.
pub struct StreamRunner {
    clock: Arc<VirtualClock>,
    link: Arc<DeviceLink>,
    artifact_dir: std::path::PathBuf,
    metrics: Option<Arc<crate::metrics::Registry>>,
}

impl StreamRunner {
    pub fn new(
        clock: Arc<VirtualClock>,
        link: Arc<DeviceLink>,
    ) -> StreamRunner {
        StreamRunner {
            clock,
            link,
            artifact_dir: crate::runtime::artifact_dir(),
            metrics: None,
        }
    }

    pub fn with_artifact_dir(mut self, dir: &std::path::Path) -> Self {
        self.artifact_dir = dir.to_path_buf();
        self
    }

    /// Publish the stream FIFOs' occupancy gauges into `registry`
    /// (`fifo.<artifact>_in.occupancy` etc.) so `rc3e metrics` shows
    /// data-plane backpressure.
    pub fn with_metrics(
        mut self,
        registry: Arc<crate::metrics::Registry>,
    ) -> Self {
        self.metrics = Some(registry);
        self
    }


    /// The core thread's work: compile/load the artifact, align on the
    /// barrier, then pop chunks, execute on PJRT and account virtual
    /// time until the input FIFO drains. Factored out so `run_one`
    /// can guarantee FIFO closure on ANY exit path.
    #[allow(clippy::too_many_arguments)]
    fn core_body(
        core_cfg: &StreamConfig,
        core_in: &Arc<AsyncFifo>,
        core_out: &Arc<AsyncFifo>,
        link: &Arc<DeviceLink>,
        clock: &Arc<VirtualClock>,
        artifact_dir: &std::path::Path,
        core_compute_wall: &Arc<AtomicU64>,
        barrier: &Barrier,
    ) -> Result<VirtualTime, String> {
        let mut engine =
            Engine::new(artifact_dir).map_err(|e| e.to_string())?;
        engine.load(&core_cfg.artifact).map_err(|e| e.to_string())?;

        // Setup charge happens before the stream opens.
        clock.advance(VirtualTime::from_millis_f64(STREAM_SETUP_MS));
        let mut in_stream = link.inbound.open_stream();
        let _out_stream = link.outbound.open_stream();
        // All concurrent cores open their handles before anyone
        // transfers, so every chunk sees the full contention.
        barrier.wait();
        let stream_start = in_stream.cursor();

        let n = core_cfg.matrix_n;
        let batch = core_cfg.chunk_batch;
        let in_bytes = core_cfg.chunk_in_bytes();
        let out_bytes = core_cfg.chunk_out_bytes();
        let compute_per_chunk = VirtualTime::from_secs_f64(
            in_bytes as f64 / (core_cfg.compute_rate_mbps * 1e6),
        );

        // Descriptor rings for both link directions: each chunk posts
        // a scatter-gather span, the batched doorbell amortises the
        // per-transfer overhead, and `charge` produces the fair-share
        // duration folded into the pipeline step below.
        let ring_params = RingParams::default();
        let in_ring = DescriptorRing::new(
            &format!("{}_in", core_cfg.artifact),
            Arc::clone(&link.inbound),
            ring_params,
        );
        let out_ring = DescriptorRing::new(
            &format!("{}_out", core_cfg.artifact),
            Arc::clone(&link.outbound),
            ring_params,
        );
        let out_pool = BufferPool::new(
            &format!("{}_out", core_cfg.artifact),
            out_bytes as usize,
            POOL_SLOTS,
        );

        while let Some(chunk) =
            core_in.pop_chunk().map_err(|e| e.to_string())?
        {
            let half = chunk.len() / 2;
            let xs = Tensor::new(
                vec![batch, n, n],
                bytes_to_f32(&chunk[..half]).map_err(|e| e.to_string())?,
            );
            let ys = Tensor::new(
                vec![batch, n, n],
                bytes_to_f32(&chunk[half..]).map_err(|e| e.to_string())?,
            );
            // Input slot goes back to the producer's pool before the
            // engine runs — that is what keeps the pool bounded.
            drop(chunk);
            let t0 = Instant::now();
            let out = engine
                .matmul(&core_cfg.artifact, xs, ys)
                .map_err(|e| e.to_string())?;
            core_compute_wall
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);

            // DMA descriptor flow: post scatter-gather spans for both
            // directions, charge the link shares (doorbell-amortised
            // overhead), retire the spans once the step is accounted.
            let sg_in = in_ring.post(in_bytes).map_err(|e| e.to_string())?;
            let sg_out =
                out_ring.post(out_bytes).map_err(|e| e.to_string())?;
            let d_in = in_ring.charge(in_bytes, core_cfg.contenders);
            let d_out = out_ring.charge(out_bytes, core_cfg.contenders);

            // Virtual pipeline step: the slowest of {link in, link
            // out, compute} bounds the double-buffered flow.
            let step =
                VirtualTime(d_in.0.max(d_out.0).max(compute_per_chunk.0));
            in_stream.occupy(step);
            in_ring.complete(sg_in);
            out_ring.complete(sg_out);

            let src = f32_as_bytes(&out.data);
            let mut obuf = out_pool.acquire();
            obuf.fill_from(src);
            if core_out.push_chunk(Chunk::Pooled(obuf)).is_err() {
                break;
            }
        }
        in_ring.flush_doorbell();
        out_ring.flush_doorbell();
        Ok(in_stream.elapsed_since(stream_start))
    }

    /// Run one stream on the calling thread (plus its producer/core
    /// threads). `barrier` aligns link-handle opening across
    /// concurrent streams so bandwidth shares are deterministic.
    fn run_one(
        &self,
        cfg: &StreamConfig,
        barrier: Arc<Barrier>,
        mut sink: Option<ChunkSink<'_>>,
    ) -> Result<StreamOutcome, String> {
        let wall_start = Instant::now();
        let in_fifo = AsyncFifo::rc2f_default(&format!("{}_in", cfg.artifact));
        let out_fifo =
            AsyncFifo::rc2f_default(&format!("{}_out", cfg.artifact));
        if let Some(reg) = &self.metrics {
            in_fifo.bind_metrics(reg);
            out_fifo.bind_metrics(reg);
        }

        // ---------------- producer: synthesize the matrix stream ----
        let prod_cfg = cfg.clone();
        let prod_fifo = Arc::clone(&in_fifo);
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(prod_cfg.seed);
            let n2 = prod_cfg.matrix_n * prod_cfg.matrix_n;
            let elems = prod_cfg.chunk_batch * n2;
            let pool = BufferPool::new(
                &format!("{}_in", prod_cfg.artifact),
                prod_cfg.chunk_in_bytes() as usize,
                POOL_SLOTS,
            );
            let mut remaining = prod_cfg.total_mults;
            let mut xs = vec![0.0f32; elems];
            let mut ys = vec![0.0f32; elems];
            while remaining > 0 {
                let take =
                    remaining.min(prod_cfg.chunk_batch as u64) as usize;
                if !produce_one(
                    &mut rng, &mut xs, &mut ys, n2, take, &pool, &prod_fifo,
                ) {
                    return; // consumer gone
                }
                remaining -= take as u64;
            }
            prod_fifo.close();
        });

        // ---------------- core: PJRT execute + virtual accounting ---
        let core_cfg = cfg.clone();
        let core_in = Arc::clone(&in_fifo);
        let core_out = Arc::clone(&out_fifo);
        let link = Arc::clone(&self.link);
        let clock = Arc::clone(&self.clock);
        let artifact_dir = self.artifact_dir.clone();
        let compute_wall_ns = Arc::new(AtomicU64::new(0));
        let core_compute_wall = Arc::clone(&compute_wall_ns);
        let core = std::thread::spawn(move || -> Result<VirtualTime, String> {
            // Whatever happens inside (including early errors before
            // the streaming loop), both FIFOs must end up closed:
            // otherwise the producer blocks on backpressure and the
            // consumer blocks on pop forever.
            let result = Self::core_body(
                &core_cfg,
                &core_in,
                &core_out,
                &link,
                &clock,
                &artifact_dir,
                &core_compute_wall,
                &barrier,
            );
            core_in.close();
            core_out.close();
            result
        });


        // ---------------- consumer: drain, checksum, validate --------
        let mut checksum = 0.0f64;
        let mut output_bytes = 0u64;
        let mut validation_failures = 0u64;
        let mut first = cfg.validate_first_chunk;
        let mut val_rng = Rng::new(cfg.seed);
        while let Some(chunk) =
            out_fifo.pop_chunk().map_err(|e| e.to_string())?
        {
            output_bytes += chunk.len() as u64;
            if let Some(cb) = sink.as_mut() {
                if !cb(&chunk) {
                    sink = None; // receiver gone; keep draining
                }
            }
            let vals = bytes_to_f32(&chunk).map_err(|e| e.to_string())?;
            checksum += vals.iter().map(|v| *v as f64).sum::<f64>();
            if first {
                first = false;
                // Recreate the first chunk like the producer did and
                // compare against the pure-Rust reference.
                let elems = cfg.chunk_batch * cfg.matrix_n * cfg.matrix_n;
                let mut xs = vec![0.0f32; elems];
                let mut ys = vec![0.0f32; elems];
                val_rng.fill_f32(&mut xs, 1.0);
                val_rng.fill_f32(&mut ys, 1.0);
                let take =
                    cfg.total_mults.min(cfg.chunk_batch as u64) as usize;
                let n2 = cfg.matrix_n * cfg.matrix_n;
                if take < cfg.chunk_batch {
                    xs[take * n2..].fill(0.0);
                    ys[take * n2..].fill(0.0);
                }
                let shape = vec![cfg.chunk_batch, cfg.matrix_n, cfg.matrix_n];
                let expect = matmul_ref(
                    &Tensor::new(shape.clone(), xs),
                    &Tensor::new(shape, ys),
                );
                let tol = 1e-3 * cfg.matrix_n as f32;
                for (got, want) in vals.iter().zip(&expect.data) {
                    if (got - want).abs() > tol * want.abs().max(1.0) {
                        validation_failures += 1;
                    }
                }
            }
        }

        producer.join().map_err(|_| "producer panicked")?;
        let virtual_stream = core
            .join()
            .map_err(|_| "core panicked".to_string())??;
        let wall_secs = wall_start.elapsed().as_secs_f64();
        Ok(StreamOutcome {
            artifact: cfg.artifact.clone(),
            mults: cfg.total_mults,
            input_bytes: cfg.chunk_in_bytes() * cfg.chunks(),
            output_bytes,
            virtual_stream,
            virtual_total: virtual_stream
                + VirtualTime::from_millis_f64(STREAM_SETUP_MS),
            wall_secs,
            compute_wall_secs: compute_wall_ns.load(Ordering::Relaxed)
                as f64
                / 1e9,
            checksum,
            validation_failures,
        })
    }

    /// Run a single stream.
    pub fn run(&self, cfg: &StreamConfig) -> Result<StreamOutcome, String> {
        self.run_one(cfg, Arc::new(Barrier::new(1)), None)
    }

    /// Run a single stream, delivering every output chunk to `sink`
    /// in order (the middleware's out-of-band data path). The sink
    /// runs on the calling thread.
    pub fn run_with_sink(
        &self,
        cfg: &StreamConfig,
        sink: ChunkSink<'_>,
    ) -> Result<StreamOutcome, String> {
        self.run_one(cfg, Arc::new(Barrier::new(1)), Some(sink))
    }

    /// Run several streams concurrently (the multi-core rows of
    /// Table III: all cores share this runner's device link).
    pub fn run_concurrent(
        &self,
        cfgs: &[StreamConfig],
    ) -> Result<Vec<StreamOutcome>, String> {
        let barrier = Arc::new(Barrier::new(cfgs.len()));
        // Pin the contention degree: every stream in the group models
        // the full group sharing the link for its whole run.
        let pinned: Vec<StreamConfig> = cfgs
            .iter()
            .map(|c| StreamConfig {
                contenders: Some(c.contenders.unwrap_or(cfgs.len())),
                ..c.clone()
            })
            .collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = pinned
                .iter()
                .map(|cfg| {
                    let b = Arc::clone(&barrier);
                    scope.spawn(move || self.run_one(cfg, b, None))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().map_err(|_| "stream panicked".to_string())?)
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runner() -> Option<(StreamRunner, Arc<VirtualClock>)> {
        let dir = crate::runtime::artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping stream test: run `make artifacts`");
            return None;
        }
        let clock = VirtualClock::new();
        let link = DeviceLink::new(
            Arc::clone(&clock),
            crate::pcie::LinkParams::gen2_x4(),
        );
        Some((StreamRunner::new(Arc::clone(&clock), link), clock))
    }

    #[test]
    fn single_core_16x16_is_compute_bound_at_509() {
        let Some((r, _)) = runner() else { return };
        let cfg = StreamConfig::matmul16(4096);
        let out = r.run(&cfg).unwrap();
        assert_eq!(out.validation_failures, 0);
        let mbps = out.virtual_mbps();
        assert!(
            (mbps - crate::paper::MM16_1C_MBPS).abs() < 12.0,
            "virtual throughput {mbps} MB/s"
        );
    }

    #[test]
    fn two_cores_16x16_share_the_link() {
        let Some((r, _)) = runner() else { return };
        let cfgs = vec![
            StreamConfig::matmul16(2048),
            StreamConfig {
                seed: 0x17,
                ..StreamConfig::matmul16(2048)
            },
        ];
        let outs = r.run_concurrent(&cfgs).unwrap();
        for out in &outs {
            let mbps = out.virtual_mbps();
            // Table III: ~398 MB/s per core.
            assert!(
                (mbps - crate::paper::MM16_2C_MBPS).abs() < 15.0,
                "virtual throughput {mbps}"
            );
            assert_eq!(out.validation_failures, 0);
        }
    }

    #[test]
    fn short_stream_pads_final_chunk() {
        let Some((r, _)) = runner() else { return };
        let mut cfg = StreamConfig::matmul16(300); // 256 + 44
        cfg.validate_first_chunk = true;
        let out = r.run(&cfg).unwrap();
        assert_eq!(out.mults, 300);
        assert_eq!(out.validation_failures, 0);
        // Two chunks of 256 each cross the link.
        assert_eq!(out.input_bytes, 2 * cfg.chunk_in_bytes());
    }

    #[test]
    fn checksum_is_deterministic() {
        let Some((r, _)) = runner() else { return };
        let cfg = StreamConfig::matmul16(512);
        let a = r.run(&cfg).unwrap();
        let b = r.run(&cfg).unwrap();
        assert_eq!(a.checksum, b.checksum);
        assert!(a.checksum.abs() > 0.0);
    }

    #[test]
    fn wall_time_is_positive_and_compute_nonzero() {
        let Some((r, _)) = runner() else { return };
        let out = r.run(&StreamConfig::matmul16(512)).unwrap();
        assert!(out.wall_secs > 0.0);
        assert!(out.compute_wall_secs > 0.0);
        assert!(out.compute_wall_secs <= out.wall_secs);
    }

    #[test]
    fn producer_steady_state_allocates_zero() {
        use crate::util::memprobe;
        let pool = BufferPool::new("alloc_probe", 2048, POOL_SLOTS);
        let fifo = AsyncFifo::new("alloc_probe", 8192);
        let mut rng = Rng::new(7);
        let elems = 256; // two 1 KiB halves per chunk
        let mut xs = vec![0.0f32; elems];
        let mut ys = vec![0.0f32; elems];
        // Warm-up: create the pool slot and grow the queue storage.
        for _ in 0..8 {
            assert!(produce_one(
                &mut rng, &mut xs, &mut ys, elems, 1, &pool, &fifo
            ));
            fifo.pop_chunk().unwrap().unwrap();
        }
        let before = memprobe::thread_allocations();
        for _ in 0..64 {
            assert!(produce_one(
                &mut rng, &mut xs, &mut ys, elems, 1, &pool, &fifo
            ));
            let chunk = fifo.pop_chunk().unwrap().unwrap();
            assert_eq!(chunk.len(), 2048);
        }
        let allocs = memprobe::thread_allocations() - before;
        assert_eq!(allocs, 0, "steady-state producer allocated {allocs}x");
        assert_eq!(pool.created_total(), 1);
    }

    #[test]
    fn sink_receives_all_output_chunks_in_order() {
        let Some((r, _)) = runner() else { return };
        let cfg = StreamConfig::matmul16(512);
        let mut seen = 0u64;
        let mut bytes = 0u64;
        let out = r
            .run_with_sink(&cfg, &mut |chunk: &[u8]| {
                seen += 1;
                bytes += chunk.len() as u64;
                true
            })
            .unwrap();
        assert_eq!(seen, cfg.chunks());
        assert_eq!(bytes, out.output_bytes);
        assert_eq!(out.validation_failures, 0);
    }

    #[test]
    fn sink_detach_keeps_pipeline_draining() {
        let Some((r, _)) = runner() else { return };
        let cfg = StreamConfig::matmul16(1024); // 4 chunks
        let mut seen = 0u64;
        let out = r
            .run_with_sink(&cfg, &mut |_: &[u8]| {
                seen += 1;
                seen < 2
            })
            .unwrap();
        assert_eq!(seen, 2, "sink detached after refusing a chunk");
        assert_eq!(out.output_bytes, cfg.chunk_out_bytes() * cfg.chunks());
    }
}
