//! The RC2F controller: configuration spaces, control signals, slot
//! state machine.
//!
//! Section IV-D1/2: "The main part of the RC2F framework consists of
//! a controller managing the configuration and the user cores as well
//! as the monitoring of status information. The controller's memory
//! space is accessible from the host through the API and on the FPGA
//! via dedicated control signals (full reset, user reset, test
//! loopback, etc.)... As interface to the user cores, a user
//! configuration space (ucs) for user-definable commands is
//! implemented as dual port memory."
//!
//! Access latencies are charged per Table II: 0.198 ms for a gcs
//! access, rising to 0.273 ms total with four vFPGAs.

use std::sync::Arc;

use super::components::ComponentModel;
use crate::util::clock::{VirtualClock, VirtualTime};
use crate::util::ids::{UserId, VfpgaId};

/// gcs register indices (word-addressed).
pub mod gcs_reg {
    /// Framework version word.
    pub const VERSION: usize = 0;
    /// Bitmap of configured slots.
    pub const CONFIGURED: usize = 1;
    /// Bitmap of clock-enabled slots.
    pub const CLOCKED: usize = 2;
    /// Device status word (composed by the controller).
    pub const STATUS: usize = 3;
    /// Scratch / loopback test register.
    pub const SCRATCH: usize = 4;
}

/// Control signals the host can pulse into a slot (Section IV-D1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlSignal {
    /// Reset the whole framework (all slots).
    FullReset,
    /// Reset one user core.
    UserReset,
    /// Route the slot's FIFOs into loopback (bypass the core).
    TestLoopback(bool),
}

/// Lifecycle state of one vFPGA slot.
#[derive(Debug, Clone, PartialEq)]
pub enum SlotState {
    /// No lease on the slot.
    Free,
    /// Leased to a user, not yet configured.
    Allocated { user: UserId },
    /// A user core is configured (and may be streaming).
    Configured { user: UserId, core: String },
}

/// Controller errors.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum ControllerError {
    #[error("no slot {0} in this design")]
    NoSuchSlot(VfpgaId),
    #[error("slot {0} is not allocated")]
    NotAllocated(VfpgaId),
    #[error("ucs address {addr:#x} out of range (size {size:#x})")]
    UcsOutOfRange { addr: usize, size: usize },
    #[error("gcs register {0} out of range")]
    GcsOutOfRange(usize),
}

/// ucs size per slot: 4 KiB of 32-bit words like a BRAM dual-port.
pub const UCS_WORDS: usize = 1024;
/// gcs size: 64 words.
pub const GCS_WORDS: usize = 64;

struct Slot {
    id: VfpgaId,
    state: SlotState,
    ucs: Vec<u32>,
    loopback: bool,
}

/// The per-device RC2F controller instance.
pub struct Controller {
    clock: Arc<VirtualClock>,
    gcs: Vec<u32>,
    slots: Vec<Slot>,
}

impl Controller {
    /// Build a controller for a design with the given slot ids.
    pub fn new(clock: Arc<VirtualClock>, slot_ids: &[VfpgaId]) -> Controller {
        let mut gcs = vec![0u32; GCS_WORDS];
        gcs[gcs_reg::VERSION] = 0x00020005; // "RC2F v2.5"
        Controller {
            clock,
            gcs,
            slots: slot_ids
                .iter()
                .map(|&id| Slot {
                    id,
                    state: SlotState::Free,
                    ucs: vec![0u32; UCS_WORDS],
                    loopback: false,
                })
                .collect(),
        }
    }

    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    pub fn slot_ids(&self) -> Vec<VfpgaId> {
        self.slots.iter().map(|s| s.id).collect()
    }

    fn charge_gcs(&self) -> VirtualTime {
        let d = VirtualTime::from_millis_f64(ComponentModel::gcs_latency_ms());
        self.clock.advance(d);
        d
    }

    fn charge_ucs(&self) -> VirtualTime {
        let d = VirtualTime::from_millis_f64(ComponentModel::ucs_latency_ms(
            self.slots.len(),
        ));
        self.clock.advance(d);
        d
    }

    fn slot(&self, id: VfpgaId) -> Result<&Slot, ControllerError> {
        self.slots
            .iter()
            .find(|s| s.id == id)
            .ok_or(ControllerError::NoSuchSlot(id))
    }

    fn slot_mut(&mut self, id: VfpgaId) -> Result<&mut Slot, ControllerError> {
        self.slots
            .iter_mut()
            .find(|s| s.id == id)
            .ok_or(ControllerError::NoSuchSlot(id))
    }

    // ------------------------------------------------------------ gcs

    /// Host read of a gcs register (charges Table II's 0.198 ms).
    pub fn gcs_read(&self, reg: usize) -> Result<u32, ControllerError> {
        if reg >= GCS_WORDS {
            return Err(ControllerError::GcsOutOfRange(reg));
        }
        self.charge_gcs();
        Ok(match reg {
            gcs_reg::CONFIGURED => self
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| {
                    matches!(s.state, SlotState::Configured { .. })
                })
                .fold(0u32, |acc, (i, _)| acc | (1 << i)),
            gcs_reg::STATUS => {
                // bit0: alive; bits 8.. slot count.
                1 | ((self.slots.len() as u32) << 8)
            }
            r => self.gcs[r],
        })
    }

    /// Host write of a gcs register.
    pub fn gcs_write(&mut self, reg: usize, value: u32) -> Result<(), ControllerError> {
        if reg >= GCS_WORDS {
            return Err(ControllerError::GcsOutOfRange(reg));
        }
        self.charge_gcs();
        self.gcs[reg] = value;
        Ok(())
    }

    // ------------------------------------------------------------ ucs

    /// Host read of a slot's user configuration space word.
    pub fn ucs_read(
        &self,
        slot: VfpgaId,
        addr: usize,
    ) -> Result<u32, ControllerError> {
        let s = self.slot(slot)?;
        if addr >= UCS_WORDS {
            return Err(ControllerError::UcsOutOfRange {
                addr,
                size: UCS_WORDS,
            });
        }
        self.charge_ucs();
        Ok(s.ucs[addr])
    }

    /// Host write of a slot's ucs word (the "user-definable commands"
    /// channel into the core).
    pub fn ucs_write(
        &mut self,
        slot: VfpgaId,
        addr: usize,
        value: u32,
    ) -> Result<(), ControllerError> {
        self.charge_ucs();
        let s = self.slot_mut(slot)?;
        if addr >= UCS_WORDS {
            return Err(ControllerError::UcsOutOfRange {
                addr,
                size: UCS_WORDS,
            });
        }
        s.ucs[addr] = value;
        Ok(())
    }

    // -------------------------------------------------- state machine

    /// Lease a slot to a user.
    pub fn allocate(
        &mut self,
        slot: VfpgaId,
        user: UserId,
    ) -> Result<(), ControllerError> {
        let s = self.slot_mut(slot)?;
        s.state = SlotState::Allocated { user };
        Ok(())
    }

    /// Record a configured core (after PR succeeded on the device).
    pub fn mark_configured(
        &mut self,
        slot: VfpgaId,
        core: &str,
    ) -> Result<(), ControllerError> {
        let s = self.slot_mut(slot)?;
        let user = match &s.state {
            SlotState::Allocated { user }
            | SlotState::Configured { user, .. } => *user,
            SlotState::Free => {
                return Err(ControllerError::NotAllocated(slot))
            }
        };
        s.state = SlotState::Configured {
            user,
            core: core.to_string(),
        };
        Ok(())
    }

    /// Release a lease: blank state, scrub the ucs (no data leaks
    /// between tenants).
    pub fn release(&mut self, slot: VfpgaId) -> Result<(), ControllerError> {
        let s = self.slot_mut(slot)?;
        s.state = SlotState::Free;
        s.ucs.fill(0);
        s.loopback = false;
        Ok(())
    }

    pub fn state(&self, slot: VfpgaId) -> Result<SlotState, ControllerError> {
        Ok(self.slot(slot)?.state.clone())
    }

    pub fn is_loopback(&self, slot: VfpgaId) -> Result<bool, ControllerError> {
        Ok(self.slot(slot)?.loopback)
    }

    /// Pulse a control signal.
    pub fn signal(
        &mut self,
        slot: Option<VfpgaId>,
        sig: ControlSignal,
    ) -> Result<(), ControllerError> {
        self.charge_gcs();
        match sig {
            ControlSignal::FullReset => {
                for s in &mut self.slots {
                    s.ucs.fill(0);
                    s.loopback = false;
                }
                self.gcs[gcs_reg::SCRATCH] = 0;
            }
            ControlSignal::UserReset => {
                let id = slot.expect("UserReset needs a slot");
                let s = self.slot_mut(id)?;
                s.ucs.fill(0);
            }
            ControlSignal::TestLoopback(on) => {
                let id = slot.expect("TestLoopback needs a slot");
                let s = self.slot_mut(id)?;
                s.loopback = on;
            }
        }
        Ok(())
    }
}

impl std::fmt::Debug for Controller {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Controller")
            .field("slots", &self.slots.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn controller() -> (Controller, Arc<VirtualClock>) {
        let clock = VirtualClock::new();
        let ids: Vec<VfpgaId> = (0..4).map(VfpgaId).collect();
        (Controller::new(Arc::clone(&clock), &ids), clock)
    }

    #[test]
    fn gcs_access_charges_198us() {
        let (c, clock) = controller();
        c.gcs_read(gcs_reg::VERSION).unwrap();
        assert!((clock.now().as_millis_f64() - 0.198).abs() < 1e-9);
    }

    #[test]
    fn ucs_access_charges_4slot_latency() {
        let (mut c, clock) = controller();
        c.ucs_write(VfpgaId(0), 0, 7).unwrap();
        // 4-slot ucs-only latency = 0.273 - 0.198 = 0.075 ms.
        assert!((clock.now().as_millis_f64() - 0.075).abs() < 1e-9);
        assert_eq!(c.ucs_read(VfpgaId(0), 0).unwrap(), 7);
    }

    #[test]
    fn version_register() {
        let (c, _) = controller();
        assert_eq!(c.gcs_read(gcs_reg::VERSION).unwrap(), 0x00020005);
    }

    #[test]
    fn configured_bitmap_tracks_slots() {
        let (mut c, _) = controller();
        assert_eq!(c.gcs_read(gcs_reg::CONFIGURED).unwrap(), 0);
        c.allocate(VfpgaId(1), UserId(3)).unwrap();
        c.mark_configured(VfpgaId(1), "matmul16").unwrap();
        assert_eq!(c.gcs_read(gcs_reg::CONFIGURED).unwrap(), 0b0010);
        c.allocate(VfpgaId(3), UserId(3)).unwrap();
        c.mark_configured(VfpgaId(3), "matmul16").unwrap();
        assert_eq!(c.gcs_read(gcs_reg::CONFIGURED).unwrap(), 0b1010);
    }

    #[test]
    fn cannot_configure_unallocated_slot() {
        let (mut c, _) = controller();
        assert_eq!(
            c.mark_configured(VfpgaId(0), "m"),
            Err(ControllerError::NotAllocated(VfpgaId(0)))
        );
    }

    #[test]
    fn release_scrubs_ucs() {
        let (mut c, _) = controller();
        c.allocate(VfpgaId(0), UserId(1)).unwrap();
        c.ucs_write(VfpgaId(0), 5, 0xDEAD).unwrap();
        c.release(VfpgaId(0)).unwrap();
        assert_eq!(c.ucs_read(VfpgaId(0), 5).unwrap(), 0);
        assert_eq!(c.state(VfpgaId(0)).unwrap(), SlotState::Free);
    }

    #[test]
    fn bounds_checked() {
        let (mut c, _) = controller();
        assert!(matches!(
            c.ucs_read(VfpgaId(0), UCS_WORDS),
            Err(ControllerError::UcsOutOfRange { .. })
        ));
        assert!(matches!(
            c.gcs_write(GCS_WORDS, 0),
            Err(ControllerError::GcsOutOfRange(_))
        ));
        assert!(matches!(
            c.ucs_read(VfpgaId(99), 0),
            Err(ControllerError::NoSuchSlot(_))
        ));
    }

    #[test]
    fn loopback_signal_toggles() {
        let (mut c, _) = controller();
        assert!(!c.is_loopback(VfpgaId(2)).unwrap());
        c.signal(Some(VfpgaId(2)), ControlSignal::TestLoopback(true))
            .unwrap();
        assert!(c.is_loopback(VfpgaId(2)).unwrap());
        c.signal(None, ControlSignal::FullReset).unwrap();
        assert!(!c.is_loopback(VfpgaId(2)).unwrap());
    }

    #[test]
    fn user_reset_clears_one_ucs_only() {
        let (mut c, _) = controller();
        c.ucs_write(VfpgaId(0), 1, 11).unwrap();
        c.ucs_write(VfpgaId(1), 1, 22).unwrap();
        c.signal(Some(VfpgaId(0)), ControlSignal::UserReset).unwrap();
        assert_eq!(c.ucs_read(VfpgaId(0), 1).unwrap(), 0);
        assert_eq!(c.ucs_read(VfpgaId(1), 1).unwrap(), 22);
    }
}
