//! The federation coordinator: the management server's cluster
//! brain.
//!
//! Owns the [`NodeRegistry`], the token-home table (`LeaseToken` →
//! owning node — tokens fence ownership across the cluster exactly
//! as they do locally), the blocking cross-node admission loop, the
//! orphan list that drives failure-driven re-admission, and one
//! event-forwarder thread per node that republishes node-local bus
//! events upstream as node-tagged federated events.
//!
//! Ownership rules:
//!
//! * A lease is homed on exactly one node. `admit_remote` records
//!   the home at grant time, together with the admit spec so the
//!   lease can be re-admitted elsewhere (with `adopt` preserving the
//!   token) if its node dies.
//! * When the health monitor declares a node `Down`, every lease
//!   homed there becomes an *orphan*; the monitor's next ticks call
//!   [`Coordinator::retry_orphans`], which re-admits each orphan on
//!   a surviving node via the scheduler's adopt machinery.
//! * A node that rejoins re-registers with the tokens its local WAL
//!   re-adopted. Tokens the cluster has since re-homed elsewhere are
//!   returned in the `release` list (the daemon tears them down
//!   locally); tokens still orphaned re-home on the registrant;
//!   tokens nobody remembers (management restart) are adopted as-is.
//!
//! Cursor federation: each node journals events under its own dense
//! node-local cursor. The per-node forwarder drains `agent.events`
//! from its last-seen cursor and republishes each record as
//! [`Event::NodeTagged`] on the management bus, preserving the
//! original visibility scope. The forwarder (and its cursor) lives
//! across node restarts — it is spawned once per node, not once per
//! registration — so one management `subscribe` stream observes
//! every node's events gaplessly even across a daemon crash.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::placement;
use super::registry::NodeRegistry;
use crate::hypervisor::Hypervisor;
use crate::middleware::api::{
    AgentAdmitRequest, AgentEventsRequest, AllocVfpgaResponse,
    ApiError, ClusterRegisterRequest, ClusterRegisterResponse,
    ErrorCode, Event,
};
use crate::middleware::client::Client;
use crate::middleware::events::{EventBus, Scope};
use crate::util::ids::{LeaseToken, NodeId, UserId};

/// How long `admit_remote` keeps retrying before giving up with
/// `no_capacity` (virtual work completes in wall-milliseconds, so
/// this bounds a genuinely stuck cluster, not a busy one).
const ADMIT_DEADLINE: Duration = Duration::from_secs(60);

/// Backoff between admission placement rounds.
const ADMIT_RETRY: Duration = Duration::from_millis(25);

/// Forwarder long-poll duration per `agent.events` call.
const FORWARD_POLL_S: f64 = 1.0;

/// Forwarder backoff after a connect failure (the node may be dead
/// or mid-restart).
const FORWARD_RECONNECT: Duration = Duration::from_millis(200);

/// Where a live federated lease is homed, plus the spec needed to
/// re-admit it elsewhere if that node dies. `spec` is `None` for
/// leases adopted from a node's registration report (the management
/// server never saw the original admit).
#[derive(Debug, Clone)]
struct Home {
    node: NodeId,
    spec: Option<AgentAdmitRequest>,
}

/// A lease whose home node died: waiting for re-admission.
#[derive(Debug, Clone)]
struct Orphan {
    token: LeaseToken,
    spec: Option<AgentAdmitRequest>,
}

/// The management-side federation coordinator.
pub struct Coordinator {
    hv: Arc<Hypervisor>,
    bus: Arc<EventBus>,
    registry: Arc<NodeRegistry>,
    homes: Mutex<BTreeMap<LeaseToken, Home>>,
    orphans: Mutex<Vec<Orphan>>,
    forwarders: Mutex<BTreeMap<NodeId, JoinHandle<()>>>,
    /// Which bitstream artifacts each node is known to hold — fed by
    /// served `agent.fetch_bitstream` calls and placed core hints,
    /// consumed as the warm tiebreak in [`placement::eligible_warm`].
    served: Mutex<placement::ResidentMap>,
    stop: Arc<AtomicBool>,
}

impl Coordinator {
    pub fn new(
        hv: Arc<Hypervisor>,
        bus: Arc<EventBus>,
    ) -> Arc<Coordinator> {
        let registry = Arc::new(NodeRegistry::new());
        registry.set_metrics(Arc::clone(&hv.metrics));
        Arc::new(Coordinator {
            hv,
            bus,
            registry,
            homes: Mutex::new(BTreeMap::new()),
            orphans: Mutex::new(Vec::new()),
            forwarders: Mutex::new(BTreeMap::new()),
            served: Mutex::new(placement::ResidentMap::new()),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn registry(&self) -> &Arc<NodeRegistry> {
        &self.registry
    }

    pub fn hv(&self) -> &Arc<Hypervisor> {
        &self.hv
    }

    /// Handle `cluster.register` from a (re)joining node: refresh the
    /// registry, reconcile the tokens its WAL re-adopted against the
    /// cluster's token-home table, and make sure an event forwarder
    /// exists for the node.
    pub fn register(
        self: &Arc<Self>,
        req: &ClusterRegisterRequest,
    ) -> Result<ClusterRegisterResponse, ApiError> {
        let addr: SocketAddr = req.addr.parse().map_err(|e| {
            ApiError::bad_request(format!("bad addr '{}': {e}", req.addr))
        })?;
        self.registry.register(
            req.node,
            &req.name,
            addr,
            req.boards.clone(),
            req.regions_total,
        );
        let mut release = Vec::new();
        {
            let mut homes = self.homes.lock().unwrap();
            let mut orphans = self.orphans.lock().unwrap();
            for t in &req.tokens {
                if let Some(home) = homes.get(t) {
                    if home.node != req.node {
                        // Re-homed on a survivor while this node was
                        // away: the registrant's copy must go.
                        release.push(*t);
                    }
                } else if let Some(pos) =
                    orphans.iter().position(|o| o.token == *t)
                {
                    // Still orphaned: the original owner is back
                    // first — re-home it right where it lives.
                    let o = orphans.remove(pos);
                    homes.insert(*t, Home { node: req.node, spec: o.spec });
                } else {
                    // Unknown (management restart): adopt as-is.
                    homes.insert(
                        *t,
                        Home {
                            node: req.node,
                            spec: None,
                        },
                    );
                }
            }
        }
        self.spawn_forwarder(req.node);
        Ok(ClusterRegisterResponse {
            accepted: true,
            release,
        })
    }

    /// Route an admission across the cluster: rank eligible nodes
    /// (most-free first), try each in order, and wait-and-retry when
    /// every candidate is full — the central queue of the federated
    /// deployment. Records the grant's home on success.
    pub fn admit_remote(
        &self,
        req: &AgentAdmitRequest,
    ) -> Result<AllocVfpgaResponse, ApiError> {
        let deadline = Instant::now() + ADMIT_DEADLINE;
        let regions = req.regions.unwrap_or(1);
        loop {
            let snaps = self.registry.snapshot();
            let ranked = {
                let served = self.served.lock().unwrap();
                placement::eligible_warm(
                    &snaps,
                    regions,
                    req.board.as_deref(),
                    req.core.as_deref(),
                    &served,
                )
            };
            for node in ranked {
                let Some(addr) = self.registry.addr_of(node) else {
                    continue;
                };
                let Ok(mut client) = Client::connect(addr) else {
                    continue;
                };
                match client.agent_admit(req) {
                    Ok(resp) => {
                        self.homes.lock().unwrap().insert(
                            resp.lease,
                            Home {
                                node,
                                spec: Some(req.clone()),
                            },
                        );
                        if let Some(core) = &req.core {
                            // The daemon fetches the artifact on its
                            // program path; count the node warm for
                            // future placements of the same design.
                            self.note_cached(node, core);
                        }
                        return Ok(resp);
                    }
                    // The snapshot was a heartbeat stale: the node's
                    // own scheduler is the arbiter. Try the next one.
                    Err(e) if e.code == ErrorCode::NoCapacity => {
                        continue;
                    }
                    Err(e) => return Err(e),
                }
            }
            if Instant::now() >= deadline {
                return Err(ApiError::new(
                    ErrorCode::NoCapacity,
                    "no registered node can serve the request",
                ));
            }
            std::thread::sleep(ADMIT_RETRY);
        }
    }

    /// Record that `node` holds the bitstream artifact for `core` —
    /// called when the management cache serves a node's
    /// `agent.fetch_bitstream` and when a placement carries the core
    /// hint. Future admissions of the same design prefer warm nodes
    /// on free-capacity ties.
    pub fn note_cached(&self, node: NodeId, core: &str) {
        self.served
            .lock()
            .unwrap()
            .entry(node)
            .or_default()
            .insert(core.to_string());
    }

    /// Snapshot of the per-node resident-artifact map (telemetry and
    /// tests).
    pub fn resident_map(&self) -> placement::ResidentMap {
        self.served.lock().unwrap().clone()
    }

    /// Which node a federated lease is homed on.
    pub fn home_of(&self, token: LeaseToken) -> Option<NodeId> {
        self.homes.lock().unwrap().get(&token).map(|h| h.node)
    }

    /// Resolve a federated lease to its home node's daemon address —
    /// the lookup every proxied hop (`stream`, the data-plane relay)
    /// starts with. Distinguishes "no such lease" (`bad_token`) from
    /// "home not registered" (internal: the node is mid-rejoin).
    pub fn agent_addr_of(
        &self,
        token: LeaseToken,
    ) -> Result<(NodeId, SocketAddr), ApiError> {
        let node = self.home_of(token).ok_or_else(|| {
            ApiError::new(
                ErrorCode::BadToken,
                "no federated lease for this token",
            )
        })?;
        let addr = self.registry.addr_of(node).ok_or_else(|| {
            ApiError::internal(format!(
                "lease home {node} not registered"
            ))
        })?;
        Ok((node, addr))
    }

    /// Forget a released lease.
    pub fn forget(&self, token: LeaseToken) {
        self.homes.lock().unwrap().remove(&token);
    }

    /// Count of live federated leases (telemetry).
    pub fn lease_count(&self) -> usize {
        self.homes.lock().unwrap().len()
    }

    /// A node was declared `Down`: every lease homed there becomes
    /// an orphan awaiting re-admission on a survivor.
    pub fn on_node_down(&self, node: NodeId) {
        let mut homes = self.homes.lock().unwrap();
        let dead: Vec<LeaseToken> = homes
            .iter()
            .filter(|(_, h)| h.node == node)
            .map(|(t, _)| *t)
            .collect();
        let mut orphans = self.orphans.lock().unwrap();
        for t in dead {
            let home = homes.remove(&t).expect("collected above");
            log::warn!("node {node} down: lease {t} orphaned");
            orphans.push(Orphan {
                token: t,
                spec: home.spec,
            });
        }
    }

    /// Try to re-admit every orphan on a surviving node, preserving
    /// its token via the adopt path. Orphans without a spec (adopted
    /// from a registration report) wait for their node to rejoin.
    pub fn retry_orphans(&self) {
        let pending: Vec<Orphan> =
            std::mem::take(&mut *self.orphans.lock().unwrap());
        if pending.is_empty() {
            return;
        }
        let mut still = Vec::new();
        for o in pending {
            match self.try_readmit(&o) {
                Some(node) => {
                    self.hv
                        .metrics
                        .counter("cluster.leases.readmitted")
                        .inc();
                    log::info!(
                        "lease {} re-admitted on node {node}",
                        o.token
                    );
                    self.homes.lock().unwrap().insert(
                        o.token,
                        Home {
                            node,
                            spec: o.spec,
                        },
                    );
                }
                None => still.push(o),
            }
        }
        self.orphans.lock().unwrap().extend(still);
    }

    fn try_readmit(&self, o: &Orphan) -> Option<NodeId> {
        let spec = o.spec.as_ref()?;
        let mut req = spec.clone();
        req.adopt = Some(o.token);
        let snaps = self.registry.snapshot();
        let regions = req.regions.unwrap_or(1);
        for node in
            placement::eligible(&snaps, regions, req.board.as_deref())
        {
            let Some(addr) = self.registry.addr_of(node) else {
                continue;
            };
            let Ok(mut client) = Client::connect(addr) else {
                continue;
            };
            if client.agent_admit(&req).is_ok() {
                return Some(node);
            }
        }
        None
    }

    /// Spawn the node's event forwarder if it does not exist yet.
    /// One forwarder per node for the coordinator's whole life: its
    /// in-thread cursor is what keeps the federated stream gapless
    /// across node restarts.
    fn spawn_forwarder(self: &Arc<Self>, node: NodeId) {
        let mut forwarders = self.forwarders.lock().unwrap();
        if forwarders.contains_key(&node) {
            return;
        }
        let this = Arc::clone(self);
        let handle =
            std::thread::spawn(move || forwarder_loop(&this, node));
        forwarders.insert(node, handle);
    }

    /// Stop and join every forwarder (management-server shutdown).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let drained: Vec<(NodeId, JoinHandle<()>)> = {
            let mut forwarders = self.forwarders.lock().unwrap();
            std::mem::take(&mut *forwarders).into_iter().collect()
        };
        for (_, h) in drained {
            let _ = h.join();
        }
    }
}

/// The per-node event pump: long-poll `agent.events` from the last
/// seen node-local cursor and republish each record on the
/// management bus as a node-tagged federated event under its
/// original visibility scope. Reconnects (re-resolving the node's
/// current address) forever; the cursor lives here, so a node that
/// restarts at a new address resumes exactly where it left off.
fn forwarder_loop(co: &Arc<Coordinator>, node: NodeId) {
    let mut cursor = 1u64;
    let mut client: Option<Client> = None;
    while !co.stop.load(Ordering::SeqCst) {
        let Some(c) = client.as_mut() else {
            match co
                .registry
                .addr_of(node)
                .and_then(|a| Client::connect(a).ok())
            {
                Some(c) => client = Some(c),
                None => std::thread::sleep(FORWARD_RECONNECT),
            }
            continue;
        };
        match c.agent_events(&AgentEventsRequest {
            from_cursor: cursor,
            max_events: 256,
            timeout_s: FORWARD_POLL_S,
        }) {
            Ok(resp) => {
                for ev in resp.events {
                    if ev.cursor < cursor {
                        continue;
                    }
                    cursor = ev.cursor + 1;
                    let scope = scope_from_wire(&co.hv, &ev.scope);
                    co.bus.publish(
                        Event::NodeTagged {
                            node,
                            node_cursor: ev.cursor,
                            event: Box::new(ev.event),
                        },
                        scope,
                    );
                }
                cursor = cursor.max(resp.next_cursor);
            }
            Err(_) => {
                // Node unreachable mid-poll: drop the connection and
                // re-resolve (it may re-register at a new address).
                client = None;
                std::thread::sleep(FORWARD_RECONNECT);
            }
        }
    }
}

// ----------------------------------------- scope wire translation

/// Resolve a tenant *name* to this process's local `UserId`, minting
/// one on first sight. Federation identifies tenants by name — each
/// process (management server, each node daemon) keeps its own id
/// space.
pub(crate) fn user_by_name(hv: &Hypervisor, name: &str) -> UserId {
    let mut db = hv.db.lock().unwrap();
    if let Some(id) = db
        .users
        .iter()
        .find(|(_, n)| n.as_str() == name)
        .map(|(id, _)| *id)
    {
        return id;
    }
    db.add_user(name)
}

/// Encode a visibility scope for the wire: `public`,
/// `token:lt-...`, or `tenant:<name>` (names, not ids — id spaces
/// are per-process).
pub(crate) fn scope_to_wire(hv: &Hypervisor, scope: &Scope) -> String {
    match scope {
        Scope::Public => "public".to_string(),
        Scope::Token(t) => format!("token:{t}"),
        Scope::Tenant(u) => {
            let db = hv.db.lock().unwrap();
            match db.user_name(*u) {
                Some(n) => format!("tenant:{n}"),
                None => format!("tenant:{u}"),
            }
        }
    }
}

/// Decode a wire scope back into this process's scope terms.
/// Unparsable scopes degrade to `Public` — over-sharing telemetry is
/// preferable to silently dropping a tenant's events; the bus filter
/// still applies topic filters downstream.
pub(crate) fn scope_from_wire(hv: &Hypervisor, wire: &str) -> Scope {
    if let Some(t) = wire.strip_prefix("token:") {
        if let Some(token) = LeaseToken::parse(t) {
            return Scope::Token(token);
        }
    } else if let Some(name) = wire.strip_prefix("tenant:") {
        return Scope::Tenant(user_by_name(hv, name));
    }
    Scope::Public
}

/// Render the registry snapshot as the `node_list` response body —
/// shared by the federated handler and `rc3e nodes`.
pub fn nodes_body(
    snaps: &[super::registry::NodeSnapshot],
) -> Vec<crate::middleware::api::NodeBody> {
    snaps
        .iter()
        .map(|s| crate::middleware::api::NodeBody {
            node: s.node,
            addr: s.addr.to_string(),
            boards: s.boards.clone(),
            regions_free: s.regions_free,
            regions_active: s.regions_active,
            leases: s.leases,
            heartbeat_age_ms: s.heartbeat_age_ms,
            state: s.state.name().to_string(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn coordinator() -> Arc<Coordinator> {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        Coordinator::new(hv, EventBus::new())
    }

    fn admit_spec(tenant: &str) -> AgentAdmitRequest {
        AgentAdmitRequest {
            tenant: tenant.to_string(),
            model: None,
            class: None,
            regions: None,
            co_located: None,
            board: None,
            core: None,
            adopt: None,
        }
    }

    #[test]
    fn scope_round_trips_through_the_wire() {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        assert_eq!(scope_to_wire(&hv, &Scope::Public), "public");
        let t = LeaseToken::mint();
        let wire = scope_to_wire(&hv, &Scope::Token(t));
        assert_eq!(scope_from_wire(&hv, &wire), Scope::Token(t));
        let alice = hv.add_user("alice");
        let wire = scope_to_wire(&hv, &Scope::Tenant(alice));
        assert_eq!(wire, "tenant:alice");
        assert_eq!(scope_from_wire(&hv, &wire), Scope::Tenant(alice));
        // Unknown wire scopes degrade to public.
        assert_eq!(scope_from_wire(&hv, "???"), Scope::Public);
    }

    #[test]
    fn user_by_name_is_idempotent() {
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap(),
        );
        let a = user_by_name(&hv, "dana");
        let b = user_by_name(&hv, "dana");
        assert_eq!(a, b);
        assert_ne!(a, user_by_name(&hv, "erin"));
    }

    #[test]
    fn node_death_orphans_its_leases() {
        let co = coordinator();
        let t0 = LeaseToken::mint();
        let t1 = LeaseToken::mint();
        co.homes.lock().unwrap().insert(
            t0,
            Home {
                node: NodeId(0),
                spec: Some(admit_spec("a")),
            },
        );
        co.homes.lock().unwrap().insert(
            t1,
            Home {
                node: NodeId(1),
                spec: Some(admit_spec("b")),
            },
        );
        co.on_node_down(NodeId(0));
        assert_eq!(co.home_of(t0), None);
        assert_eq!(co.home_of(t1), Some(NodeId(1)));
        assert_eq!(co.orphans.lock().unwrap().len(), 1);
        // No eligible node: the orphan stays pending.
        co.retry_orphans();
        assert_eq!(co.orphans.lock().unwrap().len(), 1);
    }

    #[test]
    fn register_reconciles_token_ownership() {
        let co = coordinator();
        let kept = LeaseToken::mint();
        let rehomed = LeaseToken::mint();
        let orphaned = LeaseToken::mint();
        co.homes.lock().unwrap().insert(
            kept,
            Home {
                node: NodeId(0),
                spec: None,
            },
        );
        // `rehomed` moved to node 1 while node 0 was away.
        co.homes.lock().unwrap().insert(
            rehomed,
            Home {
                node: NodeId(1),
                spec: None,
            },
        );
        co.orphans.lock().unwrap().push(Orphan {
            token: orphaned,
            spec: Some(admit_spec("a")),
        });
        let resp = co
            .register(&ClusterRegisterRequest {
                node: NodeId(0),
                name: "node-a".to_string(),
                addr: "127.0.0.1:4000".to_string(),
                boards: vec!["vc707".to_string()],
                regions_total: 8,
                tokens: vec![kept, rehomed, orphaned],
            })
            .unwrap();
        assert!(resp.accepted);
        // Only the token the cluster re-homed elsewhere is released.
        assert_eq!(resp.release, vec![rehomed]);
        // The orphan re-homed on the registrant.
        assert_eq!(co.home_of(orphaned), Some(NodeId(0)));
        assert_eq!(co.home_of(kept), Some(NodeId(0)));
        assert!(co.orphans.lock().unwrap().is_empty());
        co.shutdown();
    }

    #[test]
    fn note_cached_builds_the_resident_map() {
        let co = coordinator();
        co.note_cached(NodeId(1), "matmul16");
        co.note_cached(NodeId(1), "matmul16");
        co.note_cached(NodeId(2), "saxpy");
        let map = co.resident_map();
        assert_eq!(map[&NodeId(1)].len(), 1);
        assert!(map[&NodeId(1)].contains("matmul16"));
        assert!(map[&NodeId(2)].contains("saxpy"));
    }

    #[test]
    fn nodes_body_renders_snapshot() {
        let co = coordinator();
        co.registry.register(
            NodeId(0),
            "node-a",
            "127.0.0.1:4001".parse().unwrap(),
            vec!["vc707".to_string()],
            8,
        );
        let body = nodes_body(&co.registry.snapshot());
        assert_eq!(body.len(), 1);
        assert_eq!(body[0].state, "up");
        assert_eq!(body[0].regions_free, 8);
    }
}
