//! The management-side node table.
//!
//! One entry per registered node daemon: dial-back address, board
//! inventory, the vitals cached from the last successful heartbeat
//! ([`crate::middleware::api::AgentPingResponse`]) and the
//! up/suspect/down state machine the health monitor drives. The
//! registry is the single source the placement layer filters over
//! and the `node_list` RPC renders.
//!
//! State machine: a node registers `Up`; [`SUSPECT_AFTER_MISSES`]
//! consecutive missed heartbeats demote it to `Suspect`,
//! [`DOWN_AFTER_MISSES`] to `Down`. A `Down` node is no longer
//! pinged — it rejoins only by re-registering (`cluster.register`),
//! which resets it to `Up`. Every transition updates the
//! `cluster.nodes.{up,suspect,down}` gauges.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::metrics::Registry;
use crate::util::ids::NodeId;

/// Consecutive missed heartbeats before a node turns `Suspect`.
pub const SUSPECT_AFTER_MISSES: u32 = 1;

/// Consecutive missed heartbeats before a node turns `Down` (and its
/// surviving leases become re-admission orphans).
pub const DOWN_AFTER_MISSES: u32 = 3;

/// Health of one registered node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    Up,
    Suspect,
    Down,
}

impl NodeState {
    pub fn name(self) -> &'static str {
        match self {
            NodeState::Up => "up",
            NodeState::Suspect => "suspect",
            NodeState::Down => "down",
        }
    }
}

#[derive(Debug, Clone)]
struct NodeEntry {
    name: String,
    addr: SocketAddr,
    boards: Vec<String>,
    regions_total: u64,
    regions_free: u64,
    regions_active: u64,
    leases: u64,
    next_cursor: u64,
    last_ok: Instant,
    misses: u32,
    state: NodeState,
}

/// A point-in-time copy of one node's registry entry (what placement
/// filters and `node_list` renders).
#[derive(Debug, Clone)]
pub struct NodeSnapshot {
    pub node: NodeId,
    pub name: String,
    pub addr: SocketAddr,
    pub boards: Vec<String>,
    pub state: NodeState,
    pub regions_total: u64,
    pub regions_free: u64,
    pub regions_active: u64,
    pub leases: u64,
    pub next_cursor: u64,
    pub heartbeat_age_ms: f64,
}

/// The node table. All methods take `&self`; one mutex guards the
/// map (registration and heartbeats are rare next to admissions).
#[derive(Debug, Default)]
pub struct NodeRegistry {
    nodes: Mutex<BTreeMap<NodeId, NodeEntry>>,
    metrics: Mutex<Option<Arc<Registry>>>,
}

impl NodeRegistry {
    pub fn new() -> NodeRegistry {
        NodeRegistry::default()
    }

    /// Wire the `cluster.nodes.*` gauges.
    pub fn set_metrics(&self, metrics: Arc<Registry>) {
        *self.metrics.lock().unwrap() = Some(metrics);
        self.update_gauges();
    }

    /// Insert or refresh a node (registration and re-registration
    /// both land here). The node always comes back `Up` — rejoin is
    /// an explicit re-register, never a lucky heartbeat.
    pub fn register(
        &self,
        node: NodeId,
        name: &str,
        addr: SocketAddr,
        boards: Vec<String>,
        regions_total: u64,
    ) {
        let mut nodes = self.nodes.lock().unwrap();
        let entry = NodeEntry {
            name: name.to_string(),
            addr,
            boards,
            regions_total,
            // Until the first heartbeat reports real vitals, assume
            // the node is empty so placement does not starve it.
            regions_free: regions_total,
            regions_active: 0,
            leases: 0,
            next_cursor: 1,
            last_ok: Instant::now(),
            misses: 0,
            state: NodeState::Up,
        };
        nodes.insert(node, entry);
        drop(nodes);
        self.update_gauges();
    }

    /// Record a successful heartbeat with the vitals it returned.
    pub fn record_ok(
        &self,
        node: NodeId,
        leases: u64,
        regions_free: u64,
        regions_active: u64,
        next_cursor: u64,
    ) {
        let mut changed = false;
        {
            let mut nodes = self.nodes.lock().unwrap();
            if let Some(e) = nodes.get_mut(&node) {
                e.leases = leases;
                e.regions_free = regions_free;
                e.regions_active = regions_active;
                e.next_cursor = next_cursor;
                e.last_ok = Instant::now();
                e.misses = 0;
                changed = e.state != NodeState::Up;
                // A Down node never self-heals via heartbeat (it is
                // not pinged); Suspect recovers here.
                if e.state == NodeState::Suspect {
                    e.state = NodeState::Up;
                }
            }
        }
        if changed {
            self.update_gauges();
        }
    }

    /// Record a missed heartbeat; returns the new state when the
    /// miss caused a transition (the `Down` edge is what triggers
    /// failure-driven re-admission).
    pub fn record_miss(&self, node: NodeId) -> Option<NodeState> {
        let transition = {
            let mut nodes = self.nodes.lock().unwrap();
            let e = nodes.get_mut(&node)?;
            if e.state == NodeState::Down {
                return None;
            }
            e.misses += 1;
            let next = if e.misses >= DOWN_AFTER_MISSES {
                NodeState::Down
            } else if e.misses >= SUSPECT_AFTER_MISSES {
                NodeState::Suspect
            } else {
                e.state
            };
            if next == e.state {
                None
            } else {
                e.state = next;
                Some(next)
            }
        };
        if transition.is_some() {
            self.update_gauges();
        }
        transition
    }

    /// Dial-back address of one node.
    pub fn addr_of(&self, node: NodeId) -> Option<SocketAddr> {
        self.nodes.lock().unwrap().get(&node).map(|e| e.addr)
    }

    /// Point-in-time copy of every entry, in `NodeId` order.
    pub fn snapshot(&self) -> Vec<NodeSnapshot> {
        self.nodes
            .lock()
            .unwrap()
            .iter()
            .map(|(id, e)| NodeSnapshot {
                node: *id,
                name: e.name.clone(),
                addr: e.addr,
                boards: e.boards.clone(),
                state: e.state,
                regions_total: e.regions_total,
                regions_free: e.regions_free,
                regions_active: e.regions_active,
                leases: e.leases,
                next_cursor: e.next_cursor,
                heartbeat_age_ms: e.last_ok.elapsed().as_secs_f64()
                    * 1e3,
            })
            .collect()
    }

    /// Nodes currently pingable (everything not `Down`).
    pub fn pingable(&self) -> Vec<(NodeId, SocketAddr)> {
        self.nodes
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, e)| e.state != NodeState::Down)
            .map(|(id, e)| (*id, e.addr))
            .collect()
    }

    fn update_gauges(&self) {
        let metrics = self.metrics.lock().unwrap().clone();
        let Some(m) = metrics else { return };
        let (mut up, mut suspect, mut down) = (0i64, 0i64, 0i64);
        for e in self.nodes.lock().unwrap().values() {
            match e.state {
                NodeState::Up => up += 1,
                NodeState::Suspect => suspect += 1,
                NodeState::Down => down += 1,
            }
        }
        m.gauge("cluster.nodes.up").set(up);
        m.gauge("cluster.nodes.suspect").set(suspect);
        m.gauge("cluster.nodes.down").set(down);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    #[test]
    fn misses_walk_up_to_down_and_register_resets() {
        let r = NodeRegistry::new();
        r.register(NodeId(0), "node-a", addr(9000), vec![], 8);
        assert_eq!(r.record_miss(NodeId(0)), Some(NodeState::Suspect));
        assert_eq!(r.record_miss(NodeId(0)), None);
        assert_eq!(r.record_miss(NodeId(0)), Some(NodeState::Down));
        // Down is sticky: further misses report nothing, and an ok
        // cannot resurrect it either.
        assert_eq!(r.record_miss(NodeId(0)), None);
        r.record_ok(NodeId(0), 0, 8, 0, 1);
        assert_eq!(r.snapshot()[0].state, NodeState::Down);
        // Only re-registration brings it back.
        r.register(NodeId(0), "node-a", addr(9001), vec![], 8);
        let snap = r.snapshot();
        assert_eq!(snap[0].state, NodeState::Up);
        assert_eq!(snap[0].addr, addr(9001));
    }

    #[test]
    fn suspect_recovers_on_ok() {
        let r = NodeRegistry::new();
        r.register(NodeId(1), "node-b", addr(9002), vec![], 8);
        assert_eq!(r.record_miss(NodeId(1)), Some(NodeState::Suspect));
        r.record_ok(NodeId(1), 2, 5, 3, 7);
        let snap = r.snapshot();
        assert_eq!(snap[0].state, NodeState::Up);
        assert_eq!(snap[0].leases, 2);
        assert_eq!(snap[0].regions_free, 5);
        assert_eq!(snap[0].next_cursor, 7);
    }

    #[test]
    fn gauges_track_state_counts() {
        let m = Arc::new(Registry::new());
        let r = NodeRegistry::new();
        r.set_metrics(Arc::clone(&m));
        r.register(NodeId(0), "a", addr(9003), vec![], 8);
        r.register(NodeId(1), "b", addr(9004), vec![], 8);
        assert_eq!(m.gauge("cluster.nodes.up").get(), 2);
        r.record_miss(NodeId(1));
        assert_eq!(m.gauge("cluster.nodes.up").get(), 1);
        assert_eq!(m.gauge("cluster.nodes.suspect").get(), 1);
        for _ in 0..2 {
            r.record_miss(NodeId(1));
        }
        assert_eq!(m.gauge("cluster.nodes.down").get(), 1);
    }

    #[test]
    fn pingable_excludes_down_nodes() {
        let r = NodeRegistry::new();
        r.register(NodeId(0), "a", addr(9005), vec![], 8);
        r.register(NodeId(1), "b", addr(9006), vec![], 8);
        for _ in 0..3 {
            r.record_miss(NodeId(0));
        }
        let p = r.pingable();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].0, NodeId(1));
    }
}
