//! Cluster federation: node daemons, cross-node placement, failure
//! detection and federated event streams.
//!
//! The paper's deployment model (Section IV-C) is a management node
//! fronting many FPGA nodes over Gigabit Ethernet. This module is
//! that split made real: each node runs a [`node::NodeDaemon`] that
//! owns its local hypervisor, devices, scheduler and per-node WAL
//! under its own `--state` directory, and the management server
//! routes admissions across registered nodes instead of owning any
//! device itself.
//!
//! * [`node`] — the per-node daemon (grown from the old
//!   `middleware::agent` status seam, which still lives here as
//!   [`node::NodeAgent`]): serves the `agent.*` methods over the same
//!   typed v3 envelopes as the management server.
//! * [`registry`] — the management-side node table: address, boards,
//!   cached vitals, heartbeat age and the up/suspect/down state
//!   machine behind `node_list` and the `cluster.nodes.*` gauges.
//! * [`placement`] — pure placement policy: filter registered nodes
//!   by health, board constraint and free capacity, rank most-free
//!   first. Gang and co-location constraints stay node-local — a
//!   request lands whole on one node.
//! * [`health`] — the heartbeat monitor: pings every node, demotes
//!   missed beats to `suspect` then `down`, and triggers
//!   failure-driven re-admission.
//! * [`federation`] — the coordinator: token-home bookkeeping
//!   (`LeaseToken`s fence ownership across the cluster exactly as
//!   they do locally), the blocking cross-node admission loop,
//!   orphan re-admission after node death (reusing the scheduler's
//!   adopt machinery), and per-node event forwarders that republish
//!   node-local bus events upstream as node-tagged federated events.
//!
//! See `docs/FEDERATION.md` for the full topology, the failure and
//! rejoin sequences, and the cursor-federation contract.

pub mod federation;
pub mod health;
pub mod node;
pub mod placement;
pub mod registry;

pub use federation::Coordinator;
pub use health::HealthMonitor;
pub use node::{NodeAgent, NodeDaemon};
pub use registry::{NodeRegistry, NodeSnapshot, NodeState};
