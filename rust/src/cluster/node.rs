//! The per-node daemon, grown from the old `middleware::agent`
//! status seam.
//!
//! Two servers live here:
//!
//! * [`NodeAgent`] — the original thin agent: serves `agent.hello`
//!   and `agent.status` against a *shared* hypervisor (the
//!   single-process deployment, where the management server owns the
//!   devices and routes status reads through the agent for the
//!   management-node → node Ethernet hop).
//! * [`NodeDaemon`] — the federated node: owns its *local*
//!   [`Hypervisor`], devices, event journal and scheduler WAL under
//!   its own `--state` directory, and additionally serves
//!   `agent.ping` / `agent.admit` / `agent.release` /
//!   `agent.program` / `agent.stream` / `agent.events` so the
//!   management server can place work on it and federate its event
//!   stream upstream.
//!
//! Both speak the same typed, versioned envelopes as the management
//! server ([`crate::middleware::api`]); protocol 1 is retired here
//! too — proto-less requests are rejected with `protocol_mismatch`.
//!
//! Connection handling is shutdown-clean: the accept loop re-checks
//! the stop flag *after* `accept` returns (the wake-up connection a
//! shutdown sends must not spawn a handler), every per-connection
//! thread's handle is retained and joined on shutdown, and handlers
//! poll the stop flag on a short read timeout instead of blocking in
//! `read` forever on an idle connection.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::bitstream::Bitstream;
use crate::config::{ClusterConfig, ServiceModel};
use crate::fpga::board::BoardKind;
use crate::hypervisor::{Hypervisor, PlacementPolicy};
use crate::journal::EventJournal;
use crate::middleware::api::{
    AgentAdmitRequest, AgentEventsRequest, AgentEventsResponse,
    AgentHelloRequest, AgentHelloResponse, AgentPingResponse,
    AgentProgramRequest, AgentReleaseRequest, AgentStreamRequest,
    AllocVfpgaResponse, ApiError, ClusterRegisterRequest,
    ClusterRegisterResponse, ErrorCode, GangMemberBody, Method,
    NodeEventBody, ProgramCoreResponse, ReleaseResponse, StatusRequest,
    StatusResponse, StreamOutcomeBody, PROTO_DATA_FRAMES,
};
use crate::middleware::client::Client;
use crate::middleware::events::EventBus;
use crate::middleware::proto::{
    read_frame, respond, write_bin_frame, write_data_frame,
    write_frame, BinFrame, Request, Response, StreamFrame,
};
use crate::sched::{AdmissionRequest, RequestClass, Scheduler};
use crate::util::clock::VirtualClock;
use crate::util::ids::NodeId;
use crate::util::json::Json;

/// How often a parked connection handler re-checks the stop flag
/// while waiting for the next request frame.
const CONN_POLL: Duration = Duration::from_millis(200);

/// Long-poll tick for `agent.events`.
const EVENTS_POLL: Duration = Duration::from_millis(25);

/// Spawn the shared accept loop: re-checks `stop` after every accept
/// (a shutdown wake-up connection must not spawn a handler) and
/// retains each handler's `JoinHandle` so shutdown can join the
/// in-flight connections instead of leaking them.
fn spawn_accept_loop(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    serve: Arc<dyn Fn(TcpStream) + Send + Sync>,
) -> JoinHandle<()> {
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let Ok(stream) = conn else { continue };
            // Re-check after accept: this connection may be the
            // shutdown wake-up, which must not get a handler.
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let serve = Arc::clone(&serve);
            let handle = std::thread::spawn(move || serve(stream));
            let mut held = conns.lock().unwrap();
            // Reap handlers that already finished so the vector stays
            // bounded by the number of *live* connections.
            held.retain(|h: &JoinHandle<()>| !h.is_finished());
            held.push(handle);
        }
    })
}

/// Join the accept thread and every connection handler.
fn join_all(
    handle: &mut Option<JoinHandle<()>>,
    conns: &Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    if let Some(h) = handle.take() {
        let _ = h.join();
    }
    let drained: Vec<JoinHandle<()>> =
        std::mem::take(&mut *conns.lock().unwrap());
    for h in drained {
        let _ = h.join();
    }
}

/// Read the next request frame on a stop-polling connection: blocks
/// at most [`CONN_POLL`] at a time, returning `None` when the peer
/// hung up or the server is stopping.
fn next_frame(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> std::io::Result<Option<Json>> {
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(None);
        }
        match read_frame(stream) {
            Ok(f) => return Ok(f),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
}

// ===================================================== NodeAgent

/// A running node agent (owns its listener thread).
pub struct NodeAgent {
    pub node: NodeId,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NodeAgent {
    /// Spawn an agent for `node`, serving device ops from the shared
    /// hypervisor state (the process model is simulated; the wire is
    /// real TCP on loopback).
    pub fn spawn(
        hv: Arc<Hypervisor>,
        node: NodeId,
        fail_plan: Option<Arc<crate::testing::FailPlan>>,
    ) -> std::io::Result<NodeAgent> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let stop2 = Arc::clone(&stop);
        let serve: Arc<dyn Fn(TcpStream) + Send + Sync> =
            Arc::new(move |stream| {
                let _ = serve_agent_conn(
                    stream,
                    Arc::clone(&hv),
                    node,
                    fail_plan.clone(),
                    &stop2,
                );
            });
        let handle = spawn_accept_loop(
            listener,
            Arc::clone(&stop),
            Arc::clone(&conns),
            serve,
        );
        Ok(NodeAgent {
            node,
            addr,
            stop,
            handle: Some(handle),
            conns,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting (kicks the listener with a dummy connection)
    /// and join every in-flight connection handler.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        join_all(&mut self.handle, &self.conns);
    }
}

impl Drop for NodeAgent {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_agent_conn(
    mut stream: TcpStream,
    hv: Arc<Hypervisor>,
    node: NodeId,
    plan: Option<Arc<crate::testing::FailPlan>>,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(CONN_POLL))?;
    while let Some(frame) = next_frame(&mut stream, stop)? {
        if let Some(p) = &plan {
            if p.should_fail("agent.drop_conn") {
                // Simulated agent crash mid-request.
                stream.flush()?;
                return Ok(());
            }
        }
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::failure(None, ApiError::bad_request(e)),
            Ok(req) => {
                let result = req.negotiate_proto().and_then(|_| {
                    dispatch_agent(&hv, node, &req.method, &req.params)
                });
                respond(req.id, result)
            }
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

fn dispatch_agent(
    hv: &Hypervisor,
    node: NodeId,
    method: &str,
    params: &Json,
) -> Result<Json, ApiError> {
    match Method::parse(method) {
        Some(Method::AgentHello) => {
            let _req = AgentHelloRequest::from_json(params)?;
            Ok(AgentHelloResponse {
                node,
                version: crate::VERSION.to_string(),
            }
            .to_json())
        }
        Some(Method::AgentStatus) => {
            let req = StatusRequest::from_json(params)?;
            // The agent performs the *local* status call (Table I's
            // 11 ms path); the management server adds the RPC charge.
            let st =
                hv.status_local(req.fpga).map_err(ApiError::from)?;
            Ok(StatusResponse::from_status(&st).to_json())
        }
        _ => Err(ApiError::new(
            ErrorCode::UnknownMethod,
            format!("agent: unknown method '{method}'"),
        )),
    }
}

// ==================================================== NodeDaemon

struct DaemonInner {
    node: NodeId,
    name: String,
    hv: Arc<Hypervisor>,
    sched: Arc<Scheduler>,
    bus: Arc<EventBus>,
    journal: Arc<EventJournal>,
    cores: BTreeMap<String, Bitstream>,
    /// Management server address, recorded at registration — where
    /// `agent.program` fetches artifacts the local library lacks.
    home: Mutex<Option<SocketAddr>>,
    /// Artifacts pulled from the management cache, by core name.
    /// CRC-verified on receipt (the client rejects corrupt
    /// transfers), retained for the daemon's life.
    fetched: Mutex<BTreeMap<String, Bitstream>>,
    stop: Arc<AtomicBool>,
}

/// A federated node daemon: owns its local hypervisor, devices,
/// event journal and scheduler WAL, and serves the full `agent.*`
/// surface so the management server can place and fence work here.
pub struct NodeDaemon {
    inner: Arc<DaemonInner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NodeDaemon {
    /// Boot node `index` of `config` and serve it on an ephemeral
    /// loopback port. The daemon boots only its own boards (earlier
    /// nodes are padded empty so the hypervisor assigns the
    /// cluster-wide `NodeId`), restores users and id floors from a
    /// previous life's device DB under `state_dir`, journals every
    /// bus event to `state_dir/events/` and replays its scheduler
    /// WAL — surviving leases are re-adopted and reported to the
    /// management server on [`NodeDaemon::register`].
    pub fn spawn(
        config: &ClusterConfig,
        index: usize,
        state_dir: &Path,
        clock: Arc<VirtualClock>,
    ) -> Result<NodeDaemon, String> {
        let local = config.for_node(index)?;
        let name = local.nodes[index].name.clone();
        std::fs::create_dir_all(state_dir).map_err(|e| {
            format!("state dir {}: {e}", state_dir.display())
        })?;
        let hv = Arc::new(
            Hypervisor::boot(&local, clock, PlacementPolicy::ConsolidateFirst)
                .map_err(|e| e.to_string())?,
        );
        let db_path = state_dir.join("devices.json");
        {
            let mut db = hv.db.lock().unwrap();
            if db_path.exists() {
                // A restarted daemon must mint the same UserIds for
                // the same tenants (WAL recovery matches on tenant
                // id) and never reuse a pre-crash AllocationId.
                let old = crate::hypervisor::DeviceDb::load(&db_path)?;
                for (id, uname) in &old.users {
                    db.users.insert(*id, uname.clone());
                    db.user_ids.bump_past(id.0);
                }
                for id in old.allocations.keys() {
                    db.alloc_ids.bump_past(id.0);
                }
            }
            // Partition the allocation-id space per node so ids stay
            // cluster-unique without coordination: node N mints from
            // (N+1) << 20 upward.
            db.alloc_ids.bump_past(((index as u64) + 1) << 20);
        }
        let journal = Arc::new(
            EventJournal::open(&state_dir.join("events"))
                .map_err(|e| format!("event journal: {e}"))?,
        );
        journal.set_metrics(Arc::clone(&hv.metrics));
        let bus = EventBus::new();
        bus.set_metrics(Arc::clone(&hv.metrics));
        bus.attach_journal(Arc::clone(&journal));
        let sched = Scheduler::new(Arc::clone(&hv));
        crate::middleware::server::wire_event_sources(&hv, &sched, &bus);
        hv.db.lock().unwrap().save(&db_path)?;
        sched.attach_persistence(&db_path)?;

        let listener =
            TcpListener::bind("127.0.0.1:0").map_err(|e| e.to_string())?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        let stop = Arc::new(AtomicBool::new(false));
        let inner = Arc::new(DaemonInner {
            node: NodeId(index as u64),
            name,
            hv,
            sched,
            bus,
            journal,
            cores: crate::middleware::server::build_core_library(),
            home: Mutex::new(None),
            fetched: Mutex::new(BTreeMap::new()),
            stop: Arc::clone(&stop),
        });
        let conns = Arc::new(Mutex::new(Vec::new()));
        let inner2 = Arc::clone(&inner);
        let serve: Arc<dyn Fn(TcpStream) + Send + Sync> =
            Arc::new(move |stream| {
                let _ = serve_daemon_conn(stream, Arc::clone(&inner2));
            });
        let handle = spawn_accept_loop(
            listener,
            Arc::clone(&stop),
            Arc::clone(&conns),
            serve,
        );
        Ok(NodeDaemon {
            inner,
            addr,
            stop,
            handle: Some(handle),
            conns,
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn node(&self) -> NodeId {
        self.inner.node
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// The daemon's local hypervisor (tests and benches).
    pub fn hv(&self) -> &Arc<Hypervisor> {
        &self.inner.hv
    }

    /// The daemon's local scheduler (tests and benches).
    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.inner.sched
    }

    /// Board kinds present on this node, deduplicated.
    pub fn boards(&self) -> Vec<String> {
        let db = self.inner.hv.db.lock().unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for f in self.inner.hv.device_ids() {
            if let Some(d) = db.device(f) {
                seen.insert(d.board.name().to_string());
            }
        }
        seen.into_iter().collect()
    }

    /// Total vFPGA regions across this node's devices.
    pub fn regions_total(&self) -> u64 {
        let db = self.inner.hv.db.lock().unwrap();
        self.inner
            .hv
            .device_ids()
            .iter()
            .filter_map(|f| db.device(*f))
            .map(|d| d.regions.len() as u64)
            .sum()
    }

    /// Register (or re-register after a restart) with the management
    /// server at `mgmt`: report identity, inventory and every lease
    /// the local WAL re-adopted. The response's `release` list names
    /// tokens the cluster has since re-homed elsewhere — they are
    /// released locally here, completing reconciliation.
    pub fn register(
        &self,
        mgmt: SocketAddr,
    ) -> Result<ClusterRegisterResponse, String> {
        let mut client = Client::connect(mgmt)?;
        // Remember the management address: `agent.program` fetches
        // missing artifacts from its bitstream cache on demand.
        *self.inner.home.lock().unwrap() = Some(mgmt);
        let req = ClusterRegisterRequest {
            node: self.inner.node,
            name: self.inner.name.clone(),
            addr: self.addr.to_string(),
            boards: self.boards(),
            regions_total: self.regions_total(),
            tokens: self.inner.sched.live_tokens(),
        };
        let resp = client
            .cluster_register(&req)
            .map_err(|e| e.to_string())?;
        for t in &resp.release {
            if let Err(e) = self.inner.sched.release_token(*t) {
                log::warn!(
                    "reconcile: releasing re-homed lease {t}: {e}"
                );
            }
        }
        Ok(resp)
    }

    /// Warm this node for `core` now by pulling its artifact from
    /// the management bitstream cache — the prefetch the coordinator
    /// relies on when it places a same-design admission here.
    /// Requires a prior [`NodeDaemon::register`]; a no-op when the
    /// artifact is already held.
    pub fn prefetch_core(&self, core: &str) -> Result<(), ApiError> {
        if self.inner.fetched.lock().unwrap().contains_key(core) {
            return Ok(());
        }
        fetch_from_home(&self.inner, core).map(|_| ())
    }

    /// Stop accepting, then join the accept thread and every
    /// connection handler (long-polls notice the stop flag).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        join_all(&mut self.handle, &self.conns);
        self.inner.bus.flush();
    }
}

impl Drop for NodeDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_daemon_conn(
    mut stream: TcpStream,
    inner: Arc<DaemonInner>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(CONN_POLL))?;
    while let Some(frame) = next_frame(&mut stream, &inner.stop)? {
        let resp = match Request::from_json(&frame) {
            Err(e) => Response::failure(None, ApiError::bad_request(e)),
            Ok(req) => match req.negotiate_proto() {
                Err(e) => respond(req.id, Err(e)),
                Ok(proto) if wants_agent_stream_data(&req) => {
                    // Data-plane reply: header + output frames +
                    // terminal, written by the handler itself.
                    serve_agent_stream_data(
                        &mut stream,
                        &inner,
                        proto,
                        req.id,
                        &req.params,
                    )?;
                    continue;
                }
                Ok(_proto) => {
                    let result = dispatch_daemon(
                        &inner, &req.method, &req.params,
                    );
                    respond(req.id, result)
                }
            },
        };
        write_frame(&mut stream, &resp.to_json())?;
    }
    Ok(())
}

/// Whether a daemon request opts into the multi-frame data-plane
/// reply (`agent.stream` with `emit_output: true`).
fn wants_agent_stream_data(req: &Request) -> bool {
    req.method == Method::AgentStream.name()
        && req.params.get("emit_output").as_bool().unwrap_or(false)
}

/// Serve `agent.stream` with `emit_output`: a JSON header, the
/// output bytes as data frames — binary for hops stamped protocol 4,
/// base64 `stream_data` events for protocol 3 — then a JSON terminal
/// frame carrying the [`StreamOutcomeBody`] in `stats`. In federated
/// deployments the management server relays these frames verbatim to
/// the end client (it stamps the hop with the client's protocol).
fn serve_agent_stream_data(
    stream: &mut TcpStream,
    inner: &Arc<DaemonInner>,
    proto: u32,
    id: Option<u64>,
    params: &Json,
) -> std::io::Result<()> {
    let binary = proto >= PROTO_DATA_FRAMES;
    let prep = (|| {
        if proto < 3 {
            return Err(ApiError::bad_request(
                "emit_output requires protocol 3",
            ));
        }
        let req = AgentStreamRequest::from_json(params)?;
        let cfg = crate::middleware::server::stream_config_for(
            &req.core, req.mults,
        )?;
        let handle = authorize(inner, req.lease, req.alloc)?;
        Ok((req, cfg, handle))
    })();
    let (req, cfg, handle) = match prep {
        Err(e) => {
            return write_frame(
                stream,
                &Response::failure(id, e).to_json(),
            )
        }
        Ok(v) => v,
    };
    let idx = handle
        .members()
        .iter()
        .position(|a| *a == req.alloc)
        .unwrap_or(0);
    write_frame(
        stream,
        &Response::stream_header(
            id,
            Json::obj(vec![
                ("core", Json::from(req.core.as_str())),
                ("binary", Json::from(binary)),
            ]),
        )
        .to_json(),
    )?;
    let mut seq = 0u64;
    let mut io_err: Option<std::io::Error> = None;
    let streamed =
        handle.stream_member_sink(idx, &cfg, &mut |chunk| {
            seq += 1;
            match write_data_frame(stream, binary, seq, chunk) {
                Ok(()) => true,
                Err(e) => {
                    io_err = Some(e);
                    false
                }
            }
        });
    if let Some(e) = io_err {
        return Err(e);
    }
    let term = match streamed {
        Ok(out) => {
            if binary {
                seq += 1;
                write_bin_frame(stream, &BinFrame::end_marker(seq))?;
            }
            StreamFrame::terminal_with_stats(
                seq + 1,
                None,
                StreamOutcomeBody::from_outcome(&out).to_json(),
            )
        }
        Err(e) => {
            StreamFrame::terminal(seq + 1, Some(ApiError::from(e)))
        }
    };
    write_frame(stream, &term.to_json())
}

fn dispatch_daemon(
    inner: &Arc<DaemonInner>,
    method: &str,
    params: &Json,
) -> Result<Json, ApiError> {
    match Method::parse(method) {
        Some(Method::AgentHello) => {
            let _req = AgentHelloRequest::from_json(params)?;
            Ok(AgentHelloResponse {
                node: inner.node,
                version: crate::VERSION.to_string(),
            }
            .to_json())
        }
        Some(Method::AgentStatus) => {
            let req = StatusRequest::from_json(params)?;
            let st = inner
                .hv
                .status_local(req.fpga)
                .map_err(ApiError::from)?;
            Ok(StatusResponse::from_status(&st).to_json())
        }
        Some(Method::AgentPing) => d_ping(inner),
        Some(Method::AgentAdmit) => d_admit(inner, params),
        Some(Method::AgentRelease) => d_release(inner, params),
        Some(Method::AgentProgram) => d_program(inner, params),
        Some(Method::AgentStream) => d_stream(inner, params),
        Some(Method::AgentEvents) => d_events(inner, params),
        _ => Err(ApiError::new(
            ErrorCode::UnknownMethod,
            format!("agent: unknown method '{method}'"),
        )),
    }
}

/// Heartbeat: vitals straight from the device DB (cheap — the health
/// monitor calls this several times a second per node).
fn d_ping(inner: &Arc<DaemonInner>) -> Result<Json, ApiError> {
    let (free, total) = {
        let db = inner.hv.db.lock().unwrap();
        let mut free = 0u64;
        let mut total = 0u64;
        for f in inner.hv.device_ids() {
            free += db.free_regions(f).len() as u64;
            if let Some(d) = db.device(f) {
                total += d.regions.len() as u64;
            }
        }
        (free, total)
    };
    Ok(AgentPingResponse {
        node: inner.node,
        leases: inner.sched.live_tokens().len() as u64,
        regions_free: free,
        regions_active: total - free,
        next_cursor: inner.journal.next_cursor(),
    }
    .to_json())
}

fn d_admit(
    inner: &Arc<DaemonInner>,
    params: &Json,
) -> Result<Json, ApiError> {
    let req = AgentAdmitRequest::from_json(params)?;
    let model = req.model.unwrap_or(ServiceModel::RAaaS);
    if model == ServiceModel::RSaaS {
        return Err(ApiError::bad_request(
            "agent.admit serves vFPGA models",
        ));
    }
    let class = req.class.unwrap_or(RequestClass::Interactive);
    // Tenants federate by *name*: each daemon mints (or reuses) its
    // own local UserId for the management-side tenant string.
    let user =
        super::federation::user_by_name(&inner.hv, &req.tenant);
    let mut areq = AdmissionRequest::new(user, model, class);
    if let Some(n) = req.regions {
        areq = areq.gang(n);
    }
    if req.co_located == Some(true) {
        areq = areq.co_located();
    }
    if let Some(b) = &req.board {
        let board = BoardKind::parse(b).ok_or_else(|| {
            ApiError::bad_request(format!("unknown board '{b}'"))
        })?;
        areq = areq.on_board(board);
    }
    // Adoption keeps the cluster-wide token stable across a node
    // failure: the re-admitted lease fences with the *same*
    // capability the client already holds.
    let lease = match req.adopt {
        Some(token) => inner.sched.admit_adopted(&areq, token),
        None => inner.sched.admit(&areq),
    }
    .map_err(ApiError::from)?;
    let members: Vec<GangMemberBody> = lease
        .placements()
        .iter()
        .map(|pl| GangMemberBody {
            alloc: pl.alloc,
            vfpga: match pl.target {
                crate::sched::GrantTarget::Vfpga(v, _, _) => v,
                crate::sched::GrantTarget::Physical(_, _) => {
                    unreachable!("vFPGA admission")
                }
            },
            fpga: match pl.target {
                crate::sched::GrantTarget::Vfpga(_, f, _)
                | crate::sched::GrantTarget::Physical(f, _) => f,
            },
            node: match pl.target {
                crate::sched::GrantTarget::Vfpga(_, _, n)
                | crate::sched::GrantTarget::Physical(_, n) => n,
            },
        })
        .collect();
    let primary = members.first().cloned().ok_or_else(|| {
        ApiError::internal("admitted lease has no members")
    })?;
    let resp = AllocVfpgaResponse {
        alloc: primary.alloc,
        vfpga: primary.vfpga,
        fpga: primary.fpga,
        node: primary.node,
        wait_ms: lease.wait().as_millis_f64(),
        lease: lease.token(),
        members,
    };
    // Disarm: the lease stays live node-side, fenced by the token.
    let _token = lease.into_token();
    Ok(resp.to_json())
}

fn d_release(
    inner: &Arc<DaemonInner>,
    params: &Json,
) -> Result<Json, ApiError> {
    let req = AgentReleaseRequest::from_json(params)?;
    inner
        .sched
        .release_token(req.lease)
        .map_err(ApiError::from)?;
    Ok(ReleaseResponse { released: true }.to_json())
}

fn d_program(
    inner: &Arc<DaemonInner>,
    params: &Json,
) -> Result<Json, ApiError> {
    let req = AgentProgramRequest::from_json(params)?;
    let handle = authorize(inner, req.lease, req.alloc)?;
    let user = handle.tenant();
    // Artifact preference mirrors the management server: a fetched
    // cache artifact first, the prebuilt library next, and on a full
    // miss a cross-node pull from the management cache.
    let cached = inner.fetched.lock().unwrap().get(&req.core).cloned();
    let d = match &cached {
        Some(bs) => inner.hv.program_retargeted(req.alloc, user, bs),
        None => match inner.cores.get(&req.core) {
            Some(bs) => {
                inner.hv.program_retargeted(req.alloc, user, bs)
            }
            None => {
                let bs = fetch_from_home(inner, &req.core)?;
                inner.hv.program_retargeted(req.alloc, user, &bs)
            }
        },
    }
    .map_err(ApiError::from)?;
    Ok(ProgramCoreResponse {
        programmed: req.core,
        pr_ms: d.as_millis_f64(),
    }
    .to_json())
}

/// Pull an artifact this daemon is missing from the management
/// bitstream cache (`agent.fetch_bitstream`), self-identifying so
/// the coordinator marks this node warm for the core. The verified
/// bitstream is retained in the daemon's fetched map.
fn fetch_from_home(
    inner: &Arc<DaemonInner>,
    core: &str,
) -> Result<Bitstream, ApiError> {
    let Some(home) = *inner.home.lock().unwrap() else {
        return Err(ApiError::new(
            ErrorCode::UnknownCore,
            format!(
                "unknown core '{core}' (no management cache to fetch \
                 from)"
            ),
        ));
    };
    let part = {
        let db = inner.hv.db.lock().unwrap();
        inner
            .hv
            .device_ids()
            .first()
            .and_then(|f| db.device(*f))
            .map(|d| crate::fpga::board::BoardSpec::of(d.board).part)
            .unwrap_or(crate::fpga::board::BoardSpec::vc707().part)
    };
    let mut client = Client::connect(home).map_err(|e| {
        ApiError::internal(format!("fetch from management: {e}"))
    })?;
    let bs = client.fetch_bitstream(core, part, Some(inner.node))?;
    inner.hv.metrics.counter("bitcache.node_fetch").inc();
    inner
        .fetched
        .lock()
        .unwrap()
        .insert(core.to_string(), bs.clone());
    Ok(bs)
}

fn d_stream(
    inner: &Arc<DaemonInner>,
    params: &Json,
) -> Result<Json, ApiError> {
    let req = AgentStreamRequest::from_json(params)?;
    let cfg =
        crate::middleware::server::stream_config_for(&req.core, req.mults)?;
    let handle = authorize(inner, req.lease, req.alloc)?;
    let idx = handle
        .members()
        .iter()
        .position(|a| *a == req.alloc)
        .unwrap_or(0);
    // Synchronous on the node: the management server wraps this call
    // in its own async job, so the long wait lives there.
    let out = handle.stream_member(idx, &cfg).map_err(ApiError::from)?;
    Ok(StreamOutcomeBody::from_outcome(&out).to_json())
}

/// Long-poll the node's event journal: everything published on this
/// node (scheduler telemetry, region transitions, job progress) is
/// journaled with its local cursor; the management server's
/// forwarder drains from here and republishes upstream node-tagged.
fn d_events(
    inner: &Arc<DaemonInner>,
    params: &Json,
) -> Result<Json, ApiError> {
    let req = AgentEventsRequest::from_json(params)?;
    let deadline = Instant::now()
        + Duration::from_secs_f64(req.timeout_s.clamp(0.0, 30.0));
    let max = req.max_events.clamp(1, 1024) as usize;
    loop {
        let records = inner
            .journal
            .replay_from(req.from_cursor)
            .map_err(|e| ApiError::internal(format!("journal: {e}")))?;
        let stopping = inner.stop.load(Ordering::SeqCst);
        if !records.is_empty()
            || Instant::now() >= deadline
            || stopping
        {
            let events: Vec<NodeEventBody> = records
                .into_iter()
                .take(max)
                .map(|(cursor, event, scope)| NodeEventBody {
                    cursor,
                    scope: super::federation::scope_to_wire(
                        &inner.hv, &scope,
                    ),
                    event,
                })
                .collect();
            let next_cursor = events
                .last()
                .map(|e| e.cursor + 1)
                .unwrap_or(req.from_cursor);
            return Ok(AgentEventsResponse {
                next_cursor,
                events,
            }
            .to_json());
        }
        std::thread::sleep(EVENTS_POLL);
    }
}

/// Resolve the lease handle for a token and verify `alloc` is one of
/// its members — the node-local analogue of the management server's
/// `authorize`.
fn authorize(
    inner: &Arc<DaemonInner>,
    token: crate::util::ids::LeaseToken,
    alloc: crate::util::ids::AllocationId,
) -> Result<crate::sched::Lease, ApiError> {
    inner.sched.verify_member(token, alloc).map_err(ApiError::from)?;
    inner.sched.lease_handle(token).ok_or_else(|| {
        ApiError::new(
            ErrorCode::BadToken,
            "unknown or released lease token",
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::middleware::client::Client;
    use crate::util::clock::VirtualClock;
    use crate::util::ids::FpgaId;

    fn hv() -> Arc<Hypervisor> {
        Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap())
    }

    #[test]
    fn agent_serves_status_over_tcp() {
        let hv = hv();
        let agent = NodeAgent::spawn(Arc::clone(&hv), NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let body = client
            .call_v2(
                "agent.status",
                Json::obj(vec![("fpga", Json::from("fpga-0"))]),
            )
            .unwrap();
        assert_eq!(body.get("regions_total").as_u64(), Some(4));
        assert_eq!(body.get("board").as_str(), Some("vc707"));
    }

    #[test]
    fn agent_rejects_retired_protocol_1() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut stream = TcpStream::connect(agent.addr()).unwrap();
        let raw = Json::obj(vec![
            ("method", Json::from("agent.hello")),
            ("params", Json::obj(vec![])),
        ]);
        write_frame(&mut stream, &raw).unwrap();
        let frame = read_frame(&mut stream).unwrap().unwrap();
        let err = Response::from_json(&frame)
            .unwrap()
            .into_api_result()
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::ProtocolMismatch);
    }

    #[test]
    fn agent_serves_typed_status() {
        let hv = hv();
        let agent =
            NodeAgent::spawn(Arc::clone(&hv), NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let st = client.agent_status(FpgaId(0)).unwrap();
        assert_eq!(st.regions_total, 4);
        assert_eq!(st.board, "vc707");
        let hello = client.agent_hello().unwrap();
        assert_eq!(hello.node, NodeId(0));
        assert_eq!(hello.version, crate::VERSION);
    }

    #[test]
    fn agent_hello_reports_node() {
        let hv = hv();
        let agent =
            NodeAgent::spawn(Arc::clone(&hv), NodeId(1), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let hello = client.agent_hello().unwrap();
        assert_eq!(hello.node, NodeId(1));
    }

    #[test]
    fn unknown_method_is_error() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client
            .call_v2("agent.reboot", Json::obj(vec![]))
            .is_err());
    }

    #[test]
    fn bad_fpga_id_is_error_not_crash() {
        let hv = hv();
        let agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client
            .call_v2(
                "agent.status",
                Json::obj(vec![("fpga", Json::from("fpga-99"))])
            )
            .is_err());
        // Connection still usable after the error.
        assert!(client.agent_hello().is_ok());
    }

    #[test]
    fn injected_connection_drop_surfaces_as_io_error() {
        let hv = hv();
        let plan = crate::testing::FailPlan::new();
        plan.arm("agent.drop_conn", crate::testing::FailPoint::OnHit(1));
        let agent = NodeAgent::spawn(hv, NodeId(0), Some(plan)).unwrap();
        let mut client = Client::connect(agent.addr()).unwrap();
        let err = client.agent_hello().unwrap_err();
        assert!(
            err.message.contains("io") || err.message.contains("eof"),
            "{err}"
        );
        // Reconnect works (the node came back).
        let mut c2 = Client::connect(agent.addr()).unwrap();
        assert!(c2.agent_hello().is_ok());
    }

    #[test]
    fn shutdown_joins_inflight_connections() {
        let hv = hv();
        let mut agent = NodeAgent::spawn(hv, NodeId(0), None).unwrap();
        // Park a live connection on the agent, then shut down while
        // it is still open: shutdown must join the handler (which
        // notices the stop flag on its poll tick) instead of hanging
        // or leaking it.
        let mut client = Client::connect(agent.addr()).unwrap();
        assert!(client.agent_hello().is_ok());
        let start = Instant::now();
        agent.shutdown();
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "shutdown hung on an idle connection"
        );
        // The parked connection was closed by the join.
        assert!(client.agent_hello().is_err());
    }

    fn daemon_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "rc3e-node-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn daemon_serves_full_lifecycle_locally() {
        let dir = daemon_dir("lifecycle");
        let config = ClusterConfig::paper_testbed();
        let daemon =
            NodeDaemon::spawn(&config, 0, &dir, VirtualClock::new()).unwrap();
        assert_eq!(daemon.node(), NodeId(0));
        assert_eq!(daemon.boards(), vec!["vc707".to_string()]);
        assert_eq!(daemon.regions_total(), 8);

        let mut client = Client::connect(daemon.addr()).unwrap();
        let ping = client.agent_ping().unwrap();
        assert_eq!(ping.node, NodeId(0));
        assert_eq!(ping.regions_free, 8);
        assert_eq!(ping.leases, 0);

        let grant = client
            .agent_admit(&AgentAdmitRequest {
                tenant: "alice".to_string(),
                model: None,
                class: None,
                regions: None,
                co_located: None,
                board: None,
                core: None,
                adopt: None,
            })
            .unwrap();
        assert_eq!(grant.node, NodeId(0));
        let prog = client
            .agent_program(&AgentProgramRequest {
                lease: grant.lease,
                alloc: grant.alloc,
                core: "matmul16".to_string(),
            })
            .unwrap();
        assert_eq!(prog.programmed, "matmul16");
        let out = client
            .agent_stream(&AgentStreamRequest {
                lease: grant.lease,
                alloc: grant.alloc,
                core: "matmul16".to_string(),
                mults: 4096,
                emit_output: false,
            })
            .unwrap();
        assert_eq!(out.mults, 4096);
        assert_eq!(out.validation_failures, 0);
        let rel = client.agent_release(grant.lease).unwrap();
        assert!(rel.released);
        let ping = client.agent_ping().unwrap();
        assert_eq!(ping.regions_free, 8);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn daemon_wal_survives_restart_and_readopts_leases() {
        let dir = daemon_dir("wal");
        let config = ClusterConfig::paper_testbed();
        let token = {
            let daemon =
                NodeDaemon::spawn(&config, 1, &dir, VirtualClock::new())
                    .unwrap();
            let mut client = Client::connect(daemon.addr()).unwrap();
            let grant = client
                .agent_admit(&AgentAdmitRequest {
                    tenant: "bob".to_string(),
                    model: None,
                    class: None,
                    regions: Some(2),
                    co_located: None,
                    board: None,
                    core: None,
                    adopt: None,
                })
                .unwrap();
            grant.lease
            // Daemon dropped here — simulating a crash would skip
            // the WAL sync, which attach_persistence already did at
            // admit time.
        };
        let daemon =
            NodeDaemon::spawn(&config, 1, &dir, VirtualClock::new()).unwrap();
        let live = daemon.scheduler().live_tokens();
        assert_eq!(live, vec![token]);
        let mut client = Client::connect(daemon.addr()).unwrap();
        let ping = client.agent_ping().unwrap();
        assert_eq!(ping.leases, 1);
        assert_eq!(ping.regions_free, 6);
        // The re-adopted lease still fences: release by token works.
        assert!(client.agent_release(token).unwrap().released);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn daemon_events_long_poll_returns_node_events() {
        let dir = daemon_dir("events");
        let config = ClusterConfig::paper_testbed();
        let daemon =
            NodeDaemon::spawn(&config, 0, &dir, VirtualClock::new()).unwrap();
        let mut client = Client::connect(daemon.addr()).unwrap();
        let grant = client
            .agent_admit(&AgentAdmitRequest {
                tenant: "carol".to_string(),
                model: None,
                class: None,
                regions: None,
                co_located: None,
                board: None,
                core: None,
                adopt: None,
            })
            .unwrap();
        let resp = client
            .agent_events(&AgentEventsRequest {
                from_cursor: 1,
                max_events: 64,
                timeout_s: 2.0,
            })
            .unwrap();
        assert!(!resp.events.is_empty());
        // Cursors are the node-local journal sequence: strictly
        // increasing, and next_cursor continues past the last one.
        let cursors: Vec<u64> =
            resp.events.iter().map(|e| e.cursor).collect();
        for w in cursors.windows(2) {
            assert!(w[0] < w[1]);
        }
        assert_eq!(
            resp.next_cursor,
            cursors.last().unwrap() + 1
        );
        // Grant telemetry is public-scoped on the wire.
        assert!(resp
            .events
            .iter()
            .any(|e| e.scope == "public"));
        client.agent_release(grant.lease).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
