//! Heartbeat-based failure detection.
//!
//! One monitor thread per management server: every
//! [`HEARTBEAT_PERIOD`] it pings each pingable node (`agent.ping`),
//! feeding successes and misses into the registry's
//! up → suspect → down state machine
//! ([`super::registry::SUSPECT_AFTER_MISSES`] /
//! [`super::registry::DOWN_AFTER_MISSES`]). A node crossing the
//! `Down` edge orphans its leases
//! ([`super::Coordinator::on_node_down`]); each subsequent tick then
//! retries orphan re-admission on the survivors, so queued work and
//! surviving leases drain back into the cluster without any client
//! involvement. `Down` nodes are not pinged — rejoin is an explicit
//! re-registration by the restarted daemon.
//!
//! Heartbeats run on the *wall* clock: failure detection is about
//! the deployment, not the simulated workload, so a paused virtual
//! clock must not mask a dead node.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::federation::Coordinator;
use super::registry::NodeState;
use crate::middleware::client::Client;

/// Wall-clock interval between heartbeat rounds.
pub const HEARTBEAT_PERIOD: Duration = Duration::from_millis(250);

/// Stop-poll granularity while parked between rounds.
const PARK_TICK: Duration = Duration::from_millis(50);

/// A running heartbeat monitor (owns its thread).
pub struct HealthMonitor {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl HealthMonitor {
    pub fn spawn(coordinator: Arc<Coordinator>) -> HealthMonitor {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                heartbeat_round(&coordinator);
                coordinator.retry_orphans();
                let mut parked = Duration::ZERO;
                while parked < HEARTBEAT_PERIOD
                    && !stop2.load(Ordering::SeqCst)
                {
                    std::thread::sleep(PARK_TICK);
                    parked += PARK_TICK;
                }
            }
        });
        HealthMonitor {
            stop,
            handle: Some(handle),
        }
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HealthMonitor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Ping every pingable node once, recording vitals or misses.
fn heartbeat_round(co: &Arc<Coordinator>) {
    let metrics = Arc::clone(&co.hv().metrics);
    for (node, addr) in co.registry().pingable() {
        let ping = Client::connect(addr)
            .ok()
            .and_then(|mut c| c.agent_ping().ok());
        match ping {
            Some(p) => {
                metrics.counter("cluster.heartbeat.ok").inc();
                co.registry().record_ok(
                    node,
                    p.leases,
                    p.regions_free,
                    p.regions_active,
                    p.next_cursor,
                );
            }
            None => {
                metrics.counter("cluster.heartbeat.missed").inc();
                if co.registry().record_miss(node)
                    == Some(NodeState::Down)
                {
                    log::warn!(
                        "node {node} missed its heartbeat budget: down"
                    );
                    co.on_node_down(node);
                }
            }
        }
    }
}
