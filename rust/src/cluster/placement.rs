//! Cross-node placement policy: pure functions over registry
//! snapshots, unit-testable without any networking.
//!
//! A federated admission lands *whole* on one node — gang size and
//! co-location are enforced by that node's local scheduler exactly
//! as they are in a single-process deployment. What federates is the
//! *choice of node*: [`eligible`] filters the registered nodes down
//! to those that could serve the request (healthy, board match,
//! enough free regions) and ranks the survivors most-free-first so
//! load spreads across the cluster, ties broken by lowest `NodeId`
//! for determinism.
//!
//! The free-region capacity filter is advisory — vitals are a
//! heartbeat old, so the node's own scheduler is the arbiter and the
//! coordinator simply tries the next candidate (or waits) when an
//! admit bounces with `no_capacity`.
//!
//! When the admission names an intended core, [`eligible_warm`] uses
//! the coordinator's record of which artifacts each node already
//! fetched ([`ResidentMap`]) as a tiebreak: among equally-free nodes,
//! one that already holds the bitstream programs without a cross-node
//! artifact transfer. Cache affinity never outranks load spreading —
//! a warm-but-busier node still loses to a colder, freer one.

use std::collections::{BTreeMap, BTreeSet};

use super::registry::{NodeSnapshot, NodeState};
use crate::util::ids::NodeId;

/// Which cores each node is known to hold a bitstream artifact for —
/// the coordinator records a node as warm once it serves that node an
/// `agent.fetch_bitstream` or places an admission carrying the hint.
pub type ResidentMap = BTreeMap<NodeId, BTreeSet<String>>;

/// Filter and rank candidate nodes for an admission of `regions`
/// regions with an optional board constraint. Returns node ids in
/// placement-preference order (most free regions first, then lowest
/// id).
pub fn eligible(
    nodes: &[NodeSnapshot],
    regions: u32,
    board: Option<&str>,
) -> Vec<NodeId> {
    eligible_warm(nodes, regions, board, None, &ResidentMap::new())
}

/// [`eligible`] with a cache-affinity tiebreak: `design` is the core
/// the tenant intends to program (from the admission's hint) and
/// `resident` the coordinator's artifact map. Ordering is most free
/// regions first, then warm-before-cold, then lowest id.
pub fn eligible_warm(
    nodes: &[NodeSnapshot],
    regions: u32,
    board: Option<&str>,
    design: Option<&str>,
    resident: &ResidentMap,
) -> Vec<NodeId> {
    let warm = |n: &NodeSnapshot| -> bool {
        match design {
            Some(d) => resident
                .get(&n.node)
                .is_some_and(|cores| cores.contains(d)),
            None => false,
        }
    };
    let mut fit: Vec<&NodeSnapshot> = nodes
        .iter()
        .filter(|n| n.state == NodeState::Up)
        .filter(|n| match board {
            Some(b) => n.boards.iter().any(|have| have == b),
            None => true,
        })
        .filter(|n| n.regions_free >= u64::from(regions))
        .collect();
    fit.sort_by(|a, b| {
        b.regions_free
            .cmp(&a.regions_free)
            .then(warm(b).cmp(&warm(a)))
            .then(a.node.cmp(&b.node))
    });
    fit.into_iter().map(|n| n.node).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(
        node: u64,
        state: NodeState,
        boards: &[&str],
        free: u64,
    ) -> NodeSnapshot {
        NodeSnapshot {
            node: NodeId(node),
            name: format!("node-{node}"),
            addr: "127.0.0.1:9".parse().unwrap(),
            boards: boards.iter().map(|b| b.to_string()).collect(),
            state,
            regions_total: 8,
            regions_free: free,
            regions_active: 8 - free,
            leases: 0,
            next_cursor: 1,
            heartbeat_age_ms: 0.0,
        }
    }

    #[test]
    fn ranks_most_free_first_with_id_tiebreak() {
        let nodes = vec![
            snap(0, NodeState::Up, &["vc707"], 3),
            snap(1, NodeState::Up, &["ml605"], 8),
            snap(2, NodeState::Up, &["vc707"], 8),
        ];
        assert_eq!(
            eligible(&nodes, 1, None),
            vec![NodeId(1), NodeId(2), NodeId(0)]
        );
    }

    #[test]
    fn board_constraint_filters_nodes() {
        let nodes = vec![
            snap(0, NodeState::Up, &["vc707"], 2),
            snap(1, NodeState::Up, &["ml605"], 8),
        ];
        assert_eq!(eligible(&nodes, 1, Some("vc707")), vec![NodeId(0)]);
        assert_eq!(eligible(&nodes, 1, Some("ml605")), vec![NodeId(1)]);
        assert!(eligible(&nodes, 1, Some("zcu102")).is_empty());
    }

    #[test]
    fn warm_node_wins_ties_but_never_outranks_free_capacity() {
        let nodes = vec![
            snap(0, NodeState::Up, &["vc707"], 4),
            snap(1, NodeState::Up, &["vc707"], 4),
            snap(2, NodeState::Up, &["vc707"], 8),
        ];
        let mut resident = ResidentMap::new();
        resident
            .entry(NodeId(1))
            .or_default()
            .insert("matmul16".to_string());
        // Tie at 4 free regions: the warm node 1 beats node 0, but
        // the freer (cold) node 2 still ranks first.
        assert_eq!(
            eligible_warm(&nodes, 1, None, Some("matmul16"), &resident),
            vec![NodeId(2), NodeId(1), NodeId(0)]
        );
        // A different design (or no hint) falls back to id order.
        assert_eq!(
            eligible_warm(&nodes, 1, None, Some("saxpy"), &resident),
            vec![NodeId(2), NodeId(0), NodeId(1)]
        );
        assert_eq!(
            eligible_warm(&nodes, 1, None, None, &resident),
            eligible(&nodes, 1, None)
        );
    }

    #[test]
    fn unhealthy_and_full_nodes_are_excluded() {
        let nodes = vec![
            snap(0, NodeState::Down, &["vc707"], 8),
            snap(1, NodeState::Suspect, &["vc707"], 8),
            snap(2, NodeState::Up, &["vc707"], 1),
        ];
        // Gang of 2 does not fit on the only healthy node.
        assert!(eligible(&nodes, 2, None).is_empty());
        assert_eq!(eligible(&nodes, 1, None), vec![NodeId(2)]);
    }
}
