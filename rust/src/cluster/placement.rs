//! Cross-node placement policy: pure functions over registry
//! snapshots, unit-testable without any networking.
//!
//! A federated admission lands *whole* on one node — gang size and
//! co-location are enforced by that node's local scheduler exactly
//! as they are in a single-process deployment. What federates is the
//! *choice of node*: [`eligible`] filters the registered nodes down
//! to those that could serve the request (healthy, board match,
//! enough free regions) and ranks the survivors most-free-first so
//! load spreads across the cluster, ties broken by lowest `NodeId`
//! for determinism.
//!
//! The free-region capacity filter is advisory — vitals are a
//! heartbeat old, so the node's own scheduler is the arbiter and the
//! coordinator simply tries the next candidate (or waits) when an
//! admit bounces with `no_capacity`.

use super::registry::{NodeSnapshot, NodeState};
use crate::util::ids::NodeId;

/// Filter and rank candidate nodes for an admission of `regions`
/// regions with an optional board constraint. Returns node ids in
/// placement-preference order (most free regions first, then lowest
/// id).
pub fn eligible(
    nodes: &[NodeSnapshot],
    regions: u32,
    board: Option<&str>,
) -> Vec<NodeId> {
    let mut fit: Vec<&NodeSnapshot> = nodes
        .iter()
        .filter(|n| n.state == NodeState::Up)
        .filter(|n| match board {
            Some(b) => n.boards.iter().any(|have| have == b),
            None => true,
        })
        .filter(|n| n.regions_free >= u64::from(regions))
        .collect();
    fit.sort_by(|a, b| {
        b.regions_free
            .cmp(&a.regions_free)
            .then(a.node.cmp(&b.node))
    });
    fit.into_iter().map(|n| n.node).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(
        node: u64,
        state: NodeState,
        boards: &[&str],
        free: u64,
    ) -> NodeSnapshot {
        NodeSnapshot {
            node: NodeId(node),
            name: format!("node-{node}"),
            addr: "127.0.0.1:9".parse().unwrap(),
            boards: boards.iter().map(|b| b.to_string()).collect(),
            state,
            regions_total: 8,
            regions_free: free,
            regions_active: 8 - free,
            leases: 0,
            next_cursor: 1,
            heartbeat_age_ms: 0.0,
        }
    }

    #[test]
    fn ranks_most_free_first_with_id_tiebreak() {
        let nodes = vec![
            snap(0, NodeState::Up, &["vc707"], 3),
            snap(1, NodeState::Up, &["ml605"], 8),
            snap(2, NodeState::Up, &["vc707"], 8),
        ];
        assert_eq!(
            eligible(&nodes, 1, None),
            vec![NodeId(1), NodeId(2), NodeId(0)]
        );
    }

    #[test]
    fn board_constraint_filters_nodes() {
        let nodes = vec![
            snap(0, NodeState::Up, &["vc707"], 2),
            snap(1, NodeState::Up, &["ml605"], 8),
        ];
        assert_eq!(eligible(&nodes, 1, Some("vc707")), vec![NodeId(0)]);
        assert_eq!(eligible(&nodes, 1, Some("ml605")), vec![NodeId(1)]);
        assert!(eligible(&nodes, 1, Some("zcu102")).is_empty());
    }

    #[test]
    fn unhealthy_and_full_nodes_are_excluded() {
        let nodes = vec![
            snap(0, NodeState::Down, &["vc707"], 8),
            snap(1, NodeState::Suspect, &["vc707"], 8),
            snap(2, NodeState::Up, &["vc707"], 1),
        ];
        // Gang of 2 does not fit on the only healthy node.
        assert!(eligible(&nodes, 2, None).is_empty());
        assert_eq!(eligible(&nodes, 1, None), vec![NodeId(2)]);
    }
}
