//! The batch system.
//!
//! Section IV-C: "we integrated a batch system for long-running
//! applications without direct user interaction to improve overall
//! system utilization. A job of the batch system is to specify the
//! type as well as a configuration file for the FPGAs."
//!
//! Jobs carry a service model, a bitfile (or BAaaS service name) and
//! a stream workload. Admission is *not* handled here anymore: each
//! worker submits to the cluster [`Scheduler`] at batch class and
//! blocks until the fair-share pump grants it a region — the old
//! private FIFO + retry-on-`NoCapacity` loop is gone. Batch leases
//! are preemptable: an interactive request may relocate them via
//! migration mid-run, so workers re-resolve their vFPGA through the
//! lease before every device operation.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::rc2f::stream::{StreamConfig, StreamOutcome};
use crate::sched::{AdmissionRequest, RequestClass, Scheduler};
use crate::util::ids::{JobId, UserId};

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub user: UserId,
    /// RAaaS job: user bitfile; BAaaS job: provider service name.
    pub payload: JobPayload,
    /// The stream workload to run once configured.
    pub stream: StreamConfig,
}

/// What configures the vFPGA for this job.
#[derive(Debug, Clone)]
pub enum JobPayload {
    /// RAaaS: user-supplied partial bitfile (slot-retargeted by the
    /// scheduler to wherever the allocation lands).
    UserBitfile(Bitstream),
    /// BAaaS: provider-registered service bitfile.
    Service(String),
}

/// Job lifecycle.
#[derive(Debug, Clone)]
pub enum JobState {
    Queued,
    Running,
    Done(Box<StreamOutcome>),
    Failed(String),
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

struct QueueInner {
    pending: VecDeque<(JobId, JobSpec)>,
    states: BTreeMap<JobId, JobState>,
    next_id: u64,
}

/// The batch queue + workers (admission delegated to the scheduler).
pub struct BatchSystem {
    hv: Arc<Hypervisor>,
    sched: Arc<Scheduler>,
    inner: Mutex<QueueInner>,
}

impl BatchSystem {
    /// Stand-alone batch system with its own scheduler.
    pub fn new(hv: Arc<Hypervisor>) -> Arc<BatchSystem> {
        let sched = Scheduler::new(Arc::clone(&hv));
        BatchSystem::with_scheduler(sched)
    }

    /// Batch system sharing the cluster scheduler (so batch jobs
    /// contend fairly with the service façades).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> Arc<BatchSystem> {
        Arc::new(BatchSystem {
            hv: Arc::clone(sched.hv()),
            sched,
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                states: BTreeMap::new(),
                next_id: 0,
            }),
        })
    }

    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.sched
    }

    /// Enqueue a job; returns its id immediately.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let mut inner = self.inner.lock().unwrap();
        let id = JobId(inner.next_id);
        inner.next_id += 1;
        inner.states.insert(id, JobState::Queued);
        inner.pending.push_back((id, spec));
        id
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().states.get(&id).cloned()
    }

    /// Run jobs until the queue is drained (single worker). Each job:
    /// scheduler admission (blocking, batch class) → retarget &
    /// program → stream → release.
    pub fn run_to_completion(&self) {
        loop {
            let job = self.inner.lock().unwrap().pending.pop_front();
            let Some((id, spec)) = job else { return };
            self.set_state(id, JobState::Running);
            match self.execute(&spec) {
                Ok(outcome) => {
                    self.set_state(id, JobState::Done(Box::new(outcome)))
                }
                Err(e) => self.set_state(id, JobState::Failed(e.to_string())),
            }
        }
    }

    fn set_state(&self, id: JobId, st: JobState) {
        self.inner.lock().unwrap().states.insert(id, st);
    }

    fn execute(&self, spec: &JobSpec) -> Result<StreamOutcome, HypervisorError> {
        let model = match &spec.payload {
            JobPayload::UserBitfile(_) => ServiceModel::RAaaS,
            JobPayload::Service(_) => ServiceModel::BAaaS,
        };
        // Resolve the payload first: an unknown service must fail the
        // job without burning an admission.
        let bitfile = match &spec.payload {
            JobPayload::UserBitfile(bs) => bs.clone(),
            JobPayload::Service(name) => self.hv.service_bitfile(name)?,
        };
        // Block until the fair-share pump admits us; the scheduler
        // enforces quotas and skips us past capacity we cannot use.
        let lease = self
            .sched
            .admit_blocking(&AdmissionRequest::new(
                spec.user,
                model,
                RequestClass::Batch,
            ))
            .map_err(HypervisorError::from)?;
        // Program + stream through the lease handle: each step
        // resolves placement through the lease (a preemption may have
        // migrated us), the bitfile is retargeted to wherever the
        // lease lives (the paper's hide-the-region future-work item),
        // and a preemption racing *inside* a step fails cleanly and
        // is retried once against the new placement instead of
        // failing the job.
        let result = crate::service::run_setup_and_stream(
            &lease,
            &bitfile,
            &spec.stream,
        );
        // Always release through the scheduler, success or failure —
        // that is what pumps the next queued job in.
        let _ = lease.release();
        result
    }

    /// Spawn `n` worker threads and wait for the queue to drain
    /// (multi-worker variant used by the BAaaS example and the
    /// scheduler storm).
    pub fn drain_with_workers(self: &Arc<Self>, n: usize) {
        std::thread::scope(|scope| {
            for _ in 0..n.max(1) {
                let me = Arc::clone(self);
                scope.spawn(move || me.run_to_completion());
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn system() -> Option<Arc<BatchSystem>> {
        if !crate::testing::artifacts_available("batch::tests") {
            return None;
        }
        let hv =
            Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap());
        Some(BatchSystem::new(hv))
    }

    fn mm16_bitfile() -> Bitstream {
        crate::testing::mm16_partial(0)
    }

    fn job(bs: &BatchSystem, mults: u64) -> JobSpec {
        let user = bs.hv.add_user("batcher");
        JobSpec {
            user,
            payload: JobPayload::UserBitfile(mm16_bitfile()),
            stream: StreamConfig::matmul16(mults),
        }
    }

    #[test]
    fn job_runs_to_done() {
        let Some(bs) = system() else { return };
        let id = bs.submit(job(&bs, 512));
        assert!(matches!(bs.state(id), Some(JobState::Queued)));
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Done(out)) => {
                assert_eq!(out.mults, 512);
                assert_eq!(out.validation_failures, 0);
            }
            st => panic!("unexpected state {st:?}"),
        }
    }

    #[test]
    fn jobs_release_their_leases() {
        let Some(bs) = system() else { return };
        for _ in 0..3 {
            bs.submit(job(&bs, 256));
        }
        bs.run_to_completion();
        // All leases returned: 16 free regions again.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn baaas_job_uses_service_store() {
        let Some(bs) = system() else { return };
        bs.hv.register_service("mm16", mm16_bitfile());
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("mm16".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        assert!(matches!(bs.state(id), Some(JobState::Done(_))));
    }

    #[test]
    fn unknown_service_fails_job() {
        let Some(bs) = system() else { return };
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("nope".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("nope"), "{msg}")
            }
            st => panic!("unexpected {st:?}"),
        }
    }

    #[test]
    fn queue_order_preserved() {
        let Some(bs) = system() else { return };
        let a = bs.submit(job(&bs, 256));
        let b = bs.submit(job(&bs, 256));
        assert!(a < b);
        bs.run_to_completion();
        assert!(matches!(bs.state(a), Some(JobState::Done(_))));
        assert!(matches!(bs.state(b), Some(JobState::Done(_))));
    }

    #[test]
    fn jobs_charge_the_usage_ledger() {
        let Some(bs) = system() else { return };
        let spec = job(&bs, 256);
        let user = spec.user;
        bs.submit(spec);
        bs.run_to_completion();
        let usage = bs.scheduler().usage(user);
        assert_eq!(usage.granted, 1);
        assert_eq!(usage.released, 1);
        assert!(usage.device_seconds > 0.0);
        assert!(usage.energy_joules > 0.0);
    }
}
