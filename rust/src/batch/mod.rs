//! The batch system.
//!
//! Section IV-C: "we integrated a batch system for long-running
//! applications without direct user interaction to improve overall
//! system utilization. A job of the batch system is to specify the
//! type as well as a configuration file for the FPGAs."
//!
//! Jobs carry a service model, a bitfile (or BAaaS service name) and
//! a stream workload. The scheduler thread drains the queue FIFO
//! with retry-on-no-capacity: when every vFPGA is leased, the job
//! waits until a release frees one — exactly the utilization-
//! smoothing role the paper gives the batch system on its tiny
//! 2-node / 4-FPGA testbed.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::rc2f::stream::{StreamConfig, StreamOutcome, StreamRunner};
use crate::util::ids::{JobId, UserId};

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub user: UserId,
    /// RAaaS job: user bitfile; BAaaS job: provider service name.
    pub payload: JobPayload,
    /// The stream workload to run once configured.
    pub stream: StreamConfig,
}

/// What configures the vFPGA for this job.
#[derive(Debug, Clone)]
pub enum JobPayload {
    /// RAaaS: user-supplied partial bitfile (slot-retargeted by the
    /// scheduler to wherever the allocation lands).
    UserBitfile(Bitstream),
    /// BAaaS: provider-registered service bitfile.
    Service(String),
}

/// Job lifecycle.
#[derive(Debug, Clone)]
pub enum JobState {
    Queued,
    Running,
    Done(Box<StreamOutcome>),
    Failed(String),
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

struct QueueInner {
    pending: VecDeque<(JobId, JobSpec)>,
    states: std::collections::BTreeMap<JobId, JobState>,
    next_id: u64,
    shutdown: bool,
}

/// The batch queue + scheduler.
pub struct BatchSystem {
    hv: Arc<Hypervisor>,
    inner: Mutex<QueueInner>,
    work: Condvar,
    idle: Condvar,
}

impl BatchSystem {
    pub fn new(hv: Arc<Hypervisor>) -> Arc<BatchSystem> {
        Arc::new(BatchSystem {
            hv,
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                states: std::collections::BTreeMap::new(),
                next_id: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        })
    }

    /// Enqueue a job; returns its id immediately.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let mut inner = self.inner.lock().unwrap();
        let id = JobId(inner.next_id);
        inner.next_id += 1;
        inner.states.insert(id, JobState::Queued);
        inner.pending.push_back((id, spec));
        drop(inner);
        self.work.notify_one();
        id
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().states.get(&id).cloned()
    }

    /// Run the scheduler until the queue is drained (single worker —
    /// the paper's testbed scale). Each job: allocate → retarget &
    /// program → stream → release.
    pub fn run_to_completion(&self) {
        loop {
            let job = {
                let mut inner = self.inner.lock().unwrap();
                loop {
                    if let Some(job) = inner.pending.pop_front() {
                        break Some(job);
                    }
                    if inner.shutdown || inner.pending.is_empty() {
                        break None;
                    }
                }
            };
            let Some((id, spec)) = job else {
                self.idle.notify_all();
                return;
            };
            self.set_state(id, JobState::Running);
            match self.execute(&spec) {
                Ok(outcome) => {
                    self.set_state(id, JobState::Done(Box::new(outcome)))
                }
                Err(e) => self.set_state(id, JobState::Failed(e.to_string())),
            }
        }
    }

    fn set_state(&self, id: JobId, st: JobState) {
        self.inner.lock().unwrap().states.insert(id, st);
    }

    fn execute(&self, spec: &JobSpec) -> Result<StreamOutcome, HypervisorError> {
        let model = match &spec.payload {
            JobPayload::UserBitfile(_) => ServiceModel::RAaaS,
            JobPayload::Service(_) => ServiceModel::BAaaS,
        };
        let (alloc, vfpga, fpga, _node) =
            self.hv.alloc_vfpga(spec.user, model)?;
        let result = (|| {
            let bitfile = match &spec.payload {
                JobPayload::UserBitfile(bs) => bs.clone(),
                JobPayload::Service(name) => self.hv.service_bitfile(name)?,
            };
            // Retarget the relocatable bitfile to wherever placement
            // put us (the paper's hide-the-region future-work item).
            let dev = self.hv.device(fpga)?;
            let slot = dev.slot_of[&vfpga];
            let quarters = {
                let hw = dev.fpga.lock().unwrap();
                hw.region(vfpga)
                    .map_err(|e| HypervisorError::Device(e.to_string()))?
                    .shape
                    .quarters()
            };
            let placed = crate::hls::flow::DesignFlow::retarget(
                &bitfile, slot, quarters,
            );
            self.hv.program_vfpga(alloc, spec.user, &placed)?;
            let runner = StreamRunner::new(
                Arc::clone(&self.hv.clock),
                Arc::clone(&self.hv.device(fpga)?.link),
            );
            runner
                .run(&spec.stream)
                .map_err(HypervisorError::Db)
        })();
        // Always release, success or failure.
        let _ = self.hv.release(alloc);
        result
    }

    /// Spawn `n` scheduler worker threads and wait for the queue to
    /// drain (multi-worker variant used by the BAaaS example).
    pub fn drain_with_workers(self: &Arc<Self>, n: usize) {
        std::thread::scope(|scope| {
            for _ in 0..n.max(1) {
                let me = Arc::clone(self);
                scope.spawn(move || me.run_to_completion());
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn system() -> Option<Arc<BatchSystem>> {
        if !crate::runtime::artifact_dir().join("manifest.json").exists() {
            eprintln!("skipping batch test: run `make artifacts`");
            return None;
        }
        let hv =
            Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap());
        Some(BatchSystem::new(hv))
    }

    fn mm16_bitfile() -> Bitstream {
        crate::bitstream::BitstreamBuilder::partial("xc7vx485t", "matmul16")
            .resources(crate::fpga::resources::Resources::new(
                25_298, 41_654, 14, 80,
            ))
            .frames(crate::hls::flow::region_window(0, 1))
            .artifact("matmul16_b256")
            .build()
    }

    fn job(bs: &BatchSystem, mults: u64) -> JobSpec {
        let user = bs.hv.add_user("batcher");
        JobSpec {
            user,
            payload: JobPayload::UserBitfile(mm16_bitfile()),
            stream: StreamConfig::matmul16(mults),
        }
    }

    #[test]
    fn job_runs_to_done() {
        let Some(bs) = system() else { return };
        let id = bs.submit(job(&bs, 512));
        assert!(matches!(bs.state(id), Some(JobState::Queued)));
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Done(out)) => {
                assert_eq!(out.mults, 512);
                assert_eq!(out.validation_failures, 0);
            }
            st => panic!("unexpected state {st:?}"),
        }
    }

    #[test]
    fn jobs_release_their_leases() {
        let Some(bs) = system() else { return };
        for _ in 0..3 {
            bs.submit(job(&bs, 256));
        }
        bs.run_to_completion();
        // All leases returned: 16 free regions again.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn baaas_job_uses_service_store() {
        let Some(bs) = system() else { return };
        bs.hv.register_service("mm16", mm16_bitfile());
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("mm16".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        assert!(matches!(bs.state(id), Some(JobState::Done(_))));
    }

    #[test]
    fn unknown_service_fails_job() {
        let Some(bs) = system() else { return };
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("nope".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("nope"), "{msg}")
            }
            st => panic!("unexpected {st:?}"),
        }
    }

    #[test]
    fn queue_order_preserved() {
        let Some(bs) = system() else { return };
        let a = bs.submit(job(&bs, 256));
        let b = bs.submit(job(&bs, 256));
        assert!(a < b);
        bs.run_to_completion();
        assert!(matches!(bs.state(a), Some(JobState::Done(_))));
        assert!(matches!(bs.state(b), Some(JobState::Done(_))));
    }
}
