//! The batch system.
//!
//! Section IV-C: "we integrated a batch system for long-running
//! applications without direct user interaction to improve overall
//! system utilization. A job of the batch system is to specify the
//! type as well as a configuration file for the FPGAs."
//!
//! Jobs carry a service model, a bitfile (or BAaaS service name) and
//! a stream workload. Admission is *not* handled here anymore: each
//! worker submits to the cluster [`Scheduler`] at batch class and
//! blocks until the fair-share pump grants it a region — the old
//! private FIFO + retry-on-`NoCapacity` loop is gone. Batch leases
//! are preemptable: an interactive request may relocate them via
//! migration, but never mid-operation — setup and streaming hold
//! region pins, so a relocation waits for (or skips) a busy lease.
//!
//! Two execution modes exist:
//!
//! * **inline** ([`BatchSystem::run_to_completion`]) — each worker
//!   runs admission → PR → stream → release serially per job;
//! * **pipelined** ([`BatchSystem::run_pipelined`]) — each worker
//!   overlaps the partial reconfiguration of job *k+1* with the
//!   streaming of job *k* on a double-buffered pair of regions (two
//!   live leases), because `Reserved`/`Programming` is a first-class
//!   region state distinct from `Active`. The PR side rides the
//!   server's async job registry ([`crate::middleware::jobs`]) — a
//!   long operation is already a job there, so pipelining is registry
//!   policy, not an API change. Results are bit-identical to inline
//!   mode; only the makespan shrinks (PR time hides behind streams).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::middleware::api::{ApiError, ErrorCode};
use crate::middleware::jobs::{JobRegistry, JobState as SetupState};
use crate::rc2f::stream::{StreamConfig, StreamOutcome};
use crate::sched::{AdmissionRequest, RequestClass, Scheduler};
use crate::util::ids::{JobId, LeaseToken, UserId};
use crate::util::json::Json;

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub user: UserId,
    /// RAaaS job: user bitfile; BAaaS job: provider service name.
    pub payload: JobPayload,
    /// The stream workload to run once configured.
    pub stream: StreamConfig,
}

/// What configures the vFPGA for this job.
#[derive(Debug, Clone)]
pub enum JobPayload {
    /// RAaaS: user-supplied partial bitfile (slot-retargeted by the
    /// scheduler to wherever the allocation lands).
    UserBitfile(Bitstream),
    /// BAaaS: provider-registered service bitfile.
    Service(String),
}

/// Job lifecycle.
#[derive(Debug, Clone)]
pub enum JobState {
    Queued,
    Running,
    Done(Box<StreamOutcome>),
    Failed(String),
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

struct QueueInner {
    pending: VecDeque<(JobId, JobSpec)>,
    states: BTreeMap<JobId, JobState>,
    next_id: u64,
}

/// A job whose admission + PR is in flight on the async job registry
/// (the pipelined mode's "next" slot). The setup job's result carries
/// the lease token once admitted + programmed.
struct PendingSetup {
    id: JobId,
    spec: JobSpec,
    /// Registry id of the in-flight admission+PR job.
    pr: JobId,
}

/// The batch queue + workers (admission delegated to the scheduler).
pub struct BatchSystem {
    hv: Arc<Hypervisor>,
    sched: Arc<Scheduler>,
    inner: Mutex<QueueInner>,
    /// Async seam for pipelined PR (same registry model the RPC
    /// server uses for long operations).
    jobs: Arc<JobRegistry>,
}

impl BatchSystem {
    /// Stand-alone batch system with its own scheduler.
    pub fn new(hv: Arc<Hypervisor>) -> Arc<BatchSystem> {
        let sched = Scheduler::new(Arc::clone(&hv));
        BatchSystem::with_scheduler(sched)
    }

    /// Batch system sharing the cluster scheduler (so batch jobs
    /// contend fairly with the service façades).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> Arc<BatchSystem> {
        Arc::new(BatchSystem {
            hv: Arc::clone(sched.hv()),
            sched,
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                states: BTreeMap::new(),
                next_id: 0,
            }),
            jobs: JobRegistry::new(),
        })
    }

    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.sched
    }

    /// Enqueue a job; returns its id immediately.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let mut inner = self.inner.lock().unwrap();
        let id = JobId(inner.next_id);
        inner.next_id += 1;
        inner.states.insert(id, JobState::Queued);
        inner.pending.push_back((id, spec));
        id
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().states.get(&id).cloned()
    }

    /// Run jobs until the queue is drained (single worker). Each job:
    /// scheduler admission (blocking, batch class) → retarget &
    /// program → stream → release.
    pub fn run_to_completion(&self) {
        loop {
            let job = self.inner.lock().unwrap().pending.pop_front();
            let Some((id, spec)) = job else { return };
            self.set_state(id, JobState::Running);
            match self.execute(&spec) {
                Ok(outcome) => {
                    self.set_state(id, JobState::Done(Box::new(outcome)))
                }
                Err(e) => self.set_state(id, JobState::Failed(e.to_string())),
            }
        }
    }

    fn set_state(&self, id: JobId, st: JobState) {
        self.inner.lock().unwrap().states.insert(id, st);
    }

    fn execute(&self, spec: &JobSpec) -> Result<StreamOutcome, HypervisorError> {
        let model = match &spec.payload {
            JobPayload::UserBitfile(_) => ServiceModel::RAaaS,
            JobPayload::Service(_) => ServiceModel::BAaaS,
        };
        // Resolve the payload first: an unknown service must fail the
        // job without burning an admission.
        let bitfile = match &spec.payload {
            JobPayload::UserBitfile(bs) => bs.clone(),
            JobPayload::Service(name) => self.hv.service_bitfile(name)?,
        };
        // Block until the fair-share pump admits us; the scheduler
        // enforces quotas and skips us past capacity we cannot use.
        let lease = self
            .sched
            .admit_blocking(&AdmissionRequest::new(
                spec.user,
                model,
                RequestClass::Batch,
            ))
            .map_err(HypervisorError::from)?;
        // Program + stream through the lease handle: each step
        // resolves placement through the lease (a preemption may have
        // migrated us), the bitfile is retargeted to wherever the
        // lease lives (the paper's hide-the-region future-work item),
        // and a preemption racing *inside* a step fails cleanly and
        // is retried once against the new placement instead of
        // failing the job.
        let result = crate::service::run_setup_and_stream(
            &lease,
            &bitfile,
            &spec.stream,
        );
        // Always release through the scheduler, success or failure —
        // that is what pumps the next queued job in.
        let _ = lease.release();
        result
    }

    /// Spawn `n` worker threads and wait for the queue to drain
    /// (multi-worker variant used by the BAaaS example and the
    /// scheduler storm).
    pub fn drain_with_workers(self: &Arc<Self>, n: usize) {
        std::thread::scope(|scope| {
            for _ in 0..n.max(1) {
                let me = Arc::clone(self);
                scope.spawn(move || me.run_to_completion());
            }
        });
    }

    // ------------------------------------------------- pipelined mode

    /// Drain the queue with PR/stream pipelining (single worker):
    /// while job *k* streams on this thread, job *k+1*'s lease is
    /// already admitted and its partial reconfiguration runs on a
    /// registry worker thread — a double-buffered pair of regions.
    /// Job outcomes are identical to [`Self::run_to_completion`];
    /// only the makespan differs.
    pub fn run_pipelined(&self) {
        // Job k: programmed, waiting for its stream turn.
        let mut ready: Option<(JobId, JobSpec, LeaseToken)> = None;
        loop {
            let next = self.inner.lock().unwrap().pending.pop_front();
            let drained = next.is_none();
            // Kick off job k+1's admission + PR before streaming job
            // k — this is the overlap.
            let setup = next
                .and_then(|(id, spec)| self.start_setup(id, spec));
            if let Some((id, spec, token)) = ready.take() {
                self.finish_stream(id, &spec, token);
            }
            if let Some(pending) = setup {
                ready = self.await_setup(pending);
            }
            if drained && ready.is_none() {
                return;
            }
        }
    }

    /// Spawn `n` pipelined workers and wait for the queue to drain.
    pub fn drain_pipelined(self: &Arc<Self>, n: usize) {
        std::thread::scope(|scope| {
            for _ in 0..n.max(1) {
                let me = Arc::clone(self);
                scope.spawn(move || me.run_pipelined());
            }
        });
    }

    /// Submit the job's admission + PR to the async registry. The
    /// *whole* setup — including the blocking admission — runs on the
    /// registry worker, so the batch worker always proceeds to stream
    /// the previous job; on a one-region (or quota-capped) cluster
    /// the setup simply waits for that stream's release instead of
    /// wedging the pipeline. Returns `None` when the job failed fast
    /// (state already set).
    fn start_setup(&self, id: JobId, spec: JobSpec) -> Option<PendingSetup> {
        self.set_state(id, JobState::Running);
        let model = match &spec.payload {
            JobPayload::UserBitfile(_) => ServiceModel::RAaaS,
            JobPayload::Service(_) => ServiceModel::BAaaS,
        };
        // Resolve the payload first: an unknown service must fail the
        // job without burning an admission.
        let bitfile = match &spec.payload {
            JobPayload::UserBitfile(bs) => bs.clone(),
            JobPayload::Service(name) => {
                match self.hv.service_bitfile(name) {
                    Ok(bs) => bs,
                    Err(e) => {
                        self.set_state(
                            id,
                            JobState::Failed(e.to_string()),
                        );
                        return None;
                    }
                }
            }
        };
        let request =
            AdmissionRequest::new(spec.user, model, RequestClass::Batch);
        let sched = Arc::clone(&self.sched);
        let now_ns = self.hv.clock.now().0;
        let pr = Arc::clone(&self.jobs).submit(
            "batch_setup",
            now_ns,
            None,
            move || {
                let lease = sched
                    .admit_blocking(&request)
                    .map_err(|e| ApiError::from(&e))?;
                // Disarm: the token rides the job result back to the
                // batch worker, which streams and releases.
                let token = lease.into_token();
                let handle =
                    sched.lease_handle(token).ok_or_else(|| {
                        ApiError::internal("lease vanished before PR")
                    })?;
                if let Err(e) = handle.program(&bitfile) {
                    let _ = sched.release_token(token);
                    return Err(ApiError::from(&e));
                }
                Ok(Json::from(token.to_string()))
            },
        );
        Some(PendingSetup { id, spec, pr })
    }

    /// Collect a setup job's outcome; on success the job is ready to
    /// stream (token recovered from the job result), on failure it is
    /// failed (the setup job already released anything it held).
    fn await_setup(
        &self,
        pending: PendingSetup,
    ) -> Option<(JobId, JobSpec, LeaseToken)> {
        let PendingSetup { id, spec, pr } = pending;
        let fail = |msg: String| {
            self.set_state(id, JobState::Failed(msg));
        };
        // Wait out the setup for as long as it runs: a registry-wait
        // timeout does NOT stop the worker, and abandoning it here
        // would leak the lease it is still about to admit — exactly
        // the wedge inline mode avoids by blocking in admission.
        let outcome = loop {
            match self.jobs.wait(pr, Duration::from_secs(60)) {
                Err(e) if e.code == ErrorCode::Timeout => continue,
                other => break other,
            }
        };
        match outcome {
            Ok(rec) => match rec.state {
                SetupState::Done(body) => {
                    let token = body
                        .as_str()
                        .and_then(LeaseToken::parse);
                    match token {
                        Some(token) => Some((id, spec, token)),
                        None => {
                            fail("setup returned no lease token"
                                .to_string());
                            None
                        }
                    }
                }
                SetupState::Failed(e) => {
                    fail(e.to_string());
                    None
                }
                other => {
                    fail(format!("setup job ended {}", other.name()));
                    None
                }
            },
            Err(e) => {
                fail(e.to_string());
                None
            }
        }
    }

    /// Stream a programmed job and release its lease.
    fn finish_stream(&self, id: JobId, spec: &JobSpec, token: LeaseToken) {
        let Some(handle) = self.sched.lease_handle(token) else {
            self.set_state(
                id,
                JobState::Failed(
                    "lease vanished before stream".to_string(),
                ),
            );
            return;
        };
        let result = handle.stream_direct(&spec.stream);
        let _ = handle.release();
        match result {
            Ok(outcome) => {
                self.set_state(id, JobState::Done(Box::new(outcome)))
            }
            Err(e) => {
                self.set_state(id, JobState::Failed(e.to_string()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn system() -> Option<Arc<BatchSystem>> {
        if !crate::testing::artifacts_available("batch::tests") {
            return None;
        }
        let hv =
            Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap());
        Some(BatchSystem::new(hv))
    }

    fn mm16_bitfile() -> Bitstream {
        crate::testing::mm16_partial(0)
    }

    fn job(bs: &BatchSystem, mults: u64) -> JobSpec {
        let user = bs.hv.add_user("batcher");
        JobSpec {
            user,
            payload: JobPayload::UserBitfile(mm16_bitfile()),
            stream: StreamConfig::matmul16(mults),
        }
    }

    #[test]
    fn job_runs_to_done() {
        let Some(bs) = system() else { return };
        let id = bs.submit(job(&bs, 512));
        assert!(matches!(bs.state(id), Some(JobState::Queued)));
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Done(out)) => {
                assert_eq!(out.mults, 512);
                assert_eq!(out.validation_failures, 0);
            }
            st => panic!("unexpected state {st:?}"),
        }
    }

    #[test]
    fn jobs_release_their_leases() {
        let Some(bs) = system() else { return };
        for _ in 0..3 {
            bs.submit(job(&bs, 256));
        }
        bs.run_to_completion();
        // All leases returned: 16 free regions again.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn baaas_job_uses_service_store() {
        let Some(bs) = system() else { return };
        bs.hv.register_service("mm16", mm16_bitfile());
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("mm16".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        assert!(matches!(bs.state(id), Some(JobState::Done(_))));
    }

    #[test]
    fn unknown_service_fails_job() {
        let Some(bs) = system() else { return };
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("nope".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("nope"), "{msg}")
            }
            st => panic!("unexpected {st:?}"),
        }
    }

    #[test]
    fn queue_order_preserved() {
        let Some(bs) = system() else { return };
        let a = bs.submit(job(&bs, 256));
        let b = bs.submit(job(&bs, 256));
        assert!(a < b);
        bs.run_to_completion();
        assert!(matches!(bs.state(a), Some(JobState::Done(_))));
        assert!(matches!(bs.state(b), Some(JobState::Done(_))));
    }

    #[test]
    fn pipelined_results_match_inline() {
        let Some(inline_bs) = system() else { return };
        let Some(piped_bs) = system() else { return };
        // Same three jobs (deterministic streams) into both systems.
        let mults = [512u64, 256, 300];
        let inline_ids: Vec<JobId> =
            mults.iter().map(|m| inline_bs.submit(job(&inline_bs, *m))).collect();
        let piped_ids: Vec<JobId> =
            mults.iter().map(|m| piped_bs.submit(job(&piped_bs, *m))).collect();
        inline_bs.run_to_completion();
        piped_bs.run_pipelined();
        for (a, b) in inline_ids.iter().zip(&piped_ids) {
            let (Some(JobState::Done(x)), Some(JobState::Done(y))) =
                (inline_bs.state(*a), piped_bs.state(*b))
            else {
                panic!(
                    "jobs not done: {:?} / {:?}",
                    inline_bs.state(*a),
                    piped_bs.state(*b)
                );
            };
            assert_eq!(x.mults, y.mults);
            assert_eq!(x.checksum, y.checksum, "pipelining changed data");
            assert_eq!(y.validation_failures, 0);
        }
        // All leases returned; the structural no-race invariant held.
        let db = piped_bs.hv.db.lock().unwrap();
        let free: usize = piped_bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
        drop(db);
        assert_eq!(
            piped_bs
                .hv
                .metrics
                .counter("sched.preempt.raced")
                .get(),
            0
        );
    }

    #[test]
    fn pipelined_unknown_service_fails_cleanly() {
        // No artifacts needed: the job fails before any stream.
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(
                crate::util::clock::VirtualClock::new(),
            )
            .unwrap(),
        );
        let bs = BatchSystem::new(hv);
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("ghost".to_string()),
            stream: StreamConfig::matmul16(64),
        });
        bs.run_pipelined();
        match bs.state(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("ghost"), "{msg}")
            }
            st => panic!("unexpected {st:?}"),
        }
        // Nothing leaked: all 16 regions free.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn jobs_charge_the_usage_ledger() {
        let Some(bs) = system() else { return };
        let spec = job(&bs, 256);
        let user = spec.user;
        bs.submit(spec);
        bs.run_to_completion();
        let usage = bs.scheduler().usage(user);
        assert_eq!(usage.granted, 1);
        assert_eq!(usage.released, 1);
        assert!(usage.device_seconds > 0.0);
        assert!(usage.energy_joules > 0.0);
    }
}
