//! The batch system.
//!
//! Section IV-C: "we integrated a batch system for long-running
//! applications without direct user interaction to improve overall
//! system utilization. A job of the batch system is to specify the
//! type as well as a configuration file for the FPGAs."
//!
//! Jobs carry a service model, a bitfile (or BAaaS service name) and
//! a stream workload. Admission is *not* handled here anymore: each
//! worker submits to the cluster [`Scheduler`] at batch class and
//! blocks until the fair-share pump grants it a region — the old
//! private FIFO + retry-on-`NoCapacity` loop is gone. Batch leases
//! are preemptable: an interactive request may relocate them via
//! migration, but never mid-operation — setup and streaming hold
//! region pins, so a relocation waits for (or skips) a busy lease.
//!
//! Two execution modes exist:
//!
//! * **inline** ([`BatchSystem::run_to_completion`]) — each worker
//!   runs admission → PR → stream → release serially per job;
//! * **pipelined** ([`BatchSystem::run_pipelined`]) — each worker
//!   overlaps the partial reconfiguration of job *k+1* with the
//!   streaming of job *k* on a double-buffered pair of regions,
//!   because `Reserved`/`Programming` is a first-class region state
//!   distinct from `Active`. The pair is **long-lived**: a worker
//!   admits its two slots once and reuses them across consecutive
//!   jobs of the same (tenant, model) instead of re-admitting per
//!   job — admission latency is paid once per stretch, not once per
//!   job. Per-job device-second accounting stays correct because the
//!   worker splits the accrual at every job boundary
//!   ([`Scheduler::checkpoint_accrual`]): each job's segment lands in
//!   the ledger when the job finishes, and the final release charges
//!   only the residual. On a capacity-capped cluster the second slot
//!   simply never materializes (non-blocking admit) and the worker
//!   degrades to serial program→stream on one slot — no wedge. The
//!   PR side rides the server's async job registry
//!   ([`crate::middleware::jobs`]). Results are bit-identical to
//!   inline mode; only the makespan shrinks (PR time hides behind
//!   streams).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::bitstream::Bitstream;
use crate::config::ServiceModel;
use crate::hypervisor::{Hypervisor, HypervisorError};
use crate::middleware::api::{ApiError, ErrorCode};
use crate::middleware::jobs::{JobRegistry, JobState as SetupState};
use crate::rc2f::stream::{StreamConfig, StreamOutcome};
use crate::sched::{AdmissionRequest, RequestClass, Scheduler};
use crate::util::ids::{JobId, LeaseToken, UserId};
use crate::util::json::Json;

/// A submitted job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub user: UserId,
    /// RAaaS job: user bitfile; BAaaS job: provider service name.
    pub payload: JobPayload,
    /// The stream workload to run once configured.
    pub stream: StreamConfig,
}

/// What configures the vFPGA for this job.
#[derive(Debug, Clone)]
pub enum JobPayload {
    /// RAaaS: user-supplied partial bitfile (slot-retargeted by the
    /// scheduler to wherever the allocation lands).
    UserBitfile(Bitstream),
    /// BAaaS: provider-registered service bitfile.
    Service(String),
}

/// Job lifecycle.
#[derive(Debug, Clone)]
pub enum JobState {
    Queued,
    Running,
    Done(Box<StreamOutcome>),
    Failed(String),
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }
}

struct QueueInner {
    pending: VecDeque<(JobId, JobSpec)>,
    states: BTreeMap<JobId, JobState>,
    next_id: u64,
}

/// A pipelined worker's long-lived admitted slots: one or two
/// single-region leases of one (tenant, model), reused across
/// consecutive jobs.
struct Pair {
    user: UserId,
    model: ServiceModel,
    slots: Vec<LeaseToken>,
}

/// A job programmed onto `slot`, waiting for its stream turn.
struct Ready {
    id: JobId,
    spec: JobSpec,
    slot: usize,
}

/// The batch queue + workers (admission delegated to the scheduler).
pub struct BatchSystem {
    hv: Arc<Hypervisor>,
    sched: Arc<Scheduler>,
    inner: Mutex<QueueInner>,
    /// Async seam for pipelined PR (same registry model the RPC
    /// server uses for long operations).
    jobs: Arc<JobRegistry>,
}

impl BatchSystem {
    /// Stand-alone batch system with its own scheduler.
    pub fn new(hv: Arc<Hypervisor>) -> Arc<BatchSystem> {
        let sched = Scheduler::new(Arc::clone(&hv));
        BatchSystem::with_scheduler(sched)
    }

    /// Batch system sharing the cluster scheduler (so batch jobs
    /// contend fairly with the service façades).
    pub fn with_scheduler(sched: Arc<Scheduler>) -> Arc<BatchSystem> {
        Arc::new(BatchSystem {
            hv: Arc::clone(sched.hv()),
            sched,
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                states: BTreeMap::new(),
                next_id: 0,
            }),
            jobs: JobRegistry::new(),
        })
    }

    pub fn scheduler(&self) -> &Arc<Scheduler> {
        &self.sched
    }

    /// Enqueue a job; returns its id immediately.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let mut inner = self.inner.lock().unwrap();
        let id = JobId(inner.next_id);
        inner.next_id += 1;
        inner.states.insert(id, JobState::Queued);
        inner.pending.push_back((id, spec));
        id
    }

    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.inner.lock().unwrap().states.get(&id).cloned()
    }

    /// Run jobs until the queue is drained (single worker). Each job:
    /// scheduler admission (blocking, batch class) → retarget &
    /// program → stream → release.
    pub fn run_to_completion(&self) {
        loop {
            let job = self.inner.lock().unwrap().pending.pop_front();
            let Some((id, spec)) = job else { return };
            self.set_state(id, JobState::Running);
            match self.execute(&spec) {
                Ok(outcome) => {
                    self.set_state(id, JobState::Done(Box::new(outcome)))
                }
                Err(e) => self.set_state(id, JobState::Failed(e.to_string())),
            }
        }
    }

    fn set_state(&self, id: JobId, st: JobState) {
        self.inner.lock().unwrap().states.insert(id, st);
    }

    fn execute(&self, spec: &JobSpec) -> Result<StreamOutcome, HypervisorError> {
        let model = match &spec.payload {
            JobPayload::UserBitfile(_) => ServiceModel::RAaaS,
            JobPayload::Service(_) => ServiceModel::BAaaS,
        };
        // Resolve the payload first: an unknown service must fail the
        // job without burning an admission.
        let bitfile = match &spec.payload {
            JobPayload::UserBitfile(bs) => bs.clone(),
            JobPayload::Service(name) => self.hv.service_bitfile(name)?,
        };
        // Block until the fair-share pump admits us; the scheduler
        // enforces quotas and skips us past capacity we cannot use.
        let lease = self
            .sched
            .admit_blocking(&AdmissionRequest::new(
                spec.user,
                model,
                RequestClass::Batch,
            ))
            .map_err(HypervisorError::from)?;
        // Program + stream through the lease handle: each step
        // resolves placement through the lease (a preemption may have
        // migrated us), the bitfile is retargeted to wherever the
        // lease lives (the paper's hide-the-region future-work item),
        // and a preemption racing *inside* a step fails cleanly and
        // is retried once against the new placement instead of
        // failing the job.
        let result = crate::service::run_setup_and_stream(
            &lease,
            &bitfile,
            &spec.stream,
        );
        // Always release through the scheduler, success or failure —
        // that is what pumps the next queued job in.
        let _ = lease.release();
        result
    }

    /// Spawn `n` worker threads and wait for the queue to drain
    /// (multi-worker variant used by the BAaaS example and the
    /// scheduler storm).
    pub fn drain_with_workers(self: &Arc<Self>, n: usize) {
        std::thread::scope(|scope| {
            for _ in 0..n.max(1) {
                let me = Arc::clone(self);
                scope.spawn(move || me.run_to_completion());
            }
        });
    }

    // ------------------------------------------------- pipelined mode

    /// Drain the queue with PR/stream pipelining (single worker):
    /// while job *k* streams on this thread, job *k+1*'s partial
    /// reconfiguration runs on a registry worker thread — a
    /// double-buffered pair of regions. The pair is admitted **once**
    /// and reused across consecutive jobs of one (tenant, model);
    /// accrual is checkpointed at every job boundary so per-job
    /// device-second accounting matches the re-admit-per-job flow.
    /// Job outcomes are identical to [`Self::run_to_completion`];
    /// only the makespan differs.
    pub fn run_pipelined(&self) {
        let mut pair: Option<Pair> = None;
        // Job k: programmed on `pair.slots[ready.slot]`, waiting for
        // its stream turn.
        let mut ready: Option<Ready> = None;
        loop {
            let next = self.inner.lock().unwrap().pending.pop_front();
            let Some((id, spec)) = next else {
                if let (Some(r), Some(p)) = (ready.take(), pair.as_ref())
                {
                    self.stream_slot(p, r);
                }
                self.retire_pair(&mut pair);
                return;
            };
            self.set_state(id, JobState::Running);
            // Resolve the payload first: an unknown service must fail
            // the job without burning an admission.
            let Some(bitfile) = self.resolve_payload(id, &spec) else {
                continue;
            };
            let (user, model) = Self::job_identity(&spec);
            // Tenant/model switch: finish the in-flight job, then
            // retire the old pair (checkpointing its residual accrual
            // through release) before admitting for the new identity.
            if pair
                .as_ref()
                .is_some_and(|p| p.user != user || p.model != model)
            {
                if let (Some(r), Some(p)) = (ready.take(), pair.as_ref())
                {
                    self.stream_slot(p, r);
                }
                self.retire_pair(&mut pair);
            }
            // Ensure the primary slot (blocking — same backpressure
            // as inline admission).
            if pair.is_none() {
                match self.admit_slot(user, model, true) {
                    Ok(token) => {
                        pair = Some(Pair {
                            user,
                            model,
                            slots: vec![token],
                        })
                    }
                    Err(e) => {
                        self.set_state(id, JobState::Failed(e));
                        continue;
                    }
                }
            }
            let p = pair.as_mut().expect("pair ensured above");
            // Grow to the full pair only when overlap is actually
            // possible; a capacity-capped cluster just stays serial.
            if ready.is_some() && p.slots.len() == 1 {
                if let Ok(token) = self.admit_slot(user, model, false) {
                    p.slots.push(token);
                }
            }
            match ready.take() {
                Some(r) if p.slots.len() == 2 => {
                    // Overlap: program the idle slot on the registry
                    // while this thread streams job k.
                    let idle = 1 - r.slot;
                    let setup =
                        self.start_program(p.slots[idle], bitfile);
                    self.stream_slot(p, r);
                    ready = self.await_program(id, spec, idle, setup);
                }
                Some(r) => {
                    // One slot only: stream first, then program it.
                    let slot = r.slot;
                    self.stream_slot(p, r);
                    ready =
                        self.program_inline(id, spec, bitfile, p, slot);
                }
                None => {
                    ready = self.program_inline(id, spec, bitfile, p, 0);
                }
            }
        }
    }

    /// Spawn `n` pipelined workers and wait for the queue to drain.
    pub fn drain_pipelined(self: &Arc<Self>, n: usize) {
        std::thread::scope(|scope| {
            for _ in 0..n.max(1) {
                let me = Arc::clone(self);
                scope.spawn(move || me.run_pipelined());
            }
        });
    }

    fn job_identity(spec: &JobSpec) -> (UserId, ServiceModel) {
        let model = match &spec.payload {
            JobPayload::UserBitfile(_) => ServiceModel::RAaaS,
            JobPayload::Service(_) => ServiceModel::BAaaS,
        };
        (spec.user, model)
    }

    /// Resolve the job's bitfile, failing the job (and returning
    /// `None`) on an unknown service.
    fn resolve_payload(
        &self,
        id: JobId,
        spec: &JobSpec,
    ) -> Option<Bitstream> {
        match &spec.payload {
            JobPayload::UserBitfile(bs) => Some(bs.clone()),
            JobPayload::Service(name) => {
                match self.hv.service_bitfile(name) {
                    Ok(bs) => Some(bs),
                    Err(e) => {
                        self.set_state(
                            id,
                            JobState::Failed(e.to_string()),
                        );
                        None
                    }
                }
            }
        }
    }

    /// Admit one single-region batch slot for the pair. `blocking`
    /// waits on the fair-share pump; non-blocking returns the
    /// scheduler's immediate answer (used for the optional second
    /// slot, where "no capacity" means "stay serial", not "fail").
    fn admit_slot(
        &self,
        user: UserId,
        model: ServiceModel,
        blocking: bool,
    ) -> Result<LeaseToken, String> {
        let request =
            AdmissionRequest::new(user, model, RequestClass::Batch);
        let lease = if blocking {
            self.sched.admit_blocking(&request)
        } else {
            self.sched.admit(&request)
        }
        .map_err(|e| e.to_string())?;
        // Disarm: the pair owns the slot across jobs.
        Ok(lease.into_token())
    }

    /// Release every slot of the pair (residual accrual is charged by
    /// the release itself).
    fn retire_pair(&self, pair: &mut Option<Pair>) {
        if let Some(p) = pair.take() {
            for token in p.slots {
                let _ = self.sched.release_token(token);
            }
        }
    }

    /// Submit the PR of `bitfile` onto the slot's lease to the async
    /// registry (the overlap seam).
    fn start_program(
        &self,
        token: LeaseToken,
        bitfile: Bitstream,
    ) -> JobId {
        let sched = Arc::clone(&self.sched);
        let now_ns = self.hv.clock.now().0;
        Arc::clone(&self.jobs).submit(
            "batch_setup",
            now_ns,
            None,
            move |_progress| {
                let handle =
                    sched.lease_handle(token).ok_or_else(|| {
                        ApiError::internal("slot lease vanished")
                    })?;
                handle
                    .program(&bitfile)
                    .map_err(|e| ApiError::from(&e))?;
                Ok(Json::Null)
            },
        )
    }

    /// Collect an overlapped PR's outcome; on success the job is
    /// ready to stream on `slot`.
    fn await_program(
        &self,
        id: JobId,
        spec: JobSpec,
        slot: usize,
        pr: JobId,
    ) -> Option<Ready> {
        // Wait out the setup for as long as it runs: a registry-wait
        // timeout does NOT stop the worker, and abandoning it would
        // desynchronize the pair.
        let outcome = loop {
            match self.jobs.wait(pr, Duration::from_secs(60)) {
                Err(e) if e.code == ErrorCode::Timeout => continue,
                other => break other,
            }
        };
        match outcome {
            Ok(rec) => match rec.state {
                SetupState::Done(_) => Some(Ready { id, spec, slot }),
                SetupState::Failed(e) => {
                    self.set_state(id, JobState::Failed(e.to_string()));
                    None
                }
                other => {
                    self.set_state(
                        id,
                        JobState::Failed(format!(
                            "setup job ended {}",
                            other.name()
                        )),
                    );
                    None
                }
            },
            Err(e) => {
                self.set_state(id, JobState::Failed(e.to_string()));
                None
            }
        }
    }

    /// Program `slot` on this thread (no overlap available).
    fn program_inline(
        &self,
        id: JobId,
        spec: JobSpec,
        bitfile: Bitstream,
        pair: &Pair,
        slot: usize,
    ) -> Option<Ready> {
        let Some(handle) = self.sched.lease_handle(pair.slots[slot])
        else {
            self.set_state(
                id,
                JobState::Failed("slot lease vanished".to_string()),
            );
            return None;
        };
        match handle.program(&bitfile) {
            Ok(_) => Some(Ready { id, spec, slot }),
            Err(e) => {
                self.set_state(id, JobState::Failed(e.to_string()));
                None
            }
        }
    }

    /// Stream a programmed job on its slot, then split the pair's
    /// accrual at the job boundary so this job's device-seconds land
    /// in the ledger now (the slot itself stays admitted).
    fn stream_slot(&self, pair: &Pair, ready: Ready) {
        let Ready { id, spec, slot } = ready;
        let Some(handle) = self.sched.lease_handle(pair.slots[slot])
        else {
            self.set_state(
                id,
                JobState::Failed(
                    "lease vanished before stream".to_string(),
                ),
            );
            return;
        };
        let result = handle.stream_direct(&spec.stream);
        // Job boundary: charge this job's segment (for every slot of
        // the pair — idle time is the tenant's to pay too).
        for token in &pair.slots {
            let _ = self.sched.checkpoint_accrual(*token);
        }
        match result {
            Ok(outcome) => {
                self.set_state(id, JobState::Done(Box::new(outcome)))
            }
            Err(e) => {
                self.set_state(id, JobState::Failed(e.to_string()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn system() -> Option<Arc<BatchSystem>> {
        if !crate::testing::artifacts_available("batch::tests") {
            return None;
        }
        let hv =
            Arc::new(Hypervisor::boot_paper_testbed(VirtualClock::new()).unwrap());
        Some(BatchSystem::new(hv))
    }

    fn mm16_bitfile() -> Bitstream {
        crate::testing::mm16_partial(0)
    }

    fn job(bs: &BatchSystem, mults: u64) -> JobSpec {
        let user = bs.hv.add_user("batcher");
        JobSpec {
            user,
            payload: JobPayload::UserBitfile(mm16_bitfile()),
            stream: StreamConfig::matmul16(mults),
        }
    }

    #[test]
    fn job_runs_to_done() {
        let Some(bs) = system() else { return };
        let id = bs.submit(job(&bs, 512));
        assert!(matches!(bs.state(id), Some(JobState::Queued)));
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Done(out)) => {
                assert_eq!(out.mults, 512);
                assert_eq!(out.validation_failures, 0);
            }
            st => panic!("unexpected state {st:?}"),
        }
    }

    #[test]
    fn jobs_release_their_leases() {
        let Some(bs) = system() else { return };
        for _ in 0..3 {
            bs.submit(job(&bs, 256));
        }
        bs.run_to_completion();
        // All leases returned: 16 free regions again.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn baaas_job_uses_service_store() {
        let Some(bs) = system() else { return };
        bs.hv.register_service("mm16", mm16_bitfile());
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("mm16".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        assert!(matches!(bs.state(id), Some(JobState::Done(_))));
    }

    #[test]
    fn unknown_service_fails_job() {
        let Some(bs) = system() else { return };
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("nope".to_string()),
            stream: StreamConfig::matmul16(256),
        });
        bs.run_to_completion();
        match bs.state(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("nope"), "{msg}")
            }
            st => panic!("unexpected {st:?}"),
        }
    }

    #[test]
    fn queue_order_preserved() {
        let Some(bs) = system() else { return };
        let a = bs.submit(job(&bs, 256));
        let b = bs.submit(job(&bs, 256));
        assert!(a < b);
        bs.run_to_completion();
        assert!(matches!(bs.state(a), Some(JobState::Done(_))));
        assert!(matches!(bs.state(b), Some(JobState::Done(_))));
    }

    #[test]
    fn pipelined_results_match_inline() {
        let Some(inline_bs) = system() else { return };
        let Some(piped_bs) = system() else { return };
        // Same three jobs (deterministic streams) into both systems.
        let mults = [512u64, 256, 300];
        let inline_ids: Vec<JobId> =
            mults.iter().map(|m| inline_bs.submit(job(&inline_bs, *m))).collect();
        let piped_ids: Vec<JobId> =
            mults.iter().map(|m| piped_bs.submit(job(&piped_bs, *m))).collect();
        inline_bs.run_to_completion();
        piped_bs.run_pipelined();
        for (a, b) in inline_ids.iter().zip(&piped_ids) {
            let (Some(JobState::Done(x)), Some(JobState::Done(y))) =
                (inline_bs.state(*a), piped_bs.state(*b))
            else {
                panic!(
                    "jobs not done: {:?} / {:?}",
                    inline_bs.state(*a),
                    piped_bs.state(*b)
                );
            };
            assert_eq!(x.mults, y.mults);
            assert_eq!(x.checksum, y.checksum, "pipelining changed data");
            assert_eq!(y.validation_failures, 0);
        }
        // All leases returned; the structural no-race invariant held.
        let db = piped_bs.hv.db.lock().unwrap();
        let free: usize = piped_bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
        drop(db);
        assert_eq!(
            piped_bs
                .hv
                .metrics
                .counter("sched.preempt.raced")
                .get(),
            0
        );
    }

    #[test]
    fn pipelined_unknown_service_fails_cleanly() {
        // No artifacts needed: the job fails before any stream.
        let hv = Arc::new(
            Hypervisor::boot_paper_testbed(
                crate::util::clock::VirtualClock::new(),
            )
            .unwrap(),
        );
        let bs = BatchSystem::new(hv);
        let user = bs.hv.add_user("enduser");
        let id = bs.submit(JobSpec {
            user,
            payload: JobPayload::Service("ghost".to_string()),
            stream: StreamConfig::matmul16(64),
        });
        bs.run_pipelined();
        match bs.state(id) {
            Some(JobState::Failed(msg)) => {
                assert!(msg.contains("ghost"), "{msg}")
            }
            st => panic!("unexpected {st:?}"),
        }
        // Nothing leaked: all 16 regions free.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn pipelined_reuses_a_persistent_pair() {
        let Some(bs) = system() else { return };
        let user = bs.hv.add_user("pairy");
        let ids: Vec<JobId> = (0..4)
            .map(|_| {
                bs.submit(JobSpec {
                    user,
                    payload: JobPayload::UserBitfile(mm16_bitfile()),
                    stream: StreamConfig::matmul16(256),
                })
            })
            .collect();
        bs.run_pipelined();
        for id in ids {
            assert!(
                matches!(bs.state(id), Some(JobState::Done(_))),
                "{:?}",
                bs.state(id)
            );
        }
        let usage = bs.scheduler().usage(user);
        // Four same-tenant jobs shared one long-lived pair: at most
        // two admissions, not four.
        assert!(usage.granted <= 2, "granted {}", usage.granted);
        assert_eq!(usage.granted, usage.released);
        // Accrual split at job boundaries still bills the tenant.
        assert!(usage.device_seconds > 0.0);
        assert!(usage.energy_joules > 0.0);
        // The pair was retired at drain: every region is free again.
        let db = bs.hv.db.lock().unwrap();
        let free: usize = bs
            .hv
            .device_ids()
            .iter()
            .map(|f| db.free_regions(*f).len())
            .sum();
        assert_eq!(free, 16);
    }

    #[test]
    fn jobs_charge_the_usage_ledger() {
        let Some(bs) = system() else { return };
        let spec = job(&bs, 256);
        let user = spec.user;
        bs.submit(spec);
        bs.run_to_completion();
        let usage = bs.scheduler().usage(user);
        assert_eq!(usage.granted, 1);
        assert_eq!(usage.released, 1);
        assert!(usage.device_seconds > 0.0);
        assert!(usage.energy_joules > 0.0);
    }
}
