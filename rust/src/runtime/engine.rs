//! Thread-local PJRT engine: compile once, execute many.
//!
//! Mirrors /opt/xla-example/load_hlo: HLO text →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are cached per engine;
//! engines are cheap enough to build one per worker thread (the
//! `PjRtClient` is `Rc`-based and cannot cross threads).

use std::collections::HashMap;
use std::path::Path;

use super::artifacts::{ArtifactStore, TensorSpec};

/// A host-side tensor (f32, row-major) moving through the engine.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape/data mismatch"
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape,
            data: vec![0.0; n],
        }
    }

    /// Random tensor from the deterministic workload generator.
    pub fn random(shape: Vec<usize>, rng: &mut crate::util::rng::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        rng.fill_f32(&mut data, 1.0);
        Tensor { shape, data }
    }

    pub fn byte_len(&self) -> usize {
        self.data.len() * 4
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape == spec.shape
    }
}

/// Engine errors.
#[derive(Debug, thiserror::Error)]
pub enum EngineError {
    #[error("unknown artifact '{0}'")]
    UnknownArtifact(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error(
        "input {index} shape {got:?} does not match contract {want:?} \
         for artifact '{artifact}'"
    )]
    ShapeMismatch {
        artifact: String,
        index: usize,
        got: Vec<usize>,
        want: Vec<usize>,
    },
    #[error("artifact '{artifact}' expects {want} inputs, got {got}")]
    ArityMismatch {
        artifact: String,
        want: usize,
        got: usize,
    },
}

impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

/// One thread's compiled-executable cache over a PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    store: ArtifactStore,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Build an engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Engine, EngineError> {
        let store = ArtifactStore::discover(artifact_dir)
            .map_err(EngineError::Xla)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            store,
            cache: HashMap::new(),
        })
    }

    /// Engine over the default artifact location.
    pub fn with_default_artifacts() -> Result<Engine, EngineError> {
        Engine::new(&super::artifact_dir())
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact's executable.
    pub fn load(&mut self, name: &str) -> Result<(), EngineError> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        if self.store.meta(name).is_none() {
            return Err(EngineError::UnknownArtifact(name.to_string()));
        }
        let path = self.store.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Is an executable already compiled in this engine?
    pub fn is_loaded(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Execute an artifact on a set of input tensors, validating the
    /// shape contract first. Returns the output tensors.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>, EngineError> {
        let meta = self
            .store
            .meta(name)
            .ok_or_else(|| EngineError::UnknownArtifact(name.to_string()))?
            .clone();
        if inputs.len() != meta.inputs.len() {
            return Err(EngineError::ArityMismatch {
                artifact: name.to_string(),
                want: meta.inputs.len(),
                got: inputs.len(),
            });
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if !t.matches(spec) {
                return Err(EngineError::ShapeMismatch {
                    artifact: name.to_string(),
                    index: i,
                    got: t.shape.clone(),
                    want: spec.shape.clone(),
                });
            }
        }
        self.load(name)?;
        let exe = self.cache.get(name).expect("just loaded");

        // Hot path: host data → device buffer is a single copy
        // (no Literal materialization), execute_b runs on buffers,
        // and the single array output is read back with one
        // copy_raw_to_host_sync into a pre-sized Vec.
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.shape, None)
            })
            .collect::<Result<_, _>>()?;

        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        // Lowered with return_tuple=False; every registered variant
        // has exactly one output array (enforced here so a future
        // multi-output variant fails loudly rather than silently
        // misreading a tuple buffer).
        if meta.outputs.len() != 1 {
            return Err(EngineError::Xla(format!(
                "artifact '{name}' declares {} outputs; the fast \
                 single-output path requires exactly 1",
                meta.outputs.len()
            )));
        }
        let spec = &meta.outputs[0];
        // copy_raw_to_host is unimplemented on the TFRT CPU client, so
        // the readback goes through a (non-tuple) Literal: one device→
        // host copy + one Literal→Vec copy. Still one copy fewer than
        // the original tuple path on both sides.
        let lit = result[0][0].to_literal_sync()?;
        Ok(vec![Tensor::new(spec.shape.clone(), lit.to_vec::<f32>()?)])
    }

    /// Convenience: batched matmul through a named matmul artifact.
    pub fn matmul(
        &mut self,
        name: &str,
        xs: Tensor,
        ys: Tensor,
    ) -> Result<Tensor, EngineError> {
        let mut out = self.execute(name, &[xs, ys])?;
        Ok(out.remove(0))
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("platform", &self.client.platform_name())
            .field("cached", &self.cache.keys().collect::<Vec<_>>())
            .finish()
    }
}

/// Pure-Rust reference matmul used by tests to validate engine output
/// (the rust-side analogue of python's ref.py).
pub fn matmul_ref(xs: &Tensor, ys: &Tensor) -> Tensor {
    let (b, n) = (xs.shape[0], xs.shape[1]);
    let mut out = vec![0.0f32; b * n * n];
    for m in 0..b {
        let xo = m * n * n;
        for i in 0..n {
            for k in 0..n {
                let xv = xs.data[xo + i * n + k];
                if xv == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[xo + i * n + j] += xv * ys.data[xo + k * n + j];
                }
            }
        }
    }
    Tensor::new(xs.shape.clone(), out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn engine() -> Option<Engine> {
        let dir = crate::runtime::artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping engine test: run `make artifacts`");
            return None;
        }
        Some(Engine::new(&dir).unwrap())
    }

    #[test]
    fn executes_matmul16_and_matches_reference() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Rng::new(42);
        let xs = Tensor::random(vec![64, 16, 16], &mut rng);
        let ys = Tensor::random(vec![64, 16, 16], &mut rng);
        let out = eng.matmul("matmul16_b64", xs.clone(), ys.clone()).unwrap();
        let expect = matmul_ref(&xs, &ys);
        assert_eq!(out.shape, expect.shape);
        for (a, b) in out.data.iter().zip(&expect.data) {
            assert!((a - b).abs() <= 1e-4 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn executes_matmul32() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Rng::new(1);
        let xs = Tensor::random(vec![64, 32, 32], &mut rng);
        let ys = Tensor::random(vec![64, 32, 32], &mut rng);
        let out = eng.matmul("matmul32_b64", xs.clone(), ys.clone()).unwrap();
        let expect = matmul_ref(&xs, &ys);
        for (a, b) in out.data.iter().zip(&expect.data) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }

    #[test]
    fn loopback_is_identity() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Rng::new(2);
        let xs = Tensor::random(vec![256, 16, 16], &mut rng);
        let out = eng.execute("loopback16_b256", &[xs.clone()]).unwrap();
        assert_eq!(out[0], xs);
    }

    #[test]
    fn saxpy_matches() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Rng::new(3);
        let a = Tensor::new(vec![], vec![2.5]);
        let xs = Tensor::random(vec![256, 16, 16], &mut rng);
        let ys = Tensor::random(vec![256, 16, 16], &mut rng);
        let out = eng
            .execute("saxpy16_b256", &[a, xs.clone(), ys.clone()])
            .unwrap();
        for ((o, x), y) in out[0].data.iter().zip(&xs.data).zip(&ys.data) {
            assert!((o - (2.5 * x + y)).abs() < 1e-4);
        }
    }

    #[test]
    fn checksum_matches() {
        let Some(mut eng) = engine() else { return };
        let mut rng = Rng::new(4);
        let xs = Tensor::random(vec![256, 16, 16], &mut rng);
        let out = eng.execute("checksum16_b256", &[xs.clone()]).unwrap();
        assert_eq!(out[0].shape, vec![256]);
        for (m, o) in out[0].data.iter().enumerate() {
            let s: f32 = xs.data[m * 256..(m + 1) * 256].iter().sum();
            assert!((o - s).abs() < 1e-2, "{o} vs {s}");
        }
    }

    #[test]
    fn shape_contract_enforced() {
        let Some(mut eng) = engine() else { return };
        let bad = Tensor::zeros(vec![32, 16, 16]); // batch 32 != 64
        let good = Tensor::zeros(vec![64, 16, 16]);
        let err = eng.execute("matmul16_b64", &[bad, good]).unwrap_err();
        assert!(matches!(err, EngineError::ShapeMismatch { .. }));
    }

    #[test]
    fn arity_enforced() {
        let Some(mut eng) = engine() else { return };
        let t = Tensor::zeros(vec![64, 16, 16]);
        let err = eng.execute("matmul16_b64", &[t]).unwrap_err();
        assert!(matches!(err, EngineError::ArityMismatch { .. }));
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(mut eng) = engine() else { return };
        assert!(matches!(
            eng.load("nonexistent_core"),
            Err(EngineError::UnknownArtifact(_))
        ));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(mut eng) = engine() else { return };
        assert!(!eng.is_loaded("matmul16_b64"));
        eng.load("matmul16_b64").unwrap();
        assert!(eng.is_loaded("matmul16_b64"));
        eng.load("matmul16_b64").unwrap(); // second load is a no-op
    }

    #[test]
    fn matmul_ref_is_correct_on_identity() {
        let b = 2;
        let n = 4;
        let mut eye = Tensor::zeros(vec![b, n, n]);
        for m in 0..b {
            for i in 0..n {
                eye.data[m * n * n + i * n + i] = 1.0;
            }
        }
        let mut rng = Rng::new(5);
        let xs = Tensor::random(vec![b, n, n], &mut rng);
        let out = matmul_ref(&xs, &eye);
        assert_eq!(out, xs);
    }
}
