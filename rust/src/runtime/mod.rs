//! PJRT execution engine — the vFPGA "user core" compute substrate.
//!
//! Loads the HLO-text artifacts that `make artifacts` lowered from the
//! L2 JAX models (which call the L1 Pallas kernels), compiles them on
//! the PJRT CPU client via the `xla` crate, and executes them on the
//! request path. Python never runs here.
//!
//! Thread model: the `xla` crate's `PjRtClient` is `Rc`-based and not
//! `Send`, so an [`engine::Engine`] is *thread-local* — every vFPGA
//! core worker constructs its own engine (compilation of the small
//! stream kernels takes milliseconds and is cached per thread).

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactMeta, ArtifactStore, TensorSpec};
pub use engine::{Engine, EngineError, Tensor};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `RC3E_ARTIFACTS` env var, else
/// `artifacts/` relative to the current dir, else relative to the
/// crate manifest (so `cargo test` works from any cwd).
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("RC3E_ARTIFACTS") {
        return dir.into();
    }
    let cwd = std::path::Path::new(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.json").exists() {
        return cwd.to_path_buf();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT_DIR)
}
