//! Artifact discovery and metadata.
//!
//! `make artifacts` writes, per user-core variant:
//! * `<name>.hlo.txt`  — the HLO module text (the interchange format;
//!   serialized protos from jax ≥ 0.5 are rejected by xla_extension
//!   0.5.1, see DESIGN.md),
//! * `<name>.meta.json` — the shape/dtype contract this module
//!   validates before anything is compiled or executed (the same role
//!   the paper's bitfile metadata plays for vFPGA compatibility),
//! plus a `manifest.json` mapping variant names to content hashes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Shape + dtype of one tensor in the artifact contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        // All paper cores stream 32-bit floats (Table III header).
        self.elements() * 4
    }

    fn from_json(v: &Json) -> Option<TensorSpec> {
        let shape = v
            .get("shape")
            .as_arr()?
            .iter()
            .map(|d| d.as_u64().map(|u| u as usize))
            .collect::<Option<Vec<_>>>()?;
        Some(TensorSpec {
            shape,
            dtype: v.get("dtype").as_str()?.to_string(),
        })
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

impl ArtifactMeta {
    pub fn parse(text: &str) -> Result<ArtifactMeta, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>, String> {
            v.get(key)
                .as_arr()
                .ok_or_else(|| format!("meta missing '{key}'"))?
                .iter()
                .map(|t| {
                    TensorSpec::from_json(t)
                        .ok_or_else(|| format!("bad tensor spec in '{key}'"))
                })
                .collect()
        };
        Ok(ArtifactMeta {
            name: v.str_field("name")?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            sha256: v.str_field("sha256")?.to_string(),
        })
    }

    /// The streaming batch size (leading dim of the first input).
    pub fn batch(&self) -> usize {
        self.inputs
            .iter()
            .find(|t| !t.shape.is_empty())
            .map(|t| t.shape[0])
            .unwrap_or(0)
    }

    /// Bytes per invocation moved host→device (sum of input sizes).
    pub fn input_bytes(&self) -> usize {
        self.inputs.iter().map(|t| t.byte_len()).sum()
    }

    /// Bytes per invocation moved device→host.
    pub fn output_bytes(&self) -> usize {
        self.outputs.iter().map(|t| t.byte_len()).sum()
    }
}

/// Discovered artifacts (name → paths + meta).
#[derive(Debug, Default)]
pub struct ArtifactStore {
    dir: PathBuf,
    metas: BTreeMap<String, ArtifactMeta>,
}

impl ArtifactStore {
    /// Scan a directory for `<name>.meta.json` + `<name>.hlo.txt`
    /// pairs. Missing HLO for a meta (or vice versa) is an error —
    /// a torn artifact directory should fail loudly at startup.
    pub fn discover(dir: &Path) -> Result<ArtifactStore, String> {
        let mut metas = BTreeMap::new();
        let entries = std::fs::read_dir(dir)
            .map_err(|e| format!("artifact dir {}: {e}", dir.display()))?;
        for entry in entries {
            let path = entry.map_err(|e| e.to_string())?.path();
            let Some(name) = path
                .file_name()
                .and_then(|n| n.to_str())
                .and_then(|n| n.strip_suffix(".meta.json"))
            else {
                continue;
            };
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            let meta = ArtifactMeta::parse(&text)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            let hlo = dir.join(format!("{name}.hlo.txt"));
            if !hlo.exists() {
                return Err(format!(
                    "meta for '{name}' present but {} missing",
                    hlo.display()
                ));
            }
            metas.insert(name.to_string(), meta);
        }
        Ok(ArtifactStore {
            dir: dir.to_path_buf(),
            metas,
        })
    }

    pub fn names(&self) -> Vec<&str> {
        self.metas.keys().map(|s| s.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = r#"{
      "name": "matmul16_b64",
      "inputs": [
        {"shape": [64, 16, 16], "dtype": "float32"},
        {"shape": [64, 16, 16], "dtype": "float32"}
      ],
      "outputs": [{"shape": [64, 16, 16], "dtype": "float32"}],
      "sha256": "abc",
      "hlo_bytes": 5419
    }"#;

    #[test]
    fn parse_meta() {
        let m = ArtifactMeta::parse(META).unwrap();
        assert_eq!(m.name, "matmul16_b64");
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.inputs[0].shape, vec![64, 16, 16]);
        assert_eq!(m.batch(), 64);
        assert_eq!(m.input_bytes(), 2 * 64 * 16 * 16 * 4);
        assert_eq!(m.output_bytes(), 64 * 16 * 16 * 4);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(ArtifactMeta::parse("{}").is_err());
        assert!(ArtifactMeta::parse("not json").is_err());
        assert!(ArtifactMeta::parse(
            r#"{"name":"x","inputs":[{"shape":"bad"}],"outputs":[],"sha256":"s"}"#
        )
        .is_err());
    }

    #[test]
    fn discover_real_artifacts() {
        let dir = crate::runtime::artifact_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let store = ArtifactStore::discover(&dir).unwrap();
        for required in [
            "matmul16_b256",
            "matmul16_b64",
            "matmul32_b64",
            "loopback16_b256",
        ] {
            let meta = store
                .meta(required)
                .unwrap_or_else(|| panic!("missing artifact {required}"));
            assert!(store.hlo_path(required).exists());
            assert_eq!(meta.sha256.len(), 64);
        }
    }

    #[test]
    fn discover_rejects_torn_dir() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_torn_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.meta.json"), META).unwrap();
        // no x.hlo.txt
        let err = ArtifactStore::discover(&dir).unwrap_err();
        assert!(err.contains("missing"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tensor_spec_sizes() {
        let t = TensorSpec {
            shape: vec![256, 16, 16],
            dtype: "float32".into(),
        };
        assert_eq!(t.elements(), 65536);
        assert_eq!(t.byte_len(), 262144);
        let scalar = TensorSpec {
            shape: vec![],
            dtype: "float32".into(),
        };
        assert_eq!(scalar.elements(), 1);
    }
}
