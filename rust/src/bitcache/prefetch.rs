//! Admission-driven prefetch: queued admissions warm the cache.
//!
//! The scheduler announces every enqueued request through its
//! [`crate::sched::PrefetchSink`] (see
//! [`crate::sched::Scheduler::set_prefetch_sink`]). A queued tenant
//! is *waiting* — that wait is exactly the window in which the AOT
//! compile of their artifact is free. The prefetcher maps the hint's
//! tenant to the last core that tenant named (recorded by the program
//! / compile RPC paths) and fires a best-effort [`CompileService`]
//! submit for it on the hinted board's part.
//!
//! Deliberately heuristic: a wrong guess costs one coalescable
//! background compile on the private build clock; a right guess turns
//! the tenant's cold program into a warm one.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::compile::{CompileService, CompileTicket};
use crate::fpga::board::BoardSpec;
use crate::metrics::Registry;
use crate::sched::PrefetchHint;
use crate::util::ids::UserId;

/// The prefetcher. Cheap enough to run under scheduler locks (one map
/// lookup + an async job submit) — the contract the sink requires.
#[derive(Debug)]
pub struct Prefetcher {
    compile: Arc<CompileService>,
    /// Tenant → last core name that tenant asked for.
    last_core: Mutex<BTreeMap<UserId, String>>,
    metrics: Arc<Registry>,
}

impl Prefetcher {
    pub fn new(
        compile: Arc<CompileService>,
        metrics: Arc<Registry>,
    ) -> Prefetcher {
        Prefetcher {
            compile,
            last_core: Mutex::new(BTreeMap::new()),
            metrics,
        }
    }

    /// Record that `tenant` asked for `core` (program or compile
    /// RPC). Future queue waits prefetch this core.
    pub fn note_core(&self, tenant: UserId, core: &str) {
        self.last_core
            .lock()
            .unwrap()
            .insert(tenant, core.to_string());
    }

    /// React to one queued admission: best-effort compile of the
    /// tenant's last-named core for the hinted board. Returns the
    /// ticket when a prediction existed and the submit was accepted
    /// (`None` = nothing known about this tenant yet).
    pub fn hint(&self, hint: &PrefetchHint) -> Option<CompileTicket> {
        let core =
            self.last_core.lock().unwrap().get(&hint.tenant).cloned()?;
        let board = hint
            .board
            .map(BoardSpec::of)
            .unwrap_or_else(BoardSpec::vc707);
        self.metrics.counter("bitcache.prefetch").inc();
        match self.compile.submit(&core, &board.part) {
            Ok(ticket) => Some(ticket),
            Err(_) => None, // wrong guesses never surface to tenants
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitcache::store::BitstreamCache;
    use crate::fpga::board::BoardKind;
    use crate::middleware::jobs::JobRegistry;
    use std::time::Duration;

    fn fixture() -> (Prefetcher, Arc<JobRegistry>, Arc<Registry>) {
        let metrics = Arc::new(Registry::new());
        let cache = Arc::new(BitstreamCache::open(
            8,
            None,
            Arc::clone(&metrics),
        ));
        let jobs = JobRegistry::new();
        let compile = Arc::new(CompileService::new(
            Arc::clone(&jobs),
            cache,
            Arc::clone(&metrics),
        ));
        (
            Prefetcher::new(compile, Arc::clone(&metrics)),
            jobs,
            metrics,
        )
    }

    #[test]
    fn unknown_tenant_is_a_silent_no_op() {
        let (pf, _jobs, metrics) = fixture();
        let hint = PrefetchHint {
            tenant: UserId(1),
            board: None,
            regions: 1,
        };
        assert!(pf.hint(&hint).is_none());
        assert_eq!(metrics.counter("bitcache.prefetch").get(), 0);
    }

    #[test]
    fn known_tenant_warms_the_cache_while_queued() {
        let (pf, jobs, metrics) = fixture();
        let tenant = UserId(7);
        pf.note_core(tenant, "matmul16");
        let ticket = pf
            .hint(&PrefetchHint {
                tenant,
                board: Some(BoardKind::Vc707),
                regions: 1,
            })
            .unwrap();
        assert_eq!(ticket.state, "submitted");
        jobs.wait(ticket.job.unwrap(), Duration::from_secs(30))
            .unwrap();
        assert!(pf.compile.cache().contains(&ticket.digest));
        assert_eq!(metrics.counter("bitcache.prefetch").get(), 1);
        // A second hint for the same tenant reads straight from cache.
        let again = pf
            .hint(&PrefetchHint {
                tenant,
                board: Some(BoardKind::Vc707),
                regions: 1,
            })
            .unwrap();
        assert_eq!(again.state, "cached");
    }
}
