//! Ahead-of-time compile service fronting the HLS design flow.
//!
//! `compile_submit` turns "I will need core X on part Y" into a cache
//! artifact *before* any lease programs it, so the program path later
//! pays PR only (warm tier). The service rides the async-job
//! machinery ([`crate::middleware::jobs::JobRegistry`]): a submit
//! answers immediately with a job id, and the 23 virtual minutes of
//! synthesis + P&R happen on a worker thread.
//!
//! **Coalescing:** concurrent submits for one digest share a single
//! flow run — the second tenant gets the first tenant's job id back
//! (`bitcache.coalesced`) instead of a duplicate compile. The
//! in-flight table is keyed by the same content digest the cache is,
//! so coalescing falls out of content addressing.
//!
//! **Clocking:** the service owns a private [`VirtualClock`]. The
//! paper runs synthesis on dedicated build servers, not on the
//! management node — a background compile must not advance the
//! RPC-visible clock and distort the Table I latency model.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use super::store::BitstreamCache;
use super::CacheKey;
use crate::fpga::board::BoardSpec;
use crate::fpga::region::{equal_split, RegionShape};
use crate::fpga::resources::Resources;
use crate::hls::flow::{region_window, DesignFlow};
use crate::hls::synth::{CoreKind, CoreSpec, Synthesizer};
use crate::metrics::Registry;
use crate::middleware::api::{ApiError, ErrorCode};
use crate::middleware::jobs::{JobRegistry, ProgressReporter};
use crate::rc2f::Rc2fDesign;
use crate::util::clock::VirtualClock;
use crate::util::ids::{JobId, LeaseToken};
use crate::util::json::Json;

/// What a `compile_submit` / `compile_status` caller gets back.
#[derive(Debug, Clone)]
pub struct CompileTicket {
    /// Content digest of the requested `(core, part, shell)` triple.
    pub digest: String,
    /// `cached` | `submitted` | `coalesced` | `running` | `unknown`.
    pub state: &'static str,
    /// The flow job to `job_wait` on, when one is running.
    pub job: Option<JobId>,
    /// Owner token of that job (subscribes to its progress events).
    pub token: Option<LeaseToken>,
}

#[derive(Debug, Clone, Copy)]
struct Inflight {
    job: JobId,
    token: LeaseToken,
}

/// The AOT compile service.
#[derive(Debug)]
pub struct CompileService {
    jobs: Arc<JobRegistry>,
    cache: Arc<BitstreamCache>,
    /// Private build-server clock (see module docs).
    clock: Arc<VirtualClock>,
    metrics: Arc<Registry>,
    /// Digest → running flow job, for coalescing. Shared with the
    /// worker closures, which clear their entry on completion.
    inflight: Arc<Mutex<BTreeMap<String, Inflight>>>,
}

/// The AOT core library: request name → (HLS kind, artifact batch).
/// Mirrors the server's prebuilt core library so `compile_submit`
/// accepts exactly the names `run` does.
fn core_entry(core: &str) -> Option<(CoreKind, usize)> {
    Some(match core {
        "matmul16" => (CoreKind::MatMul { n: 16 }, 256),
        "matmul16_small" => (CoreKind::MatMul { n: 16 }, 64),
        "matmul32" => (CoreKind::MatMul { n: 32 }, 64),
        "loopback" => (CoreKind::Loopback, 256),
        "saxpy" => (CoreKind::Saxpy, 256),
        "checksum" => (CoreKind::Checksum, 256),
        _ => return None,
    })
}

/// Resolve a part marking to its board (the flow needs bitstream
/// sizing and the PR budget).
fn board_of_part(part: &str) -> Option<BoardSpec> {
    let vc707 = BoardSpec::vc707();
    let ml605 = BoardSpec::ml605();
    if part == vc707.part {
        Some(vc707)
    } else if part == ml605.part {
        Some(ml605)
    } else {
        None
    }
}

/// PR budget of a region spanning `quarters` slots, mirroring the
/// device floorplan: board minus the 4-vFPGA RC2F shell, 20% routing
/// margin, split four ways.
fn region_budget(board: &BoardSpec, quarters: u64) -> Resources {
    let free = board
        .resources
        .minus(Rc2fDesign::new(4).total_resources());
    let budget = Resources::new(
        free.lut * 8 / 10,
        free.ff * 8 / 10,
        free.bram * 8 / 10,
        free.dsp * 8 / 10,
    );
    equal_split(budget, 4).times(quarters)
}

impl CompileService {
    pub fn new(
        jobs: Arc<JobRegistry>,
        cache: Arc<BitstreamCache>,
        metrics: Arc<Registry>,
    ) -> CompileService {
        CompileService {
            jobs,
            cache,
            clock: VirtualClock::new(),
            metrics,
            inflight: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }

    /// The store this service admits into.
    pub fn cache(&self) -> &Arc<BitstreamCache> {
        &self.cache
    }

    /// Request an artifact for `core` on `part`. Returns immediately:
    /// `cached` (nothing to do), `coalesced` (another tenant's flow
    /// run is already building this digest — share its job), or
    /// `submitted` (a fresh flow job was started). Unknown cores and
    /// parts fail synchronously.
    pub fn submit(
        &self,
        core: &str,
        part: &str,
    ) -> Result<CompileTicket, ApiError> {
        let key = CacheKey::new(core, part);
        let digest = key.digest();
        if self.cache.contains(&digest) {
            return Ok(CompileTicket {
                digest,
                state: "cached",
                job: None,
                token: None,
            });
        }
        let (kind, batch) = core_entry(core).ok_or_else(|| {
            ApiError::bad_request(format!(
                "unknown core '{core}' — not in the AOT library"
            ))
        })?;
        if board_of_part(part).is_none() {
            return Err(ApiError::bad_request(format!(
                "unknown part '{part}'"
            )));
        }
        // The inflight lock spans job submission *and* table insert;
        // the worker closure takes the same lock to clear its entry,
        // so it cannot race past us before the entry exists.
        let mut inflight = self.inflight.lock().unwrap();
        // Re-check the cache under the lock: the worker admits its
        // artifact *before* clearing its inflight entry, so a digest
        // absent from the table but present in the cache means the
        // run finished between our first check and here — without
        // this, that window would start a duplicate flow run.
        if self.cache.contains(&digest) {
            return Ok(CompileTicket {
                digest,
                state: "cached",
                job: None,
                token: None,
            });
        }
        if let Some(f) = inflight.get(&digest) {
            self.metrics.counter("bitcache.coalesced").inc();
            return Ok(CompileTicket {
                digest,
                state: "coalesced",
                job: Some(f.job),
                token: Some(f.token),
            });
        }
        let token = LeaseToken::mint();
        let cache = Arc::clone(&self.cache);
        let clock = Arc::clone(&self.clock);
        let metrics = Arc::clone(&self.metrics);
        let table = Arc::clone(&self.inflight);
        let worker_key = key.clone();
        let worker_digest = digest.clone();
        let job = Arc::clone(&self.jobs).submit(
            "compile_submit",
            self.clock.now().0,
            Some(token),
            move |progress| {
                let result = run_flow(
                    &cache,
                    &clock,
                    &metrics,
                    progress,
                    &worker_key,
                    kind,
                    batch,
                );
                table.lock().unwrap().remove(&worker_digest);
                result
            },
        );
        inflight.insert(digest.clone(), Inflight { job, token });
        Ok(CompileTicket {
            digest,
            state: "submitted",
            job: Some(job),
            token: Some(token),
        })
    }

    /// Poll a digest: `cached`, `running` (with the job to wait on),
    /// or `unknown`.
    pub fn status(&self, digest: &str) -> CompileTicket {
        if self.cache.contains(digest) {
            return CompileTicket {
                digest: digest.to_string(),
                state: "cached",
                job: None,
                token: None,
            };
        }
        if let Some(f) = self.inflight.lock().unwrap().get(digest) {
            return CompileTicket {
                digest: digest.to_string(),
                state: "running",
                job: Some(f.job),
                token: Some(f.token),
            };
        }
        CompileTicket {
            digest: digest.to_string(),
            state: "unknown",
            job: None,
            token: None,
        }
    }
}

/// One flow run on the worker thread: synthesize, pick the smallest
/// region shape the core fits, place & route, admit into the cache.
fn run_flow(
    cache: &BitstreamCache,
    clock: &Arc<VirtualClock>,
    metrics: &Registry,
    progress: &ProgressReporter,
    key: &CacheKey,
    kind: CoreKind,
    batch: usize,
) -> Result<Json, ApiError> {
    let board = board_of_part(&key.part).ok_or_else(|| {
        ApiError::internal(format!("part '{}' vanished", key.part))
    })?;
    let spec = CoreSpec::named(kind, &key.part);
    progress.report("synthesis", 0, 10.0);
    let total = Synthesizer::new().synthesize(&spec).total_for(1);
    let quarter = region_budget(&board, 1);
    let (shape, quarters) = if total.fits_in(quarter) {
        (RegionShape::Quarter, 1u64)
    } else {
        (RegionShape::Half, 2u64)
    };
    let flow = DesignFlow::new(Arc::clone(clock));
    let out = flow
        .run(
            &spec,
            shape,
            0,
            batch,
            region_budget(&board, quarters),
        )
        .map_err(|e| {
            ApiError::bad_request(format!("design flow failed: {e}"))
        })?;
    progress.report("place_route", 0, 80.0);
    let digest = cache
        .admit(
            key,
            out.bitstream.clone(),
            region_window(0, quarters as usize),
        )
        .map_err(|e| {
            ApiError::new(ErrorCode::CacheRejected, e.to_string())
        })?;
    metrics.counter("bitcache.compile_runs").inc();
    Ok(Json::obj(vec![
        ("digest", Json::from(digest.as_str())),
        ("core", Json::from(key.core.as_str())),
        ("part", Json::from(key.part.as_str())),
        ("quarters", Json::from(quarters)),
        ("build_ms", Json::from(out.build_time.as_millis_f64())),
        ("bytes", Json::from(out.bitstream.payload.len())),
        ("sha256", Json::from(out.bitstream.sha256.as_str())),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::middleware::jobs::JobState;
    use std::time::Duration;

    fn service() -> (CompileService, Arc<JobRegistry>) {
        let metrics = Arc::new(Registry::new());
        let cache = Arc::new(BitstreamCache::open(
            8,
            None,
            Arc::clone(&metrics),
        ));
        let jobs = JobRegistry::new();
        (
            CompileService::new(Arc::clone(&jobs), cache, metrics),
            jobs,
        )
    }

    fn wait_done(
        jobs: &Arc<JobRegistry>,
        ticket: &CompileTicket,
    ) -> Json {
        let rec = jobs
            .wait(ticket.job.unwrap(), Duration::from_secs(30))
            .unwrap();
        match rec.state {
            JobState::Done(v) => v,
            s => panic!("compile job ended {s:?}"),
        }
    }

    #[test]
    fn unknown_core_and_part_fail_synchronously() {
        let (svc, _) = service();
        assert!(svc.submit("warpdrive", "xc7vx485t").is_err());
        assert!(svc.submit("matmul16", "xcvu9p").is_err());
        assert_eq!(svc.jobs.running(), 0);
    }

    #[test]
    fn cold_submit_runs_the_flow_then_reads_cached() {
        let (svc, jobs) = service();
        let t = svc.submit("matmul16", "xc7vx485t").unwrap();
        assert_eq!(t.state, "submitted");
        let body = wait_done(&jobs, &t);
        assert_eq!(body.get("digest").as_str().unwrap(), t.digest);
        assert!(body.get("build_ms").as_f64().unwrap() > 1000.0);
        assert!(svc.cache.contains(&t.digest));
        // Same request again: no second flow run.
        let again = svc.submit("matmul16", "xc7vx485t").unwrap();
        assert_eq!(again.state, "cached");
        assert_eq!(again.digest, t.digest);
        assert_eq!(
            svc.metrics.counter("bitcache.compile_runs").get(),
            1
        );
        assert_eq!(svc.status(&t.digest).state, "cached");
        assert_eq!(svc.status("no-such-digest").state, "unknown");
    }

    #[test]
    fn oversized_core_is_floorplanned_into_a_half_region() {
        let (svc, jobs) = service();
        // matmul32 (64,711 LUT) exceeds the ~59k quarter budget.
        let t = svc.submit("matmul32", "xc7vx485t").unwrap();
        let body = wait_done(&jobs, &t);
        assert_eq!(body.get("quarters").as_u64(), Some(2));
        let bs = svc.cache.lookup(&t.digest).unwrap();
        assert_eq!(bs.meta.resources.lut, 64_711);
        assert!(region_window(0, 2).contains(bs.meta.frames));
    }

    #[test]
    fn build_time_lands_on_the_private_clock_only() {
        let (svc, jobs) = service();
        let t = svc.submit("saxpy", "xc7vx485t").unwrap();
        wait_done(&jobs, &t);
        // 23 virtual minutes charged to the build-server clock.
        assert!(svc.clock.now().as_secs_f64() >= 23.0 * 60.0);
    }

    #[test]
    fn distinct_batches_get_distinct_digests() {
        let (svc, jobs) = service();
        let a = svc.submit("matmul16", "xc7vx485t").unwrap();
        wait_done(&jobs, &a);
        let b = svc.submit("matmul16_small", "xc7vx485t").unwrap();
        wait_done(&jobs, &b);
        assert_ne!(a.digest, b.digest);
        assert_eq!(svc.cache.len(), 2);
    }
}
