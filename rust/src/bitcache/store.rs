//! Content-addressed bitstream store: bounded, LRU-evicted,
//! CRC-verified at admission, persistent under `--state DIR`.
//!
//! Layout: one JSON file per artifact at
//! `<state>/bitcache/<digest>.json` holding the [`CacheKey`] and the
//! full [`Bitstream::to_transfer_json`] encoding (payload inline as
//! base64). Files are written with [`crate::util::fsx::write_atomic`]
//! so a crash mid-admission never leaves a torn artifact; a reopened
//! cache re-verifies every file's CRC and digest and silently drops
//! anything corrupt — a lost cache entry costs one recompile, a
//! poisoned one would program garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::CacheKey;
use crate::bitstream::{Bitstream, FrameRange};
use crate::metrics::Registry;

/// Typed admission failures (surfaced as the `cache_rejected` RPC
/// error code).
#[derive(Debug, Clone, thiserror::Error, PartialEq, Eq)]
pub enum CacheError {
    #[error("bitstream payload fails CRC verification")]
    BadCrc,
    #[error(
        "claimed frames [{claimed_start},{claimed_end}) escape the \
         target region window [{window_start},{window_end})"
    )]
    FrameEscape {
        claimed_start: u64,
        claimed_end: u64,
        window_start: u64,
        window_end: u64,
    },
}

#[derive(Debug)]
struct Entry {
    key: CacheKey,
    bitstream: Bitstream,
    /// LRU clock value of the last admit/lookup touch.
    last_used: u64,
}

#[derive(Debug, Default)]
struct Inner {
    entries: BTreeMap<String, Entry>,
    tick: u64,
}

/// The store. All methods are `&self`; one mutex guards the map (a
/// handful of entries, microsecond critical sections).
#[derive(Debug)]
pub struct BitstreamCache {
    capacity: usize,
    dir: Option<PathBuf>,
    metrics: Arc<Registry>,
    inner: Mutex<Inner>,
}

impl BitstreamCache {
    /// Open a cache bounded to `capacity` artifacts. With a state
    /// directory the on-disk layout is loaded (corrupt files are
    /// dropped) and every later admission/eviction is mirrored to
    /// disk; without one the cache is memory-only.
    pub fn open(
        capacity: usize,
        state_dir: Option<&Path>,
        metrics: Arc<Registry>,
    ) -> BitstreamCache {
        let dir = state_dir.map(|s| s.join("bitcache"));
        let cache = BitstreamCache {
            capacity: capacity.max(1),
            dir,
            metrics,
            inner: Mutex::new(Inner::default()),
        };
        cache.load();
        cache
    }

    /// Verify and admit one artifact; returns its digest. The frame
    /// window check pins the artifact to the region window it was
    /// compiled for — a bitstream claiming frames outside it is the
    /// tampering case the sanity checker exists for, and it must not
    /// be served from cache to other tenants.
    pub fn admit(
        &self,
        key: &CacheKey,
        bitstream: Bitstream,
        window: FrameRange,
    ) -> Result<String, CacheError> {
        if !bitstream.crc_ok() {
            self.metrics.counter("bitcache.rejected").inc();
            return Err(CacheError::BadCrc);
        }
        if !window.contains(bitstream.meta.frames) {
            self.metrics.counter("bitcache.rejected").inc();
            return Err(CacheError::FrameEscape {
                claimed_start: bitstream.meta.frames.start,
                claimed_end: bitstream.meta.frames.end,
                window_start: window.start,
                window_end: window.end,
            });
        }
        let digest = key.digest();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.entries.insert(
            digest.clone(),
            Entry {
                key: key.clone(),
                bitstream,
                last_used: tick,
            },
        );
        self.persist(&inner, &digest);
        while inner.entries.len() > self.capacity {
            let victim = inner
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(d, _)| d.clone())
                .expect("non-empty over capacity");
            inner.entries.remove(&victim);
            self.unpersist(&victim);
            self.metrics.counter("bitcache.evicted").inc();
        }
        self.metrics.counter("bitcache.admitted").inc();
        self.metrics
            .gauge("bitcache.entries")
            .set(inner.entries.len() as i64);
        Ok(digest)
    }

    /// Fetch by digest, bumping recency. Counts `bitcache.hit` /
    /// `bitcache.miss`.
    pub fn lookup(&self, digest: &str) -> Option<Bitstream> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.entries.get_mut(digest) {
            Some(e) => {
                e.last_used = tick;
                self.metrics.counter("bitcache.hit").inc();
                Some(e.bitstream.clone())
            }
            None => {
                self.metrics.counter("bitcache.miss").inc();
                None
            }
        }
    }

    /// Fetch by core/part under the current shell version.
    pub fn lookup_core(
        &self,
        core: &str,
        part: &str,
    ) -> Option<Bitstream> {
        self.lookup(&CacheKey::new(core, part).digest())
    }

    /// Presence check without touching recency or hit/miss counters.
    pub fn contains(&self, digest: &str) -> bool {
        self.inner.lock().unwrap().entries.contains_key(digest)
    }

    /// Keys of every resident artifact (most-recent last).
    pub fn keys(&self) -> Vec<CacheKey> {
        let inner = self.inner.lock().unwrap();
        let mut entries: Vec<(&u64, &CacheKey)> = inner
            .entries
            .values()
            .map(|e| (&e.last_used, &e.key))
            .collect();
        entries.sort_by_key(|(t, _)| **t);
        entries.into_iter().map(|(_, k)| k.clone()).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // ------------------------------------------------- persistence

    fn artifact_path(dir: &Path, digest: &str) -> PathBuf {
        dir.join(format!("{digest}.json"))
    }

    fn persist(&self, inner: &Inner, digest: &str) {
        let Some(dir) = &self.dir else { return };
        let Some(e) = inner.entries.get(digest) else { return };
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let body = crate::util::json::Json::obj(vec![
            (
                "key",
                crate::util::json::Json::obj(vec![
                    (
                        "core",
                        crate::util::json::Json::from(
                            e.key.core.as_str(),
                        ),
                    ),
                    (
                        "part",
                        crate::util::json::Json::from(
                            e.key.part.as_str(),
                        ),
                    ),
                    (
                        "shell",
                        crate::util::json::Json::from(
                            e.key.shell.as_str(),
                        ),
                    ),
                ]),
            ),
            ("bitstream", e.bitstream.to_transfer_json(true)),
        ]);
        let path = Self::artifact_path(dir, digest);
        if let Err(err) =
            crate::util::fsx::write_atomic(&path, &body.to_string())
        {
            log::warn!("bitcache: persist {digest} failed: {err}");
        }
    }

    fn unpersist(&self, digest: &str) {
        if let Some(dir) = &self.dir {
            let _ =
                std::fs::remove_file(Self::artifact_path(dir, digest));
        }
    }

    /// Load the on-disk layout: every `<digest>.json` whose content
    /// parses, passes CRC and whose key re-hashes to its file name.
    fn load(&self) {
        let Some(dir) = self.dir.clone() else { return };
        let Ok(listing) = std::fs::read_dir(&dir) else { return };
        let mut loaded = 0u64;
        for entry in listing.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(digest) = name.strip_suffix(".json") else {
                continue;
            };
            let Ok(text) = std::fs::read_to_string(entry.path())
            else {
                continue;
            };
            let Ok(body) = crate::util::json::Json::parse(&text)
            else {
                continue;
            };
            let k = body.get("key");
            let (Some(core), Some(part), Some(shell)) = (
                k.get("core").as_str(),
                k.get("part").as_str(),
                k.get("shell").as_str(),
            ) else {
                continue;
            };
            let key = CacheKey {
                core: core.to_string(),
                part: part.to_string(),
                shell: shell.to_string(),
            };
            let Some(bitstream) = Bitstream::from_transfer_json(
                body.get("bitstream"),
                None,
            ) else {
                continue;
            };
            if key.digest() != digest || !bitstream.crc_ok() {
                log::warn!("bitcache: dropping corrupt {name}");
                continue;
            }
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            inner.entries.insert(
                digest.to_string(),
                Entry {
                    key,
                    bitstream,
                    last_used: tick,
                },
            );
            loaded += 1;
        }
        if loaded > 0 {
            self.metrics.counter("bitcache.loaded").add(loaded);
            self.metrics
                .gauge("bitcache.entries")
                .set(self.len() as i64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitstream::BitstreamBuilder;
    use crate::fpga::resources::Resources;
    use crate::hls::flow::region_window;

    fn bs(core: &str, seed: u64) -> Bitstream {
        BitstreamBuilder::partial("xc7vx485t", core)
            .resources(Resources::new(100, 100, 1, 1))
            .frames(region_window(0, 1))
            .payload_seed(seed)
            .build()
    }

    fn cache(cap: usize) -> BitstreamCache {
        BitstreamCache::open(
            cap,
            None,
            Arc::new(Registry::new()),
        )
    }

    #[test]
    fn admit_lookup_roundtrip_counts_hits_and_misses() {
        let c = cache(4);
        let key = CacheKey::new("matmul16", "xc7vx485t");
        let digest = c
            .admit(&key, bs("matmul16", 1), region_window(0, 1))
            .unwrap();
        assert_eq!(digest, key.digest());
        assert_eq!(
            c.lookup(&digest).unwrap().meta.core,
            "matmul16"
        );
        assert!(c.lookup("no-such-digest").is_none());
        assert_eq!(c.metrics.counter("bitcache.hit").get(), 1);
        assert_eq!(c.metrics.counter("bitcache.miss").get(), 1);
    }

    #[test]
    fn admission_rejects_bad_crc_and_frame_escape() {
        let c = cache(4);
        let key = CacheKey::new("evil", "xc7vx485t");
        let mut corrupt = bs("evil", 1);
        corrupt.payload[0] ^= 0xFF;
        assert_eq!(
            c.admit(&key, corrupt, region_window(0, 1)),
            Err(CacheError::BadCrc)
        );
        // Claims slot-1 frames while targeting the slot-0 window.
        let escaping = BitstreamBuilder::partial("xc7vx485t", "evil")
            .resources(Resources::new(1, 1, 1, 1))
            .frames(region_window(1, 1))
            .build();
        assert!(matches!(
            c.admit(&key, escaping, region_window(0, 1)),
            Err(CacheError::FrameEscape { .. })
        ));
        assert!(c.is_empty());
        assert_eq!(c.metrics.counter("bitcache.rejected").get(), 2);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = cache(2);
        let ka = CacheKey::new("a", "p");
        let kb = CacheKey::new("b", "p");
        let kc = CacheKey::new("c", "p");
        c.admit(&ka, bs("a", 1), region_window(0, 1)).unwrap();
        c.admit(&kb, bs("b", 2), region_window(0, 1)).unwrap();
        // Touch `a`, then admit `c`: `b` is the LRU victim.
        assert!(c.lookup(&ka.digest()).is_some());
        c.admit(&kc, bs("c", 3), region_window(0, 1)).unwrap();
        assert_eq!(c.len(), 2);
        assert!(c.contains(&ka.digest()));
        assert!(!c.contains(&kb.digest()));
        assert!(c.contains(&kc.digest()));
        assert_eq!(c.metrics.counter("bitcache.evicted").get(), 1);
    }

    #[test]
    fn persists_across_reopen_and_drops_corrupt_files() {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_bitcache_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let key = CacheKey::new("matmul16", "xc7vx485t");
        {
            let c = BitstreamCache::open(
                4,
                Some(&dir),
                Arc::new(Registry::new()),
            );
            c.admit(&key, bs("matmul16", 7), region_window(0, 1))
                .unwrap();
        }
        // Plant a corrupt sibling: parses, but fails the digest check.
        std::fs::write(
            dir.join("bitcache").join(format!("{:064}.json", 0)),
            "{\"key\":{\"core\":\"x\",\"part\":\"p\",\
             \"shell\":\"s\"}}",
        )
        .unwrap();
        let c = BitstreamCache::open(
            4,
            Some(&dir),
            Arc::new(Registry::new()),
        );
        assert_eq!(c.len(), 1);
        let back = c.lookup(&key.digest()).unwrap();
        assert_eq!(back.meta.core, "matmul16");
        assert!(back.crc_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
