//! Cluster-wide bitstream cache + AOT compile service.
//!
//! Every `program` path used to pay the full HLS flow (23 virtual
//! minutes of synthesis + P&R) plus partial reconfiguration, even
//! when the identical design had just been built for another tenant
//! or was still resident in the target region. This subsystem turns
//! that cost into three latency tiers (see `docs/BITCACHE.md`):
//!
//! * **cold** — nothing cached: the AOT compile service runs the
//!   [`crate::hls::flow::DesignFlow`] once, admits the artifact into
//!   the store, then PR programs it (flow + ~843 ms).
//! * **warm** — the artifact is in the [`store::BitstreamCache`]:
//!   programming skips the flow entirely and pays only PR (~843 ms).
//! * **resident** — the target region still holds exactly this
//!   design (same content sha tracked on
//!   [`crate::fpga::region::RegionDesign`]): the hypervisor skips
//!   reconfiguration too (`bitcache.resident_skip`) and the program
//!   call is virtually free.
//!
//! Artifacts are **content-addressed** by [`CacheKey`] — the
//! `(model, part, shell version)` triple hashed to one digest — so N
//! tenants asking for the same core on the same board share one
//! artifact and one compile ([`compile::CompileService`] coalesces
//! concurrent `compile_submit`s per digest). The store is bounded
//! (LRU eviction), verifies CRC and frame-window containment at
//! admission, and persists under `--state DIR` so a restarted
//! management server comes back warm. Queued admissions prefetch
//! through [`prefetch::Prefetcher`]; federated node daemons fetch
//! missing artifacts from the management cache over
//! `agent.fetch_bitstream` (protocol-4 binary frames).

pub mod compile;
pub mod prefetch;
pub mod store;

pub use compile::{CompileService, CompileTicket};
pub use prefetch::Prefetcher;
pub use store::{BitstreamCache, CacheError};

/// Version of the RC2F static shell the cached partial bitstreams
/// are floorplanned against. Part of every cache key: a shell
/// revision that moves region boundaries invalidates the whole cache
/// by construction, never by flag day.
pub const SHELL_VERSION: &str = "rc2f-2.1";

/// Content-address key of one compiled artifact: the accelerator
/// model (core name), the FPGA part it targets and the shell version
/// it was floorplanned against.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CacheKey {
    pub core: String,
    pub part: String,
    pub shell: String,
}

impl CacheKey {
    /// Key for a core/part pair under the current [`SHELL_VERSION`].
    pub fn new(core: &str, part: &str) -> CacheKey {
        CacheKey {
            core: core.to_string(),
            part: part.to_string(),
            shell: SHELL_VERSION.to_string(),
        }
    }

    /// The content address: sha256 over the canonical triple.
    pub fn digest(&self) -> String {
        crate::util::hash::sha256_hex(
            format!("{}|{}|{}", self.core, self.part, self.shell)
                .as_bytes(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_is_stable_and_discriminates() {
        let a = CacheKey::new("matmul16", "xc7vx485t");
        assert_eq!(a.digest(), a.digest());
        assert_eq!(a.digest().len(), 64);
        assert_ne!(
            a.digest(),
            CacheKey::new("matmul32", "xc7vx485t").digest()
        );
        assert_ne!(
            a.digest(),
            CacheKey::new("matmul16", "xc6vlx240t").digest()
        );
        let other_shell = CacheKey {
            shell: "rc2f-9.9".to_string(),
            ..a.clone()
        };
        assert_ne!(a.digest(), other_shell.digest());
    }
}
