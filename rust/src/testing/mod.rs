//! Test infrastructure built in-tree: a property-testing
//! mini-framework (proptest is unavailable offline), a bench harness
//! (criterion substitute) and failure-injection hooks.

pub mod bench;
pub mod failpoint;
pub mod prop;

pub use bench::{BenchResult, Bencher};
pub use failpoint::{FailPoint, FailPlan};
pub use prop::{forall, Gen, PropError};
