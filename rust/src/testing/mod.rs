//! Test infrastructure built in-tree: a property-testing
//! mini-framework (proptest is unavailable offline), a bench harness
//! (criterion substitute) and failure-injection hooks.

pub mod baseline;
pub mod bench;
pub mod failpoint;
pub mod prop;

pub use bench::{BenchResult, Bencher};
pub use failpoint::{FailPoint, FailPlan};
pub use prop::{forall, Gen, PropError};

/// The standard relocatable matmul16 partial bitfile (synth-report
/// resources) targeting `slot` — the fixture tests and examples use
/// to program a lease so it can stream or be migrated.
pub fn mm16_partial(slot: usize) -> crate::bitstream::Bitstream {
    crate::bitstream::BitstreamBuilder::partial("xc7vx485t", "matmul16")
        .resources(crate::fpga::resources::Resources::new(
            25_298, 41_654, 14, 80,
        ))
        .frames(crate::hls::flow::region_window(slot, 1))
        .artifact("matmul16_b256")
        .build()
}

/// Fill `n` regions with programmed batch-class BAaaS leases for
/// `user` through the scheduler — the standard setup for preemption
/// scenarios (a programmed lease is migratable). The leases are
/// disarmed (kept live server-side via their tokens) and returned as
/// their scheduler grants so callers can inspect placement and
/// release by allocation id. Panics on failure; intended for tests
/// and examples.
pub fn fill_batch_leases(
    sched: &std::sync::Arc<crate::sched::Scheduler>,
    user: crate::util::ids::UserId,
    n: usize,
) -> Vec<crate::sched::SchedGrant> {
    (0..n)
        .map(|_| {
            let lease = sched
                .admit(&crate::sched::AdmissionRequest::new(
                    user,
                    crate::config::ServiceModel::BAaaS,
                    crate::sched::RequestClass::Batch,
                ))
                .expect("batch fill lease");
            // Lease::program retargets the slot-0 bitfile to wherever
            // the lease actually landed.
            lease.program(&mm16_partial(0)).expect("program fill lease");
            let grant =
                sched.grant(lease.alloc()).expect("grant of fresh lease");
            let _token = lease.into_token();
            grant
        })
        .collect()
}

/// Gate for artifact-dependent tests. Returns whether the AOT
/// artifact bundle (`make artifacts`) is present; when it is not,
/// logs an explicit "skipped" line through [`crate::util::logging`]
/// so the skip is visible in test output instead of silently passing.
pub fn artifacts_available(test: &str) -> bool {
    let present =
        crate::runtime::artifact_dir().join("manifest.json").exists();
    if !present {
        crate::util::logging::init();
        log::warn!(
            "{test} skipped: artifacts missing (run `make artifacts`)"
        );
    }
    present
}
