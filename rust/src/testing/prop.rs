//! Property-testing mini-framework (proptest substitute).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs. On failure it *shrinks*: the generator is re-run with a
//! shrink budget that biases sizes/magnitudes down, and the smallest
//! failing case found is reported together with the case seed so the
//! failure replays deterministically.

use crate::util::rng::Rng;

/// A generator: draws a value from randomness at a given size bound.
pub struct Gen<'a, T> {
    f: Box<dyn Fn(&mut Rng, usize) -> T + 'a>,
}

impl<'a, T: std::fmt::Debug + 'a> Gen<'a, T> {
    pub fn new(f: impl Fn(&mut Rng, usize) -> T + 'a) -> Gen<'a, T> {
        Gen { f: Box::new(f) }
    }

    pub fn gen(&self, rng: &mut Rng, size: usize) -> T {
        (self.f)(rng, size)
    }

    /// Map the generated value.
    pub fn map<U: std::fmt::Debug + 'a>(
        self,
        g: impl Fn(T) -> U + 'a,
    ) -> Gen<'a, U> {
        Gen::new(move |rng, size| g(self.gen(rng, size)))
    }
}

/// Common generators.
pub mod gens {
    use super::Gen;

    /// u64 in [0, size].
    pub fn small_u64<'a>() -> Gen<'a, u64> {
        Gen::new(|rng, size| rng.next_below(size as u64 + 1))
    }

    /// u64 in [lo, hi] (size-independent).
    pub fn u64_range<'a>(lo: u64, hi: u64) -> Gen<'a, u64> {
        Gen::new(move |rng, _| rng.range(lo, hi))
    }

    /// Vec of length ≤ size from an element generator function.
    pub fn vec_of<'a, T: std::fmt::Debug + 'a>(
        elem: impl Fn(&mut crate::util::rng::Rng) -> T + 'a,
    ) -> Gen<'a, Vec<T>> {
        Gen::new(move |rng, size| {
            let len = rng.next_below(size as u64 + 1) as usize;
            (0..len).map(|_| elem(rng)).collect()
        })
    }

    /// f64 in [-size, size].
    pub fn f64_sym<'a>() -> Gen<'a, f64> {
        Gen::new(|rng, size| (rng.next_f64() * 2.0 - 1.0) * size as f64)
    }
}

/// A failing property report.
#[derive(Debug)]
pub struct PropError {
    pub case_seed: u64,
    pub shrunk_input: String,
    pub message: String,
}

impl std::fmt::Display for PropError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed (replay seed {}): input = {}, {}",
            self.case_seed, self.shrunk_input, self.message
        )
    }
}

/// Run `prop` over `cases` inputs drawn from `gen`. Shrinks on
/// failure by retrying the failing case seed at smaller sizes.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> Result<(), PropError> {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        // Sizes ramp up so early cases are small by construction.
        let size = 1 + case * 64 / cases.max(1);
        let mut rng = Rng::new(case_seed);
        let input = gen.gen(&mut rng, size);
        if let Err(message) = prop(&input) {
            // Shrink: re-generate at decreasing sizes from the same
            // case seed; keep the smallest size that still fails.
            let mut best = (format!("{input:?}"), message);
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut rng = Rng::new(case_seed);
                let candidate = gen.gen(&mut rng, s);
                if let Err(m) = prop(&candidate) {
                    best = (format!("{candidate:?}"), m);
                } else {
                    break;
                }
            }
            return Err(PropError {
                case_seed,
                shrunk_input: best.0,
                message: best.1,
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let gen = gens::small_u64();
        forall(1, 200, &gen, |&x| {
            if x.checked_add(1).is_some() {
                Ok(())
            } else {
                Err("overflow".into())
            }
        })
        .unwrap();
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let gen = gens::small_u64();
        let err = forall(2, 500, &gen, |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        })
        .unwrap_err();
        // The shrunk input should still fail, and shrinking should
        // have reduced it from the original failing size.
        let v: u64 = err.shrunk_input.parse().unwrap();
        assert!(v >= 10);
        assert!(err.message.contains("too big"));
    }

    #[test]
    fn replay_seed_reproduces() {
        let gen = gens::small_u64();
        let err = forall(3, 500, &gen, |&x| {
            if x % 7 != 3 {
                Ok(())
            } else {
                Err("hit".into())
            }
        })
        .unwrap_err();
        // Replaying the case seed at any size yields deterministic
        // values; just assert the recorded input parses and fails.
        let v: u64 = err.shrunk_input.parse().unwrap();
        assert_eq!(v % 7, 3);
    }

    #[test]
    fn vec_generator_respects_size() {
        let gen = gens::vec_of(|rng| rng.next_below(100));
        let mut rng = Rng::new(4);
        for size in [1usize, 8, 64] {
            let v = gen.gen(&mut rng, size);
            assert!(v.len() <= size);
        }
    }

    #[test]
    fn map_transforms() {
        let gen = gens::small_u64().map(|x| x * 2);
        forall(5, 100, &gen, |&x| {
            if x % 2 == 0 {
                Ok(())
            } else {
                Err("odd".into())
            }
        })
        .unwrap();
    }
}
