//! Machine-readable bench baselines (`BENCH_baseline.json`).
//!
//! Bench binaries append named series of numeric stats to a shared
//! JSON file so performance changes diff as data, not prose. Opt in
//! per run with `BENCH_BASELINE_OUT=<path>`; each bench replaces
//! only the series it owns, so the baseline benches can be run in
//! any order against the same file:
//!
//! ```text
//! BENCH_BASELINE_OUT=BENCH_baseline.json cargo bench --bench hotpath
//! BENCH_BASELINE_OUT=BENCH_baseline.json cargo bench --bench admission_wait
//! BENCH_BASELINE_OUT=BENCH_baseline.json cargo bench --bench event_fanout
//! ```
//!
//! The object keys sort deterministically (`Json::Obj` is a
//! `BTreeMap`), so re-running a bench yields a minimal diff.

use std::path::{Path, PathBuf};

use crate::testing::BenchResult;
use crate::util::json::Json;

/// Bump when the series shape changes incompatibly.
pub const FORMAT: u64 = 1;

/// An accumulating `{ format, series: { name: stats } }` report.
pub struct BaselineReport {
    series: Vec<(String, Json)>,
}

impl Default for BaselineReport {
    fn default() -> Self {
        Self::new()
    }
}

impl BaselineReport {
    pub fn new() -> BaselineReport {
        BaselineReport { series: Vec::new() }
    }

    /// Parse an existing report so this run merges into it; a
    /// missing or unreadable file starts fresh (baselines are
    /// regenerable, never load-bearing).
    pub fn load_or_new(path: &Path) -> BaselineReport {
        let mut report = BaselineReport::new();
        let Ok(text) = std::fs::read_to_string(path) else {
            return report;
        };
        let Ok(root) = Json::parse(&text) else {
            return report;
        };
        if let Some(map) = root.get("series").as_obj() {
            for (k, v) in map {
                report.series.push((k.clone(), v.clone()));
            }
        }
        report
    }

    /// Insert or replace one series.
    pub fn set(&mut self, name: &str, value: Json) {
        match self.series.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v = value,
            None => self.series.push((name.to_string(), value)),
        }
    }

    /// Record a wall-time [`BenchResult`] under `name`.
    pub fn record(&mut self, name: &str, r: &BenchResult) {
        self.set(name, wall_stats(r));
    }

    /// Record a bare scalar (a ratio, a percentage, a latency).
    pub fn record_scalar(&mut self, name: &str, value: f64) {
        self.set(name, Json::from(round3(value)));
    }

    pub fn to_json(&self) -> Json {
        let series = Json::Obj(
            self.series
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        );
        Json::obj(vec![
            ("format", Json::from(FORMAT)),
            ("series", series),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_json().to_pretty())
            .map_err(|e| format!("write {}: {e}", path.display()))
    }
}

/// `{ kind: "wall_us", iters, mean_us, median_us, min_us, max_us }`.
pub fn wall_stats(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("kind", Json::from("wall_us")),
        ("iters", Json::from(r.iterations as u64)),
        ("mean_us", Json::from(round3(r.mean_s * 1e6))),
        ("median_us", Json::from(round3(r.median_s * 1e6))),
        ("min_us", Json::from(round3(r.min_s * 1e6))),
        ("max_us", Json::from(round3(r.max_s * 1e6))),
    ])
}

/// Percent by which `test`'s median is slower than `base`'s
/// (negative when it is faster).
pub fn overhead_pct(base: &BenchResult, test: &BenchResult) -> f64 {
    if base.median_s <= 0.0 {
        return 0.0;
    }
    (test.median_s / base.median_s - 1.0) * 100.0
}

/// The opt-in output path (`BENCH_BASELINE_OUT`), if set.
pub fn out_path() -> Option<PathBuf> {
    std::env::var_os("BENCH_BASELINE_OUT").map(PathBuf::from)
}

fn round3(v: f64) -> f64 {
    (v * 1e3).round() / 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(median_s: f64) -> BenchResult {
        BenchResult {
            name: "x".into(),
            iterations: 10,
            mean_s: median_s,
            median_s,
            min_s: median_s * 0.9,
            max_s: median_s * 1.1,
        }
    }

    #[test]
    fn report_shape() {
        let mut rep = BaselineReport::new();
        rep.record("hotpath.rpc_hello", &result(0.0005));
        rep.record_scalar("hotpath.tracing_overhead_pct", 2.123456);
        let j = rep.to_json();
        assert_eq!(j.get("format").as_u64(), Some(FORMAT));
        let s = j.get("series");
        assert_eq!(
            s.get("hotpath.rpc_hello").get("kind").as_str(),
            Some("wall_us")
        );
        assert_eq!(
            s.get("hotpath.rpc_hello").get("median_us").as_f64(),
            Some(500.0)
        );
        // Scalars are rounded to 3 decimals for diff stability.
        assert_eq!(
            s.get("hotpath.tracing_overhead_pct").as_f64(),
            Some(2.123)
        );
    }

    #[test]
    fn set_replaces_in_place() {
        let mut rep = BaselineReport::new();
        rep.record_scalar("a", 1.0);
        rep.record_scalar("b", 2.0);
        rep.record_scalar("a", 3.0);
        let j = rep.to_json();
        assert_eq!(j.get("series").get("a").as_f64(), Some(3.0));
        assert_eq!(j.get("series").get("b").as_f64(), Some(2.0));
    }

    #[test]
    fn overhead_math() {
        let base = result(0.001);
        let mut t = result(0.00104);
        assert!((overhead_pct(&base, &t) - 4.0).abs() < 1e-9);
        t.median_s = 0.00098;
        assert!(overhead_pct(&base, &t) < 0.0);
    }

    #[test]
    fn save_and_merge_round_trip() {
        let path = std::env::temp_dir().join(format!(
            "rc3e_baseline_{}.json",
            std::process::id()
        ));
        let mut rep = BaselineReport::new();
        rep.record("hotpath.fifo", &result(0.0001));
        rep.save(&path).unwrap();
        // A second bench run merges into the same file.
        let mut rep2 = BaselineReport::load_or_new(&path);
        rep2.record("event_fanout.x16", &result(0.002));
        rep2.save(&path).unwrap();
        let merged = BaselineReport::load_or_new(&path);
        let j = merged.to_json();
        assert!(j.get("series").get("hotpath.fifo").as_obj().is_some());
        assert!(j
            .get("series")
            .get("event_fanout.x16")
            .as_obj()
            .is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_starts_fresh() {
        let rep = BaselineReport::load_or_new(Path::new(
            "/nonexistent/rc3e/baseline.json",
        ));
        assert!(rep.to_json().get("series").as_obj().unwrap().is_empty());
    }
}
