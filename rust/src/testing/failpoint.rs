//! Failure injection.
//!
//! Integration tests flip named fail points on to exercise error
//! paths that are otherwise unreachable in a healthy simulation:
//! node-agent death mid-RPC, bitfile corruption in transit, PR
//! timeouts. Production code queries `FailPlan::should_fail(name)`
//! at the injection site; the default plan never fires, costs one
//! atomic load, and is compiled in (failures must be testable in
//! release builds too).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One named injection site's trigger rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailPoint {
    /// Never fire (default).
    Off,
    /// Fire on every hit.
    Always,
    /// Fire on the nth hit (1-based), once.
    OnHit(u64),
    /// Fire on every hit after the nth.
    AfterHit(u64),
}

/// A process-wide plan mapping site names to triggers.
#[derive(Debug, Default)]
pub struct FailPlan {
    sites: Mutex<BTreeMap<String, (FailPoint, Arc<AtomicU64>)>>,
}

impl FailPlan {
    pub fn new() -> Arc<FailPlan> {
        Arc::new(FailPlan::default())
    }

    /// Arm a fail point.
    pub fn arm(&self, name: &str, point: FailPoint) {
        self.sites.lock().unwrap().insert(
            name.to_string(),
            (point, Arc::new(AtomicU64::new(0))),
        );
    }

    /// Disarm (back to Off).
    pub fn disarm(&self, name: &str) {
        self.sites.lock().unwrap().remove(name);
    }

    /// Called at the injection site: should this hit fail?
    pub fn should_fail(&self, name: &str) -> bool {
        let sites = self.sites.lock().unwrap();
        let Some((point, hits)) = sites.get(name) else {
            return false;
        };
        let hit = hits.fetch_add(1, Ordering::SeqCst) + 1;
        match point {
            FailPoint::Off => false,
            FailPoint::Always => true,
            FailPoint::OnHit(n) => hit == *n,
            FailPoint::AfterHit(n) => hit > *n,
        }
    }

    /// Hits recorded at a site (armed sites only).
    pub fn hits(&self, name: &str) -> u64 {
        self.sites
            .lock()
            .unwrap()
            .get(name)
            .map(|(_, h)| h.load(Ordering::SeqCst))
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_never_fires() {
        let plan = FailPlan::new();
        for _ in 0..10 {
            assert!(!plan.should_fail("anything"));
        }
    }

    #[test]
    fn always_fires_every_time() {
        let plan = FailPlan::new();
        plan.arm("x", FailPoint::Always);
        assert!(plan.should_fail("x"));
        assert!(plan.should_fail("x"));
        assert_eq!(plan.hits("x"), 2);
    }

    #[test]
    fn on_hit_fires_once() {
        let plan = FailPlan::new();
        plan.arm("x", FailPoint::OnHit(3));
        assert!(!plan.should_fail("x"));
        assert!(!plan.should_fail("x"));
        assert!(plan.should_fail("x"));
        assert!(!plan.should_fail("x"));
    }

    #[test]
    fn after_hit_fires_from_then_on() {
        let plan = FailPlan::new();
        plan.arm("x", FailPoint::AfterHit(2));
        assert!(!plan.should_fail("x"));
        assert!(!plan.should_fail("x"));
        assert!(plan.should_fail("x"));
        assert!(plan.should_fail("x"));
    }

    #[test]
    fn disarm_restores_default() {
        let plan = FailPlan::new();
        plan.arm("x", FailPoint::Always);
        assert!(plan.should_fail("x"));
        plan.disarm("x");
        assert!(!plan.should_fail("x"));
    }
}
