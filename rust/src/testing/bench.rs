//! Bench harness (criterion substitute).
//!
//! `cargo bench` binaries use `harness = false` and drive this:
//! warmup iterations, N measured iterations, median/mean/min/max in
//! wall time. Virtual-time measurements are taken by the benches
//! themselves from the [`crate::util::clock::VirtualClock`].

use std::time::Instant;

/// Summary statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>5} iters  mean {:>10.4} ms  median {:>10.4} ms  \
             min {:>10.4} ms  max {:>10.4} ms",
            self.name,
            self.iterations,
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3
        )
    }
}

/// Wall-clock bench runner.
pub struct Bencher {
    warmup: usize,
    iters: usize,
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Bencher {
        assert!(iters >= 1);
        Bencher { warmup, iters }
    }

    /// Quick defaults for heavyweight end-to-end benches.
    pub fn quick() -> Bencher {
        Bencher::new(1, 3)
    }

    /// Defaults for microbenches.
    pub fn standard() -> Bencher {
        Bencher::new(3, 10)
    }

    /// Run `f` and collect stats.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        for _ in 0..self.warmup {
            let _ = f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            let _ = f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iterations: self.iters,
            mean_s: mean,
            median_s: samples[samples.len() / 2],
            min_s: samples[0],
            max_s: *samples.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_ordered_stats() {
        let b = Bencher::new(0, 5);
        let mut n = 0u64;
        let r = b.run("spin", || {
            n += 1;
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert_eq!(n, 5);
        assert_eq!(r.iterations, 5);
        assert!(r.min_s <= r.median_s);
        assert!(r.median_s <= r.max_s);
        assert!(r.mean_s > 0.0);
    }

    #[test]
    fn warmup_not_measured() {
        let b = Bencher::new(2, 1);
        let mut calls = 0;
        let r = b.run("w", || calls += 1);
        assert_eq!(calls, 3); // 2 warmup + 1 measured
        assert_eq!(r.iterations, 1);
    }

    #[test]
    fn line_formats() {
        let r = BenchResult {
            name: "x".into(),
            iterations: 3,
            mean_s: 0.001,
            median_s: 0.001,
            min_s: 0.0009,
            max_s: 0.0011,
        };
        assert!(r.line().contains("3 iters"));
    }
}
