//! Segmented, append-only, length-prefixed record log.
//!
//! The durable substrate under both the event journal
//! ([`super::eventlog`]) and the scheduler write-ahead log
//! ([`super::walsched`]). Records are opaque byte payloads framed as
//!
//! ```text
//! [len: u32 LE] [crc32: u32 LE] [seq: u64 LE] [payload: len bytes]
//! ```
//!
//! where the CRC covers the sequence number and the payload, so a
//! record torn anywhere — header, body, or a bit flip in between —
//! fails verification as a unit. Sequence numbers are minted
//! monotonically (starting at 1) and exposed to callers as
//! **cursors**: a cursor names exactly one committed record, forever.
//!
//! The log is a directory of fixed-size segment files named
//! `seg-<first-seq>.wal`. Appends rotate to a fresh segment once the
//! current one exceeds [`JournalConfig::segment_bytes`]; rotation
//! fsyncs the finished segment and the directory so a crash cannot
//! lose a sealed segment. Retention is bounded two ways: by segment
//! count ([`JournalConfig::max_segments`], oldest dropped first) and
//! explicitly by cursor ([`Journal::retain_from`], used by snapshot
//! compaction — segments whose records are all folded into a durable
//! snapshot are deleted).
//!
//! Replay ([`Journal::replay_from`]) walks the segments in order and
//! **stops cleanly at the first torn record**: a crash mid-append
//! yields exactly the committed prefix, never a partial record and
//! never a panic. Reopening a log with a torn tail truncates the tail
//! so new appends start on a clean boundary.
//!
//! Durability level: appends issue a `write(2)` per record (the data
//! survives a killed *process* in the OS page cache); fsync happens on
//! rotation and on explicit [`Journal::sync`]. See
//! `docs/DURABILITY.md` for why that is the right default on the
//! admission hot path.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::metrics::Registry;
use crate::util::hash::crc32_update;

/// Tuning for one [`Journal`].
#[derive(Debug, Clone)]
pub struct JournalConfig {
    /// Rotate to a new segment once the current one reaches this many
    /// bytes (the last record may run past it; segments are "at
    /// least" this size, never split a record).
    pub segment_bytes: u64,
    /// Keep at most this many segments (0 = unbounded; callers doing
    /// snapshot compaction use [`Journal::retain_from`] instead).
    /// The live (newest) segment is never dropped.
    pub max_segments: usize,
}

impl Default for JournalConfig {
    fn default() -> JournalConfig {
        JournalConfig {
            segment_bytes: 1 << 20,
            max_segments: 0,
        }
    }
}

/// Fixed per-record framing overhead: len + crc + seq.
const RECORD_HEADER: usize = 4 + 4 + 8;

/// Hard cap on one record's payload (a corrupt length field must not
/// allocate gigabytes during replay).
pub const MAX_RECORD: u32 = 16 * 1024 * 1024;

/// A segmented append-only record log rooted at one directory.
#[derive(Debug)]
pub struct Journal {
    dir: PathBuf,
    cfg: JournalConfig,
    inner: Mutex<Writer>,
    metrics: Mutex<Option<(Arc<Registry>, String)>>,
}

#[derive(Debug)]
struct Writer {
    /// Open handle on the live (newest) segment.
    file: File,
    /// First sequence number of the live segment (names the file).
    segment_start: u64,
    /// Bytes written to the live segment so far.
    segment_len: u64,
    /// Next sequence number to mint.
    next_seq: u64,
    /// First-seq of every segment on disk, ascending (last = live).
    segments: Vec<u64>,
}

impl Journal {
    /// Open (or create) the log rooted at `dir`. Scans existing
    /// segments, verifies the newest one and truncates any torn tail
    /// so appends resume on a clean record boundary.
    pub fn open(
        dir: &Path,
        cfg: JournalConfig,
    ) -> std::io::Result<Journal> {
        std::fs::create_dir_all(dir)?;
        let mut segments = scan_segments(dir)?;
        if segments.is_empty() {
            let file = create_segment(dir, 1)?;
            let inner = Writer {
                file,
                segment_start: 1,
                segment_len: 0,
                next_seq: 1,
                segments: vec![1],
            };
            return Ok(Journal {
                dir: dir.to_path_buf(),
                cfg,
                inner: Mutex::new(inner),
                metrics: Mutex::new(None),
            });
        }
        segments.sort_unstable();
        // Verify the newest segment: find the committed prefix and
        // cut the file back to it, so a torn tail from a crash cannot
        // corrupt records appended after reopen.
        let live_start = *segments.last().unwrap();
        let live_path = segment_path(dir, live_start);
        let bytes = std::fs::read(&live_path)?;
        let (valid_len, next_seq) =
            committed_prefix(&bytes, live_start);
        if valid_len < bytes.len() as u64 {
            let f = OpenOptions::new().write(true).open(&live_path)?;
            f.set_len(valid_len)?;
            f.sync_all()?;
        }
        let file = OpenOptions::new().append(true).open(&live_path)?;
        let inner = Writer {
            file,
            segment_start: live_start,
            segment_len: valid_len,
            next_seq,
            segments,
        };
        Ok(Journal {
            dir: dir.to_path_buf(),
            cfg,
            inner: Mutex::new(inner),
            metrics: Mutex::new(None),
        })
    }

    /// Wire a metrics registry; instruments are named
    /// `journal.<label>.*` (append histogram, segment-count gauge,
    /// appended counter).
    pub fn set_metrics(&self, metrics: Arc<Registry>, label: &str) {
        *self.metrics.lock().unwrap() =
            Some((metrics, label.to_string()));
    }

    /// Append one record; returns its cursor (sequence number). The
    /// record is flushed with a `write(2)` before this returns —
    /// durable across a process kill, not across a power cut (see
    /// module docs).
    pub fn append(&self, payload: &[u8]) -> std::io::Result<u64> {
        let t0 = std::time::Instant::now();
        assert!(
            payload.len() as u64 <= MAX_RECORD as u64,
            "journal record of {} bytes exceeds MAX_RECORD",
            payload.len()
        );
        let mut w = self.inner.lock().unwrap();
        if w.segment_len >= self.cfg.segment_bytes {
            self.rotate_locked(&mut w)?;
        }
        let seq = w.next_seq;
        let mut buf =
            Vec::with_capacity(RECORD_HEADER + payload.len());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&record_crc(seq, payload).to_le_bytes());
        buf.extend_from_slice(&seq.to_le_bytes());
        buf.extend_from_slice(payload);
        w.file.write_all(&buf)?;
        w.segment_len += buf.len() as u64;
        w.next_seq = seq + 1;
        let segs = w.segments.len();
        drop(w);
        if let Some((m, label)) = self.metrics.lock().unwrap().as_ref()
        {
            m.histogram(&format!("journal.{label}.append"))
                .record_us(t0.elapsed().as_micros() as u64);
            m.counter(&format!("journal.{label}.appended")).inc();
            m.gauge(&format!("journal.{label}.segments"))
                .set(segs as i64);
        }
        Ok(seq)
    }

    /// The next cursor that will be minted (last committed + 1; 1 on
    /// an empty log).
    pub fn next_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Segments currently on disk.
    pub fn segment_count(&self) -> usize {
        self.inner.lock().unwrap().segments.len()
    }

    /// fsync the live segment (callers that need power-cut
    /// durability at a boundary, e.g. after folding a snapshot).
    pub fn sync(&self) -> std::io::Result<()> {
        self.inner.lock().unwrap().file.sync_all()
    }

    /// Replay every committed record with `seq >= from`, in order.
    /// Reads run under the writer lock, so the result is a consistent
    /// snapshot — full records only, ending at the last committed
    /// append. Stops cleanly (no error, no partial record) at a torn
    /// tail left by a crashed writer.
    pub fn replay_from(
        &self,
        from: u64,
    ) -> std::io::Result<Vec<(u64, Vec<u8>)>> {
        let w = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (i, &start) in w.segments.iter().enumerate() {
            // Skip segments that end before `from` (the next
            // segment's first seq bounds this one).
            if let Some(&next_start) = w.segments.get(i + 1) {
                if next_start <= from {
                    continue;
                }
            }
            let path = segment_path(&self.dir, start);
            let bytes = std::fs::read(&path)?;
            let mut expected = start;
            let mut off = 0usize;
            while let Some((seq, payload, next_off)) =
                read_record(&bytes, off, expected)
            {
                if seq >= from {
                    out.push((seq, payload));
                }
                expected = seq + 1;
                off = next_off;
            }
        }
        Ok(out)
    }

    /// Drop whole segments whose records all precede `from` (i.e.
    /// every record has `seq < from`) — snapshot compaction. The live
    /// segment is never dropped. Returns the number of segments
    /// removed.
    pub fn retain_from(&self, from: u64) -> std::io::Result<usize> {
        let mut w = self.inner.lock().unwrap();
        let mut removed = 0usize;
        while w.segments.len() > 1 {
            // The oldest segment's records all precede `from` exactly
            // when the *next* segment starts at or below it.
            if w.segments[1] <= from {
                let victim = w.segments.remove(0);
                std::fs::remove_file(segment_path(&self.dir, victim))?;
                removed += 1;
            } else {
                break;
            }
        }
        if removed > 0 {
            sync_dir(&self.dir)?;
            if let Some((m, label)) =
                self.metrics.lock().unwrap().as_ref()
            {
                m.gauge(&format!("journal.{label}.segments"))
                    .set(w.segments.len() as i64);
            }
        }
        Ok(removed)
    }

    /// Seal the live segment (fsync) and start a fresh one named by
    /// the next sequence number; applies count-based retention.
    fn rotate_locked(&self, w: &mut Writer) -> std::io::Result<()> {
        w.file.sync_all()?;
        let start = w.next_seq;
        w.file = create_segment(&self.dir, start)?;
        w.segment_start = start;
        w.segment_len = 0;
        w.segments.push(start);
        if self.cfg.max_segments > 0 {
            while w.segments.len() > self.cfg.max_segments {
                let victim = w.segments.remove(0);
                std::fs::remove_file(segment_path(&self.dir, victim))?;
            }
        }
        sync_dir(&self.dir)?;
        Ok(())
    }
}

/// `dir/seg-<first-seq>.wal`, zero-padded so lexical order equals
/// numeric order.
fn segment_path(dir: &Path, first_seq: u64) -> PathBuf {
    dir.join(format!("seg-{first_seq:020}.wal"))
}

fn create_segment(dir: &Path, first_seq: u64) -> std::io::Result<File> {
    let file = OpenOptions::new()
        .create(true)
        .append(true)
        .open(segment_path(dir, first_seq))?;
    sync_dir(dir)?;
    Ok(file)
}

fn sync_dir(dir: &Path) -> std::io::Result<()> {
    // Durable rename/create on POSIX requires fsyncing the directory.
    File::open(dir)?.sync_all()
}

/// First-seq numbers of every segment file in `dir` (unsorted).
fn scan_segments(dir: &Path) -> std::io::Result<Vec<u64>> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(num) = name
            .strip_prefix("seg-")
            .and_then(|r| r.strip_suffix(".wal"))
        {
            if let Ok(n) = num.parse::<u64>() {
                out.push(n);
            }
        }
    }
    Ok(out)
}

/// Parse one record at `off`; `None` on a torn/corrupt/out-of-order
/// record (replay stops there). Returns (seq, payload, next offset).
fn read_record(
    bytes: &[u8],
    off: usize,
    expected_seq: u64,
) -> Option<(u64, Vec<u8>, usize)> {
    if off + RECORD_HEADER > bytes.len() {
        return None;
    }
    let len = u32::from_le_bytes(
        bytes[off..off + 4].try_into().unwrap(),
    );
    if len > MAX_RECORD {
        return None;
    }
    let crc = u32::from_le_bytes(
        bytes[off + 4..off + 8].try_into().unwrap(),
    );
    let seq = u64::from_le_bytes(
        bytes[off + 8..off + 16].try_into().unwrap(),
    );
    let body_start = off + RECORD_HEADER;
    let body_end = body_start + len as usize;
    if body_end > bytes.len() {
        return None;
    }
    let payload = &bytes[body_start..body_end];
    if record_crc(seq, payload) != crc || seq != expected_seq {
        return None;
    }
    Some((seq, payload.to_vec(), body_end))
}

/// Byte length of the committed record prefix of one segment, plus
/// the sequence number following its last committed record.
fn committed_prefix(bytes: &[u8], first_seq: u64) -> (u64, u64) {
    let mut expected = first_seq;
    let mut off = 0usize;
    while let Some((seq, _, next_off)) =
        read_record(bytes, off, expected)
    {
        expected = seq + 1;
        off = next_off;
    }
    (off as u64, expected)
}

/// CRC over `seq || payload` (shared CRC-32 from [`crate::util::hash`]).
fn record_crc(seq: u64, payload: &[u8]) -> u32 {
    let mut crc = crc32_update(0xFFFF_FFFF, &seq.to_le_bytes());
    crc = crc32_update(crc, payload);
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{forall, Gen};

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_journal_{tag}_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn small_cfg() -> JournalConfig {
        JournalConfig {
            segment_bytes: 256,
            max_segments: 0,
        }
    }

    #[test]
    fn crc32_matches_known_vector() {
        // CRC-32("123456789") = 0xCBF43926 (IEEE check value).
        let crc = !crc32_update(0xFFFF_FFFF, b"123456789");
        assert_eq!(crc, 0xCBF4_3926);
    }

    #[test]
    fn append_replay_roundtrip_across_reopen() {
        let dir = tmp_dir("roundtrip");
        let j = Journal::open(&dir, small_cfg()).unwrap();
        for i in 0..50u64 {
            let seq =
                j.append(format!("rec-{i}").as_bytes()).unwrap();
            assert_eq!(seq, i + 1, "cursors are dense from 1");
        }
        assert!(j.segment_count() > 1, "small segments must rotate");
        drop(j);
        let j = Journal::open(&dir, small_cfg()).unwrap();
        assert_eq!(j.next_seq(), 51);
        let records = j.replay_from(1).unwrap();
        assert_eq!(records.len(), 50);
        for (i, (seq, payload)) in records.iter().enumerate() {
            assert_eq!(*seq, i as u64 + 1);
            assert_eq!(payload, format!("rec-{i}").as_bytes());
        }
        // A mid-log cursor replays exactly the suffix.
        let tail = j.replay_from(40).unwrap();
        assert_eq!(tail.len(), 11);
        assert_eq!(tail[0].0, 40);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_dropped_on_replay_and_reopen() {
        let dir = tmp_dir("torn");
        let j = Journal::open(&dir, JournalConfig::default()).unwrap();
        for i in 0..10u64 {
            j.append(format!("payload-{i}").as_bytes()).unwrap();
        }
        drop(j);
        // Tear the tail: chop 5 bytes off the live segment.
        let seg = scan_segments(&dir).unwrap();
        let path = segment_path(&dir, *seg.iter().max().unwrap());
        let len = std::fs::metadata(&path).unwrap().len();
        OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(len - 5)
            .unwrap();
        let j = Journal::open(&dir, JournalConfig::default()).unwrap();
        // The torn record (seq 10) is gone; the prefix survives.
        assert_eq!(j.next_seq(), 10);
        assert_eq!(j.replay_from(1).unwrap().len(), 9);
        // New appends reuse the torn record's cursor cleanly.
        assert_eq!(j.append(b"after-crash").unwrap(), 10);
        let recs = j.replay_from(1).unwrap();
        assert_eq!(recs.len(), 10);
        assert_eq!(recs[9].1, b"after-crash");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn count_retention_drops_oldest_segments() {
        let dir = tmp_dir("retention");
        let cfg = JournalConfig {
            segment_bytes: 128,
            max_segments: 3,
        };
        let j = Journal::open(&dir, cfg).unwrap();
        for i in 0..200u64 {
            j.append(format!("event-{i}").as_bytes()).unwrap();
        }
        assert!(j.segment_count() <= 3);
        let recs = j.replay_from(1).unwrap();
        // The newest records survive; the replayed prefix is a dense
        // suffix of the full history.
        assert_eq!(recs.last().unwrap().0, 200);
        for pair in recs.windows(2) {
            assert_eq!(pair[1].0, pair[0].0 + 1);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retain_from_compacts_up_to_cursor() {
        let dir = tmp_dir("compact");
        let j = Journal::open(&dir, small_cfg()).unwrap();
        for i in 0..100u64 {
            j.append(format!("wal-{i}").as_bytes()).unwrap();
        }
        let before = j.segment_count();
        assert!(before > 2);
        let removed = j.retain_from(80).unwrap();
        assert!(removed > 0);
        // Everything at/after the cursor is still replayable.
        let recs = j.replay_from(80).unwrap();
        assert_eq!(recs.len(), 21);
        assert_eq!(recs[0].0, 80);
        // The live segment survives even a future cursor.
        j.retain_from(10_000).unwrap();
        assert_eq!(j.segment_count(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The ISSUE's property test: random append/rotate/reopen
    /// sequences with a truncated-tail corruption step must replay
    /// exactly the committed prefix — never a panic, never a partial
    /// or reordered record.
    #[test]
    fn prop_replay_yields_exactly_the_committed_prefix() {
        // Each case: a script of (op, arg) pairs driven from the
        // generated seed vector.
        let script = Gen::new(|rng, size| {
            let n = 3 + (rng.next_u64() as usize % (size.max(4)));
            (0..n)
                .map(|_| (rng.next_u64() % 10, rng.next_u64()))
                .collect::<Vec<(u64, u64)>>()
        });
        forall(0xD0C5, 60, &script, |ops| {
            let dir = tmp_dir("prop");
            let cfg = JournalConfig {
                segment_bytes: 96,
                max_segments: 0,
            };
            let mut j = Journal::open(&dir, cfg.clone()).unwrap();
            // Committed payloads by cursor, in order.
            let mut committed: Vec<(u64, Vec<u8>)> = Vec::new();
            for &(op, arg) in ops {
                match op {
                    // Mostly appends (sizes 0..64 bytes).
                    0..=6 => {
                        let len = (arg % 64) as usize;
                        let payload: Vec<u8> = (0..len)
                            .map(|k| (arg.wrapping_add(k as u64)) as u8)
                            .collect();
                        let seq = j.append(&payload).unwrap();
                        committed.push((seq, payload));
                    }
                    // Reopen (clean).
                    7 => {
                        drop(j);
                        j = Journal::open(&dir, cfg.clone()).unwrap();
                    }
                    // Crash: truncate the live segment's tail by a
                    // random byte count, then reopen. Whole torn-off
                    // records are uncommitted; the prefix survives.
                    8 => {
                        drop(j);
                        let segs = scan_segments(&dir).unwrap();
                        let live = *segs.iter().max().unwrap();
                        let path = segment_path(&dir, live);
                        let len =
                            std::fs::metadata(&path).unwrap().len();
                        let cut = arg % (len + 1);
                        OpenOptions::new()
                            .write(true)
                            .open(&path)
                            .unwrap()
                            .set_len(len - cut)
                            .unwrap();
                        j = Journal::open(&dir, cfg.clone()).unwrap();
                        // Drop committed entries the tear destroyed.
                        let next = j.next_seq();
                        committed.retain(|(s, _)| *s < next);
                    }
                    // Corrupt a byte in the live segment, then
                    // reopen: the flipped record and everything after
                    // it is uncommitted.
                    _ => {
                        drop(j);
                        let segs = scan_segments(&dir).unwrap();
                        let live = *segs.iter().max().unwrap();
                        let path = segment_path(&dir, live);
                        let mut bytes = std::fs::read(&path).unwrap();
                        if !bytes.is_empty() {
                            let idx = (arg as usize) % bytes.len();
                            bytes[idx] ^= 0x5A;
                            std::fs::write(&path, &bytes).unwrap();
                        }
                        j = Journal::open(&dir, cfg.clone()).unwrap();
                        let next = j.next_seq();
                        committed.retain(|(s, _)| *s < next);
                    }
                }
                // Invariant after every op: replay equals the
                // committed prefix exactly.
                let replayed = j.replay_from(1).unwrap();
                if replayed != committed {
                    let _ = std::fs::remove_dir_all(&dir);
                    return Err(format!(
                        "replay diverged: {} committed, {} replayed",
                        committed.len(),
                        replayed.len()
                    ));
                }
            }
            let _ = std::fs::remove_dir_all(&dir);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn concurrent_appends_stay_dense_and_ordered() {
        let dir = tmp_dir("concurrent");
        let j = std::sync::Arc::new(
            Journal::open(&dir, small_cfg()).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let j = std::sync::Arc::clone(&j);
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    j.append(format!("t{t}-{i}").as_bytes()).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let recs = j.replay_from(1).unwrap();
        assert_eq!(recs.len(), 200);
        for (i, (seq, _)) in recs.iter().enumerate() {
            assert_eq!(*seq, i as u64 + 1);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metrics_instruments_register() {
        let dir = tmp_dir("metrics");
        let m = std::sync::Arc::new(Registry::new());
        let j = Journal::open(&dir, small_cfg()).unwrap();
        j.set_metrics(std::sync::Arc::clone(&m), "test");
        for _ in 0..20 {
            j.append(b"x").unwrap();
        }
        assert_eq!(m.counter("journal.test.appended").get(), 20);
        assert_eq!(m.histogram("journal.test.append").count(), 20);
        assert!(m.gauge("journal.test.segments").get() >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
