//! The event journal: durable backing store for the [`EventBus`].
//!
//! Every event published on the bus is appended here *before* fan-out
//! to subscriber queues, so the journal sequence number doubles as the
//! event's **cursor**: the dense, monotonically increasing position
//! that `subscribe` clients quote (`from_cursor`) to resume a dropped
//! stream. Because the append happens first, any event a live
//! subscriber ever saw is on disk, and a resume can replay the gap
//! from the journal and then switch to live delivery with no gaps and
//! no duplicates (`docs/DURABILITY.md`).
//!
//! Each record is a JSON object carrying the delivery [`Scope`]
//! alongside the event, so replay can re-apply the same visibility
//! rules fan-out used (`Public` vs. lease-token vs. tenant scoped):
//!
//! ```text
//! { "scope": "public",                      "event": { ... } }
//! { "scope": "token",  "token": "lt-..",    "event": { ... } }
//! { "scope": "tenant", "tenant": "user-0",  "event": { ... } }
//! ```
//!
//! The journal keeps a bounded window of history (segment-count
//! retention); a `from_cursor` older than the window resumes from the
//! oldest retained record — the client's cursor arithmetic still
//! detects the gap because cursors are dense.
//!
//! [`EventBus`]: crate::middleware::EventBus
//! [`Scope`]: crate::middleware::Scope

use std::path::Path;
use std::sync::Arc;

use crate::journal::log::{Journal, JournalConfig};
use crate::metrics::Registry;
use crate::middleware::api::Event;
use crate::middleware::Scope;
use crate::util::ids::{LeaseToken, UserId};
use crate::util::json::Json;

/// Segment size for the event journal. Events are small (a few
/// hundred bytes) so 256 KiB segments keep rotation frequent enough
/// for retention to matter without syncing constantly.
const EVENT_SEGMENT_BYTES: u64 = 256 * 1024;

/// How many segments of event history to retain. With ~256 KiB
/// segments this bounds the journal at a few MiB while keeping
/// thousands of events available for cursor resume.
const EVENT_MAX_SEGMENTS: usize = 16;

/// Durable, scope-tagged event log with cursor-addressed replay.
pub struct EventJournal {
    log: Journal,
}

impl EventJournal {
    /// Open (or create) the event journal at `dir`.
    pub fn open(dir: &Path) -> std::io::Result<EventJournal> {
        let cfg = JournalConfig {
            segment_bytes: EVENT_SEGMENT_BYTES,
            max_segments: EVENT_MAX_SEGMENTS,
        };
        Ok(EventJournal { log: Journal::open(dir, cfg)? })
    }

    /// Register `journal.events.*` instruments on `metrics`.
    pub fn set_metrics(&self, metrics: Arc<Registry>) {
        self.log.set_metrics(metrics, "events");
    }

    /// Append one event with its delivery scope; returns the cursor
    /// assigned to it.
    pub fn append(&self, event: &Event, scope: Scope) -> std::io::Result<u64> {
        let mut rec = match scope {
            Scope::Public => {
                Json::obj(vec![("scope", Json::from("public"))])
            }
            Scope::Token(token) => Json::obj(vec![
                ("scope", Json::from("token")),
                ("token", Json::from(token.to_string())),
            ]),
            Scope::Tenant(user) => Json::obj(vec![
                ("scope", Json::from("tenant")),
                ("tenant", Json::from(user.to_string())),
            ]),
        };
        rec.set("event", event.to_json());
        self.log.append(rec.to_string().as_bytes())
    }

    /// The cursor the *next* append will receive.
    pub fn next_cursor(&self) -> u64 {
        self.log.next_seq()
    }

    /// Replay every retained record with cursor >= `from`, in cursor
    /// order. Records that fail to parse (foreign-version residue)
    /// are skipped rather than failing the whole replay.
    pub fn replay_from(
        &self,
        from: u64,
    ) -> std::io::Result<Vec<(u64, Event, Scope)>> {
        let raw = self.log.replay_from(from)?;
        let mut out = Vec::with_capacity(raw.len());
        for (cursor, payload) in raw {
            if let Some((event, scope)) = decode(&payload) {
                out.push((cursor, event, scope));
            }
        }
        Ok(out)
    }

    /// Force buffered appends to stable storage.
    pub fn sync(&self) -> std::io::Result<()> {
        self.log.sync()
    }

    /// Number of live segments (exposed for tests and metrics).
    pub fn segment_count(&self) -> usize {
        self.log.segment_count()
    }
}

fn decode(payload: &[u8]) -> Option<(Event, Scope)> {
    let text = std::str::from_utf8(payload).ok()?;
    let json = Json::parse(text).ok()?;
    let scope = match json.get("scope").as_str()? {
        "public" => Scope::Public,
        "token" => {
            Scope::Token(LeaseToken::parse(json.get("token").as_str()?)?)
        }
        "tenant" => {
            Scope::Tenant(UserId::parse(json.get("tenant").as_str()?)?)
        }
        _ => return None,
    };
    let event = Event::from_json(json.get("event")).ok()?;
    Some((event, scope))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::JobId;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_evjournal_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn depth_event(depth: u64) -> Event {
        Event::QueueDepth { depth }
    }

    #[test]
    fn append_assigns_dense_cursors_and_replays_in_order() {
        let dir = tmp_dir("dense");
        let j = EventJournal::open(&dir).unwrap();
        for i in 0..10 {
            let c = j.append(&depth_event(i), Scope::Public).unwrap();
            assert_eq!(c, i + 1);
        }
        let replay = j.replay_from(4).unwrap();
        assert_eq!(replay.len(), 7);
        assert_eq!(replay[0].0, 4);
        assert_eq!(replay.last().unwrap().0, 10);
        for (cursor, event, scope) in &replay {
            assert_eq!(*scope, Scope::Public);
            match event {
                Event::QueueDepth { depth } => {
                    assert_eq!(*depth, cursor - 1)
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scopes_round_trip_through_disk() {
        let dir = tmp_dir("scopes");
        let token = LeaseToken::mint();
        let user = UserId(7);
        {
            let j = EventJournal::open(&dir).unwrap();
            j.append(&depth_event(1), Scope::Public).unwrap();
            j.append(&depth_event(2), Scope::Token(token)).unwrap();
            j.append(&depth_event(3), Scope::Tenant(user)).unwrap();
        }
        // Reopen from disk: cursors and scopes must survive.
        let j = EventJournal::open(&dir).unwrap();
        assert_eq!(j.next_cursor(), 4);
        let replay = j.replay_from(1).unwrap();
        assert_eq!(replay.len(), 3);
        assert_eq!(replay[0].2, Scope::Public);
        assert_eq!(replay[1].2, Scope::Token(token));
        assert_eq!(replay[2].2, Scope::Tenant(user));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn job_progress_payload_survives_replay() {
        let dir = tmp_dir("payload");
        let j = EventJournal::open(&dir).unwrap();
        let ev = Event::JobProgress {
            job: JobId(3),
            method: "stream_mm".into(),
            phase: "running".into(),
            bytes_streamed: 4096,
            pct: 62.5,
            state: "running".into(),
            result: None,
            trace: None,
        };
        let cursor = j.append(&ev, Scope::Public).unwrap();
        let replay = j.replay_from(cursor).unwrap();
        assert_eq!(replay.len(), 1);
        assert_eq!(replay[0].1, ev);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
