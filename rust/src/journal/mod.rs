//! Durability subsystem: the segmented record log and its two
//! clients.
//!
//! RC3E's control plane owns state that outlives any single process —
//! tenant designs stay resident on the devices across a management
//! restart — so the middleware must be able to fail and recover
//! independently of the hardware it manages. This module provides the
//! three layers that make that honest (`docs/DURABILITY.md`):
//!
//! * [`log`] — a segmented, append-only, CRC-checked record log with
//!   monotonic sequence numbers exposed as **cursors**, atomic
//!   segment rotation, bounded retention and a replay that stops
//!   cleanly at a torn tail.
//! * [`eventlog`] — the [`crate::middleware::EventBus`] backing
//!   store: every published event is appended (with its delivery
//!   scope) before fan-out, giving each event a durable cursor that
//!   `subscribe` clients use to resume a dropped stream with no gaps
//!   and no duplicates.
//! * [`walsched`] — the scheduler write-ahead log: admissions,
//!   releases, relocations, queue and quota mutations append
//!   intent/commit records next to the `sched/persist.rs` snapshot;
//!   on boot the snapshot plus the log suffix reconstructs every live
//!   lease so the restarted scheduler **re-adopts** them (tokens
//!   still validate, placements match the hypervisor).

pub mod eventlog;
pub mod log;
pub mod walsched;

pub use eventlog::EventJournal;
pub use log::{Journal, JournalConfig};
pub use walsched::{
    LeaseRecord, MemberRecord, RecoveredLive, SchedWal, WalRecord,
};
