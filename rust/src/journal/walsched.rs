//! The scheduler write-ahead log: durable grant/queue/quota records.
//!
//! Every scheduler mutation that affects durable state appends one
//! [`WalRecord`] here *while still holding the scheduler state lock*,
//! so the log order is exactly the order the mutations were applied
//! in-memory. On boot, `rc3e serve --state DIR` loads the latest
//! snapshot (`sched/persist.rs`) and folds the WAL suffix past the
//! snapshot's `wal_cursor` into it via [`RecoveredLive::apply`]; the
//! result is the set of live leases and queued admissions at the
//! moment of the crash, which the scheduler then **re-adopts**
//! (tokens validate again, placements are re-registered with the
//! hypervisor, queue entries resume waiting).
//!
//! Record taxonomy (JSON payloads, `"type"`-tagged):
//!
//! * `intent` — an admission is about to be attempted. Never paired
//!   with state on replay; it exists so a crash *during* an admission
//!   is diagnosable. Unpaired intents are ignored by recovery.
//! * `grant` — an admission committed: the full lease (token, tenant,
//!   gang members with placements).
//! * `release` / `release_member` — a whole lease or one gang member
//!   was torn down.
//! * `rebind` — a member was migrated to a new target region.
//! * `enqueue` / `dequeue` — an admission entered / permanently left
//!   the wait queue (grant, terminal rejection or cancel).
//! * `quota` — a tenant's quota limits changed.
//!
//! Compaction: every durable snapshot write records the WAL cursor it
//! covers; segments at or below that cursor are dropped with
//! [`SchedWal::retain_from`], bounding replay work to one snapshot
//! plus the live suffix. See `docs/DURABILITY.md`.

use std::path::Path;
use std::sync::Arc;

use crate::config::ServiceModel;
use crate::fpga::board::BoardKind;
use crate::journal::log::{Journal, JournalConfig};
use crate::metrics::Registry;
use crate::sched::{
    GrantTarget, QueueEntry, RequestClass, TenantQuota,
};
use crate::util::ids::{
    AllocationId, FpgaId, LeaseToken, NodeId, TicketId, UserId, VfpgaId,
};
use crate::util::json::Json;

/// Segment size for the scheduler WAL. Grant records are the largest
/// (a few hundred bytes per gang member); 1 MiB segments give
/// compaction useful granularity without constant rotation.
const WAL_SEGMENT_BYTES: u64 = 1024 * 1024;

/// One gang member of a persisted lease: the allocation, where it is
/// placed, and the accounting facts needed to re-adopt it.
///
/// `from_reservation` is deliberately absent: reservations are
/// in-memory claims that do not survive a restart, so recovery
/// re-adopts members with no reservation linkage.
#[derive(Debug, Clone, PartialEq)]
pub struct MemberRecord {
    pub alloc: AllocationId,
    pub target: GrantTarget,
    /// vFPGA-equivalents charged against quota and accounting.
    pub units: u64,
    /// Virtual timestamp of the grant.
    pub started_ns: u64,
    /// Per-unit active power (W) for energy accounting.
    pub charge_w: f64,
    /// Rebind count carried across restarts (preemption-retry
    /// signal).
    pub migrations: u64,
}

/// One live lease as the WAL (and the snapshot) records it.
#[derive(Debug, Clone, PartialEq)]
pub struct LeaseRecord {
    pub token: LeaseToken,
    pub tenant: UserId,
    pub model: ServiceModel,
    pub class: RequestClass,
    pub co_located: bool,
    /// Virtual time the admission spent queued before the grant.
    pub wait_ns: u64,
    pub members: Vec<MemberRecord>,
}

/// One scheduler mutation, as appended to the WAL.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// An admission attempt is starting (forensic only — recovery
    /// ignores intents with no matching `grant`).
    Intent {
        user: UserId,
        model: ServiceModel,
        class: RequestClass,
        regions: u64,
        co_located: bool,
    },
    /// An admission committed.
    Grant(LeaseRecord),
    /// A whole lease was released.
    Release { token: LeaseToken },
    /// One gang member was released (lease may live on).
    ReleaseMember { alloc: AllocationId },
    /// A member was migrated to a new target.
    Rebind {
        alloc: AllocationId,
        vfpga: Option<VfpgaId>,
        fpga: FpgaId,
        node: NodeId,
    },
    /// An admission entered the wait queue.
    Enqueue(QueueEntry),
    /// An admission permanently left the queue (granted, rejected
    /// or cancelled).
    Dequeue { ticket: TicketId },
    /// A tenant's quota limits changed.
    Quota { user: UserId, quota: TenantQuota },
}

/// The live scheduler state a snapshot + WAL-suffix fold produces.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RecoveredLive {
    /// Live leases in grant order.
    pub leases: Vec<LeaseRecord>,
    /// Still-waiting queue entries in enqueue order.
    pub queue: Vec<QueueEntry>,
    /// Quota limits set via the WAL (upserted over the snapshot's).
    pub quotas: Vec<(UserId, TenantQuota)>,
}

impl RecoveredLive {
    /// Fold one WAL record into the recovered state. Application is
    /// idempotent for re-delivered records (a `grant` with a known
    /// token replaces, releases of unknown tokens are no-ops).
    pub fn apply(&mut self, rec: &WalRecord) {
        match rec {
            WalRecord::Intent { .. } => {}
            WalRecord::Grant(lease) => {
                self.leases.retain(|l| l.token != lease.token);
                self.leases.push(lease.clone());
            }
            WalRecord::Release { token } => {
                self.leases.retain(|l| l.token != *token);
            }
            WalRecord::ReleaseMember { alloc } => {
                for lease in &mut self.leases {
                    lease.members.retain(|m| m.alloc != *alloc);
                }
                self.leases.retain(|l| !l.members.is_empty());
            }
            WalRecord::Rebind { alloc, vfpga, fpga, node } => {
                for lease in &mut self.leases {
                    for m in &mut lease.members {
                        if m.alloc == *alloc {
                            m.target = match vfpga {
                                Some(v) => {
                                    GrantTarget::Vfpga(*v, *fpga, *node)
                                }
                                None => {
                                    GrantTarget::Physical(*fpga, *node)
                                }
                            };
                            m.migrations += 1;
                        }
                    }
                }
            }
            WalRecord::Enqueue(entry) => {
                self.queue.retain(|e| e.ticket != entry.ticket);
                self.queue.push(entry.clone());
            }
            WalRecord::Dequeue { ticket } => {
                self.queue.retain(|e| e.ticket != *ticket);
            }
            WalRecord::Quota { user, quota } => {
                match self.quotas.iter_mut().find(|(u, _)| u == user) {
                    Some((_, q)) => *q = *quota,
                    None => self.quotas.push((*user, *quota)),
                }
            }
        }
    }
}

/// Durable, append-only scheduler mutation log.
///
/// Retention is unbounded at the log layer; compaction (snapshot +
/// [`SchedWal::retain_from`]) is what bounds disk usage.
pub struct SchedWal {
    log: Journal,
}

impl SchedWal {
    /// Open (or create) the scheduler WAL at `dir`.
    pub fn open(dir: &Path) -> std::io::Result<SchedWal> {
        let cfg = JournalConfig {
            segment_bytes: WAL_SEGMENT_BYTES,
            max_segments: 0,
        };
        Ok(SchedWal { log: Journal::open(dir, cfg)? })
    }

    /// Register `journal.sched.*` instruments on `metrics`.
    pub fn set_metrics(&self, metrics: Arc<Registry>) {
        self.log.set_metrics(metrics, "sched");
    }

    /// Append one record; returns its WAL cursor.
    pub fn append(&self, rec: &WalRecord) -> std::io::Result<u64> {
        self.log.append(record_to_json(rec).to_string().as_bytes())
    }

    /// The cursor the *next* append will receive.
    pub fn next_cursor(&self) -> u64 {
        self.log.next_seq()
    }

    /// Replay every retained record with cursor >= `from`, in
    /// order. Unparseable records are skipped.
    pub fn replay_from(
        &self,
        from: u64,
    ) -> std::io::Result<Vec<(u64, WalRecord)>> {
        let raw = self.log.replay_from(from)?;
        let mut out = Vec::with_capacity(raw.len());
        for (cursor, payload) in raw {
            let Ok(text) = std::str::from_utf8(&payload) else {
                continue;
            };
            let Ok(json) = Json::parse(text) else { continue };
            if let Some(rec) = record_from_json(&json) {
                out.push((cursor, rec));
            }
        }
        Ok(out)
    }

    /// Drop whole segments made redundant by a snapshot covering
    /// `snapshot_cursor` (the last WAL cursor folded into it).
    pub fn retain_from(
        &self,
        snapshot_cursor: u64,
    ) -> std::io::Result<usize> {
        self.log.retain_from(snapshot_cursor.saturating_add(1))
    }

    /// Force buffered appends to stable storage.
    pub fn sync(&self) -> std::io::Result<()> {
        self.log.sync()
    }

    /// Number of live segments (exposed for tests and metrics).
    pub fn segment_count(&self) -> usize {
        self.log.segment_count()
    }
}

/// Serialize a lease record (shared by the WAL and snapshot v2).
pub fn lease_to_json(lease: &LeaseRecord) -> Json {
    Json::obj(vec![
        ("token", Json::from(lease.token.to_string())),
        ("tenant", Json::from(lease.tenant.to_string())),
        ("model", Json::from(lease.model.name())),
        ("class", Json::from(lease.class.name())),
        ("co_located", Json::from(lease.co_located)),
        ("wait_ns", Json::from(lease.wait_ns)),
        (
            "members",
            Json::Arr(lease.members.iter().map(member_to_json).collect()),
        ),
    ])
}

/// Parse a lease record; `None` on any malformed field.
pub fn lease_from_json(j: &Json) -> Option<LeaseRecord> {
    let members = j
        .get("members")
        .as_arr()?
        .iter()
        .map(member_from_json)
        .collect::<Option<Vec<_>>>()?;
    Some(LeaseRecord {
        token: LeaseToken::parse(j.get("token").as_str()?)?,
        tenant: UserId::parse(j.get("tenant").as_str()?)?,
        model: ServiceModel::parse(j.get("model").as_str()?)?,
        class: RequestClass::parse(j.get("class").as_str()?)?,
        co_located: j.get("co_located").as_bool()?,
        wait_ns: j.get("wait_ns").as_u64()?,
        members,
    })
}

fn member_to_json(m: &MemberRecord) -> Json {
    let mut j = Json::obj(vec![
        ("alloc", Json::from(m.alloc.to_string())),
        ("units", Json::from(m.units)),
        ("started_ns", Json::from(m.started_ns)),
        ("charge_w", Json::from(m.charge_w)),
        ("migrations", Json::from(m.migrations)),
    ]);
    set_target(&mut j, m.target);
    j
}

fn member_from_json(j: &Json) -> Option<MemberRecord> {
    Some(MemberRecord {
        alloc: AllocationId::parse(j.get("alloc").as_str()?)?,
        target: get_target(j)?,
        units: j.get("units").as_u64()?,
        started_ns: j.get("started_ns").as_u64()?,
        charge_w: j.get("charge_w").as_f64()?,
        migrations: j.get("migrations").as_u64()?,
    })
}

fn set_target(j: &mut Json, target: GrantTarget) {
    match target {
        GrantTarget::Vfpga(v, f, n) => {
            j.set("kind", Json::from("vfpga"));
            j.set("vfpga", Json::from(v.to_string()));
            j.set("fpga", Json::from(f.to_string()));
            j.set("node", Json::from(n.to_string()));
        }
        GrantTarget::Physical(f, n) => {
            j.set("kind", Json::from("physical"));
            j.set("fpga", Json::from(f.to_string()));
            j.set("node", Json::from(n.to_string()));
        }
    }
}

fn get_target(j: &Json) -> Option<GrantTarget> {
    let fpga = FpgaId::parse(j.get("fpga").as_str()?)?;
    let node = NodeId::parse(j.get("node").as_str()?)?;
    match j.get("kind").as_str()? {
        "vfpga" => {
            let v = VfpgaId::parse(j.get("vfpga").as_str()?)?;
            Some(GrantTarget::Vfpga(v, fpga, node))
        }
        "physical" => Some(GrantTarget::Physical(fpga, node)),
        _ => None,
    }
}

/// Serialize a queue entry (shared by the WAL and snapshot v2).
pub fn queue_entry_to_json(e: &QueueEntry) -> Json {
    let mut j = Json::obj(vec![
        ("ticket", Json::from(e.ticket.to_string())),
        ("user", Json::from(e.user.to_string())),
        ("model", Json::from(e.model.name())),
        ("class", Json::from(e.class.name())),
        ("regions", Json::from(e.regions)),
        ("co_located", Json::from(e.co_located)),
        ("enqueued_ns", Json::from(e.enqueued_ns)),
        ("seq", Json::from(e.seq)),
        ("skipped", Json::from(e.skipped)),
    ]);
    if let Some(board) = e.board {
        j.set("board", Json::from(board.name()));
    }
    if let Some(deadline) = e.deadline_ns {
        j.set("deadline_ns", Json::from(deadline));
    }
    j
}

/// Parse a queue entry; `None` on any malformed field.
pub fn queue_entry_from_json(j: &Json) -> Option<QueueEntry> {
    let board = match j.get("board").as_str() {
        Some(s) => Some(BoardKind::parse(s)?),
        None => None,
    };
    Some(QueueEntry {
        ticket: TicketId::parse(j.get("ticket").as_str()?)?,
        user: UserId::parse(j.get("user").as_str()?)?,
        model: ServiceModel::parse(j.get("model").as_str()?)?,
        class: RequestClass::parse(j.get("class").as_str()?)?,
        regions: j.get("regions").as_u64()?,
        co_located: j.get("co_located").as_bool()?,
        board,
        deadline_ns: j.get("deadline_ns").as_u64(),
        enqueued_ns: j.get("enqueued_ns").as_u64()?,
        seq: j.get("seq").as_u64()?,
        skipped: j.get("skipped").as_u64()?,
    })
}

fn quota_to_json(user: UserId, q: TenantQuota) -> Json {
    let mut j = Json::obj(vec![
        ("user", Json::from(user.to_string())),
        ("max_concurrent", Json::from(q.max_concurrent)),
        ("weight", Json::from(q.weight)),
    ]);
    if let Some(budget) = q.device_seconds_budget {
        j.set("budget_s", Json::from(budget));
    }
    j
}

fn quota_from_json(j: &Json) -> Option<(UserId, TenantQuota)> {
    Some((
        UserId::parse(j.get("user").as_str()?)?,
        TenantQuota {
            max_concurrent: j.get("max_concurrent").as_u64()?,
            device_seconds_budget: j.get("budget_s").as_f64(),
            weight: j.get("weight").as_u64()?,
        },
    ))
}

fn record_to_json(rec: &WalRecord) -> Json {
    match rec {
        WalRecord::Intent { user, model, class, regions, co_located } => {
            Json::obj(vec![
                ("type", Json::from("intent")),
                ("user", Json::from(user.to_string())),
                ("model", Json::from(model.name())),
                ("class", Json::from(class.name())),
                ("regions", Json::from(*regions)),
                ("co_located", Json::from(*co_located)),
            ])
        }
        WalRecord::Grant(lease) => Json::obj(vec![
            ("type", Json::from("grant")),
            ("lease", lease_to_json(lease)),
        ]),
        WalRecord::Release { token } => Json::obj(vec![
            ("type", Json::from("release")),
            ("token", Json::from(token.to_string())),
        ]),
        WalRecord::ReleaseMember { alloc } => Json::obj(vec![
            ("type", Json::from("release_member")),
            ("alloc", Json::from(alloc.to_string())),
        ]),
        WalRecord::Rebind { alloc, vfpga, fpga, node } => {
            let mut j = Json::obj(vec![
                ("type", Json::from("rebind")),
                ("alloc", Json::from(alloc.to_string())),
                ("fpga", Json::from(fpga.to_string())),
                ("node", Json::from(node.to_string())),
            ]);
            if let Some(v) = vfpga {
                j.set("vfpga", Json::from(v.to_string()));
            }
            j
        }
        WalRecord::Enqueue(entry) => Json::obj(vec![
            ("type", Json::from("enqueue")),
            ("entry", queue_entry_to_json(entry)),
        ]),
        WalRecord::Dequeue { ticket } => Json::obj(vec![
            ("type", Json::from("dequeue")),
            ("ticket", Json::from(ticket.to_string())),
        ]),
        WalRecord::Quota { user, quota } => {
            let mut j = quota_to_json(*user, *quota);
            j.set("type", Json::from("quota"));
            j
        }
    }
}

fn record_from_json(j: &Json) -> Option<WalRecord> {
    match j.get("type").as_str()? {
        "intent" => Some(WalRecord::Intent {
            user: UserId::parse(j.get("user").as_str()?)?,
            model: ServiceModel::parse(j.get("model").as_str()?)?,
            class: RequestClass::parse(j.get("class").as_str()?)?,
            regions: j.get("regions").as_u64()?,
            co_located: j.get("co_located").as_bool()?,
        }),
        "grant" => Some(WalRecord::Grant(lease_from_json(j.get("lease"))?)),
        "release" => Some(WalRecord::Release {
            token: LeaseToken::parse(j.get("token").as_str()?)?,
        }),
        "release_member" => Some(WalRecord::ReleaseMember {
            alloc: AllocationId::parse(j.get("alloc").as_str()?)?,
        }),
        "rebind" => Some(WalRecord::Rebind {
            alloc: AllocationId::parse(j.get("alloc").as_str()?)?,
            vfpga: match j.get("vfpga").as_str() {
                Some(s) => Some(VfpgaId::parse(s)?),
                None => None,
            },
            fpga: FpgaId::parse(j.get("fpga").as_str()?)?,
            node: NodeId::parse(j.get("node").as_str()?)?,
        }),
        "enqueue" => Some(WalRecord::Enqueue(queue_entry_from_json(
            j.get("entry"),
        )?)),
        "dequeue" => Some(WalRecord::Dequeue {
            ticket: TicketId::parse(j.get("ticket").as_str()?)?,
        }),
        "quota" => {
            let (user, quota) = quota_from_json(j)?;
            Some(WalRecord::Quota { user, quota })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "rc3e_walsched_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn lease(token_bits: u128, allocs: &[u64]) -> LeaseRecord {
        LeaseRecord {
            token: LeaseToken(token_bits),
            tenant: UserId(1),
            model: ServiceModel::RAaaS,
            class: RequestClass::Normal,
            co_located: allocs.len() > 1,
            wait_ns: 1_500_000,
            members: allocs
                .iter()
                .map(|&a| MemberRecord {
                    alloc: AllocationId(a),
                    target: GrantTarget::Vfpga(
                        VfpgaId(a * 10),
                        FpgaId(2),
                        NodeId(0),
                    ),
                    units: 1,
                    started_ns: 42,
                    charge_w: 4.5,
                    migrations: 0,
                })
                .collect(),
        }
    }

    fn entry(ticket: u64) -> QueueEntry {
        QueueEntry {
            ticket: TicketId(ticket),
            user: UserId(2),
            model: ServiceModel::RAaaS,
            class: RequestClass::Batch,
            regions: 2,
            co_located: true,
            board: Some(BoardKind::Vc707),
            deadline_ns: Some(9_000_000_000),
            enqueued_ns: 77,
            seq: ticket,
            skipped: 3,
        }
    }

    #[test]
    fn every_record_type_round_trips() {
        let records = vec![
            WalRecord::Intent {
                user: UserId(4),
                model: ServiceModel::RSaaS,
                class: RequestClass::Interactive,
                regions: 1,
                co_located: false,
            },
            WalRecord::Grant(lease(0xABCD, &[7, 8])),
            WalRecord::Release { token: LeaseToken(0xABCD) },
            WalRecord::ReleaseMember { alloc: AllocationId(8) },
            WalRecord::Rebind {
                alloc: AllocationId(7),
                vfpga: Some(VfpgaId(3)),
                fpga: FpgaId(1),
                node: NodeId(1),
            },
            WalRecord::Rebind {
                alloc: AllocationId(9),
                vfpga: None,
                fpga: FpgaId(5),
                node: NodeId(2),
            },
            WalRecord::Enqueue(entry(11)),
            WalRecord::Dequeue { ticket: TicketId(11) },
            WalRecord::Quota {
                user: UserId(2),
                quota: TenantQuota {
                    max_concurrent: 3,
                    device_seconds_budget: Some(120.5),
                    weight: 2,
                },
            },
        ];
        for rec in &records {
            let json = record_to_json(rec);
            let parsed =
                Json::parse(&json.to_string()).expect("wire form parses");
            assert_eq!(
                record_from_json(&parsed).as_ref(),
                Some(rec),
                "round trip of {rec:?}"
            );
        }
    }

    #[test]
    fn wal_append_and_replay_across_reopen() {
        let dir = tmp_dir("reopen");
        let granted = lease(0x51, &[1, 2]);
        {
            let wal = SchedWal::open(&dir).unwrap();
            assert_eq!(
                wal.append(&WalRecord::Grant(granted.clone())).unwrap(),
                1
            );
            wal.append(&WalRecord::Enqueue(entry(5))).unwrap();
        }
        let wal = SchedWal::open(&dir).unwrap();
        assert_eq!(wal.next_cursor(), 3);
        let replay = wal.replay_from(1).unwrap();
        assert_eq!(replay.len(), 2);
        assert_eq!(replay[0].1, WalRecord::Grant(granted));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fold_reconstructs_live_state() {
        let mut live = RecoveredLive::default();
        // Two grants, one fully released, one loses a member then
        // migrates the survivor.
        live.apply(&WalRecord::Grant(lease(0xA, &[1, 2])));
        live.apply(&WalRecord::Grant(lease(0xB, &[3])));
        live.apply(&WalRecord::Release { token: LeaseToken(0xB) });
        live.apply(&WalRecord::ReleaseMember { alloc: AllocationId(2) });
        live.apply(&WalRecord::Rebind {
            alloc: AllocationId(1),
            vfpga: Some(VfpgaId(9)),
            fpga: FpgaId(3),
            node: NodeId(1),
        });
        assert_eq!(live.leases.len(), 1);
        let survivor = &live.leases[0];
        assert_eq!(survivor.token, LeaseToken(0xA));
        assert_eq!(survivor.members.len(), 1);
        assert_eq!(
            survivor.members[0].target,
            GrantTarget::Vfpga(VfpgaId(9), FpgaId(3), NodeId(1))
        );
        assert_eq!(survivor.members[0].migrations, 1);
        // Queue: enqueue two, dequeue one.
        live.apply(&WalRecord::Enqueue(entry(1)));
        live.apply(&WalRecord::Enqueue(entry(2)));
        live.apply(&WalRecord::Dequeue { ticket: TicketId(1) });
        assert_eq!(live.queue.len(), 1);
        assert_eq!(live.queue[0].ticket, TicketId(2));
        // Quota upsert.
        let q1 = TenantQuota {
            max_concurrent: 9,
            device_seconds_budget: None,
            weight: 1,
        };
        let q2 = TenantQuota { max_concurrent: 2, ..q1 };
        live.apply(&WalRecord::Quota { user: UserId(2), quota: q1 });
        live.apply(&WalRecord::Quota { user: UserId(2), quota: q2 });
        assert_eq!(live.quotas, vec![(UserId(2), q2)]);
        // A member release that empties a lease drops the lease.
        live.apply(&WalRecord::ReleaseMember { alloc: AllocationId(1) });
        assert!(live.leases.is_empty());
    }

    #[test]
    fn release_of_unknown_lease_is_noop() {
        let mut live = RecoveredLive::default();
        live.apply(&WalRecord::Grant(lease(0xA, &[1])));
        live.apply(&WalRecord::Release { token: LeaseToken(0xFF) });
        live.apply(&WalRecord::Dequeue { ticket: TicketId(99) });
        assert_eq!(live.leases.len(), 1);
    }

    #[test]
    fn compaction_drops_covered_segments() {
        let dir = tmp_dir("compact");
        // Small segments so rotation happens without megabytes of
        // appends; the production path only differs in size.
        let cfg = JournalConfig { segment_bytes: 2048, max_segments: 0 };
        let wal = SchedWal { log: Journal::open(&dir, cfg).unwrap() };
        // Force several rotations with bulky grant records.
        let mut last = 0;
        while wal.segment_count() < 4 {
            last = wal
                .append(&WalRecord::Grant(lease(
                    last as u128 + 1,
                    &[1, 2, 3, 4],
                )))
                .unwrap();
        }
        let before = wal.segment_count();
        wal.retain_from(last).unwrap();
        assert!(wal.segment_count() < before);
        // Replay from past the snapshot cursor still works.
        let replay = wal.replay_from(last + 1).unwrap();
        assert!(replay.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
