//! PCIe link simulation.
//!
//! The paper's host↔FPGA path is a Xillybus PCIe IP core capped at
//! 800 MB/s (Section IV-D2), exposed to the host as one device file
//! per FIFO/memory. This module reproduces that substrate:
//!
//! * [`LinkParams`] — negotiated link state, snapshotted/restored
//!   around full reconfigurations (PCIe hot-plug, Section IV-C);
//! * [`arbiter::BandwidthArbiter`] — the shared-bandwidth fluid model
//!   that produces Table III's 509 → 398 → 198 MB/s per-core
//!   progression when multiple vFPGA streams share one link;
//! * [`devfile`] — the per-FIFO/memory device files with access
//!   rights ("For security reasons the device files are protected by
//!   access rights", Section IV-D2);
//! * [`ring`] — the descriptor-ring DMA data plane: pooled DMA
//!   buffers, scatter-gather descriptors with head/tail indices, and
//!   batched doorbell accounting against the arbiter.

pub mod arbiter;
pub mod devfile;
pub mod ring;

pub use arbiter::{BandwidthArbiter, StreamHandle};
pub use devfile::{DevFileError, DeviceFile, DeviceFileKind, DeviceFileRegistry};
pub use ring::{BufferPool, DescriptorRing, PooledBuf, RingParams};

/// Negotiated PCIe link parameters.
///
/// A full reconfiguration replaces the FPGA's PCIe endpoint, dropping
/// the link; RC3E restores these parameters afterwards so the host
/// does not need a reboot ("the hypervisor implements PCIe
/// hot-plugging by restoration of the PCIe link parameters after
/// reconfiguration").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkParams {
    /// PCIe generation (1..=3 for the paper's era).
    pub gen: u8,
    /// Lane count.
    pub lanes: u8,
    /// Max payload size in bytes.
    pub max_payload: u16,
}

impl LinkParams {
    /// The paper's effective configuration (Xillybus on Gen2 x4).
    pub fn gen2_x4() -> LinkParams {
        LinkParams {
            gen: 2,
            lanes: 4,
            max_payload: 256,
        }
    }

    /// Raw line rate in MB/s (before protocol overhead and the
    /// Xillybus IP cap).
    pub fn line_rate_mbps(self) -> f64 {
        // Gen1: 250 MB/s/lane, Gen2: 500, Gen3: ~985 (128b/130b).
        let per_lane = match self.gen {
            1 => 250.0,
            2 => 500.0,
            _ => 985.0,
        };
        per_lane * self.lanes as f64
    }

    /// Effective application throughput cap: the Xillybus IP core
    /// limit (800 MB/s) or the line rate, whichever is lower.
    pub fn effective_cap_mbps(self) -> f64 {
        self.line_rate_mbps().min(crate::paper::LINK_MBPS)
    }
}

/// The full-duplex link of one FPGA board: PCIe moves host→FPGA and
/// FPGA→host traffic on independent lanes, so each direction gets its
/// own arbiter at the Xillybus cap (this is why Table III's two-core
/// row sits at ~398 MB/s *input-side* per core: the 800 MB/s inbound
/// direction is what saturates).
#[derive(Debug)]
pub struct DeviceLink {
    pub params: LinkParams,
    pub inbound: std::sync::Arc<BandwidthArbiter>,
    pub outbound: std::sync::Arc<BandwidthArbiter>,
}

impl DeviceLink {
    pub fn new(
        clock: std::sync::Arc<crate::util::clock::VirtualClock>,
        params: LinkParams,
    ) -> std::sync::Arc<DeviceLink> {
        let cap = params.effective_cap_mbps();
        std::sync::Arc::new(DeviceLink {
            params,
            inbound: BandwidthArbiter::new(std::sync::Arc::clone(&clock), cap),
            outbound: BandwidthArbiter::new(clock, cap),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_link_directions_independent() {
        let clock = crate::util::clock::VirtualClock::new();
        let link = DeviceLink::new(clock, LinkParams::gen2_x4());
        let _in0 = link.inbound.open_stream();
        let _in1 = link.inbound.open_stream();
        assert_eq!(link.inbound.active_streams(), 2);
        assert_eq!(link.outbound.active_streams(), 0);
        assert_eq!(link.inbound.cap_mbps(), 800.0);
        assert_eq!(link.outbound.cap_mbps(), 800.0);
    }

    #[test]
    fn gen2_x4_caps_at_xillybus_limit() {
        let p = LinkParams::gen2_x4();
        assert_eq!(p.line_rate_mbps(), 2000.0);
        assert_eq!(p.effective_cap_mbps(), 800.0);
    }

    #[test]
    fn narrow_link_caps_below_ip_limit() {
        let p = LinkParams {
            gen: 1,
            lanes: 1,
            max_payload: 128,
        };
        assert_eq!(p.effective_cap_mbps(), 250.0);
    }
}
