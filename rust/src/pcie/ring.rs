//! Descriptor-ring DMA data plane.
//!
//! The paper's Xillybus core moves stream data through per-FIFO DMA
//! engines; real PCIe DMA engines (and the `dbs-pci` device/bus split
//! this module borrows its shape from) work off *descriptor rings*: a
//! fixed array of scatter-gather descriptors indexed by head/tail
//! pointers, with the driver ringing a *doorbell* after posting a
//! batch so the device fetches many descriptors per PCIe round trip.
//!
//! Two pieces live here:
//!
//! * [`BufferPool`] — a pool of fixed-size DMA slots. Producers fill
//!   a [`PooledBuf`] in place and hand it down the pipeline; dropping
//!   the buffer returns the slot to the pool, so the steady-state
//!   stream loop performs **zero heap allocations** per chunk
//!   (asserted in `rc2f::stream` tests).
//! * [`DescriptorRing`] — head/tail descriptor accounting over the
//!   ring, scatter-gather splitting of logical chunks across slots,
//!   and *batched doorbell* time accounting against the shared
//!   [`BandwidthArbiter`]: the per-transfer protocol overhead
//!   ([`arbiter::PER_TRANSFER_OVERHEAD_US`](crate::pcie::arbiter::PER_TRANSFER_OVERHEAD_US))
//!   is amortised across `doorbell_batch` descriptors instead of
//!   being paid per chunk.
//!
//! The ring does not move bytes itself — payloads travel through
//! [`crate::fifo::AsyncFifo`] as pooled chunks — it models the
//! *device-side* descriptor flow and produces the virtual-time charge
//! for each chunk's link crossing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::pcie::arbiter::{BandwidthArbiter, PER_TRANSFER_OVERHEAD_US};
use crate::util::clock::VirtualTime;

/// First descriptor of a scatter-gather span (start-of-frame).
pub const DESC_SOF: u8 = 0b0000_0001;
/// Last descriptor of a scatter-gather span (end-of-frame).
pub const DESC_EOF: u8 = 0b0000_0010;

/// Errors from descriptor-ring operations.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
pub enum RingError {
    /// Not enough free descriptor slots for the chunk.
    #[error("ring full: chunk needs {need} descriptors, {free} free")]
    Full { need: usize, free: usize },
    /// The chunk can never fit, even in an empty ring.
    #[error("chunk of {bytes} bytes exceeds ring span of {max} bytes")]
    TooLarge { bytes: u64, max: u64 },
}

// ======================================================= buffer pool

struct PoolInner {
    /// Recycled slots ready for reuse.
    free: Vec<Box<[u8]>>,
    /// Slots in existence (free + in flight); bounded by `cap_slots`.
    created: usize,
}

/// A bounded pool of fixed-size DMA buffers.
///
/// `acquire` hands out a slot, allocating only until `cap_slots`
/// slots exist; after warm-up every acquire reuses a recycled slot
/// and the pool allocates nothing. When all slots are in flight,
/// `acquire` blocks until one is dropped — this is the data plane's
/// second backpressure layer next to the FIFO byte budget.
#[derive(Debug)]
pub struct BufferPool {
    name: String,
    slot_bytes: usize,
    cap_slots: usize,
    inner: Mutex<PoolInner>,
    freed: Condvar,
    created_total: AtomicU64,
    reused_total: AtomicU64,
}

impl std::fmt::Debug for PoolInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PoolInner")
            .field("free", &self.free.len())
            .field("created", &self.created)
            .finish()
    }
}

impl BufferPool {
    /// A pool of `cap_slots` slots of `slot_bytes` each.
    pub fn new(name: &str, slot_bytes: usize, cap_slots: usize) -> Arc<BufferPool> {
        assert!(slot_bytes > 0, "pool slot size must be non-zero");
        assert!(cap_slots > 0, "pool must hold at least one slot");
        Arc::new(BufferPool {
            name: name.to_string(),
            slot_bytes,
            cap_slots,
            inner: Mutex::new(PoolInner {
                free: Vec::with_capacity(cap_slots),
                created: 0,
            }),
            freed: Condvar::new(),
            created_total: AtomicU64::new(0),
            reused_total: AtomicU64::new(0),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Size of every slot in bytes.
    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    /// Slots ever allocated; stops growing once the pool is warm.
    pub fn created_total(&self) -> u64 {
        self.created_total.load(Ordering::SeqCst)
    }

    /// Acquires that reused a recycled slot (no allocation).
    pub fn reused_total(&self) -> u64 {
        self.reused_total.load(Ordering::SeqCst)
    }

    /// Take a slot, blocking while all slots are in flight. The
    /// returned buffer starts with length 0; fill via
    /// [`PooledBuf::slot_mut`] + [`PooledBuf::set_len`].
    pub fn acquire(self: &Arc<Self>) -> PooledBuf {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(slot) = inner.free.pop() {
                self.reused_total.fetch_add(1, Ordering::SeqCst);
                return PooledBuf {
                    slot: Some(slot),
                    len: 0,
                    pool: Arc::clone(self),
                };
            }
            if inner.created < self.cap_slots {
                inner.created += 1;
                self.created_total.fetch_add(1, Ordering::SeqCst);
                drop(inner);
                let slot = vec![0u8; self.slot_bytes].into_boxed_slice();
                return PooledBuf {
                    slot: Some(slot),
                    len: 0,
                    pool: Arc::clone(self),
                };
            }
            inner = self.freed.wait(inner).unwrap();
        }
    }

    /// Non-blocking acquire; `None` when every slot is in flight.
    pub fn try_acquire(self: &Arc<Self>) -> Option<PooledBuf> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.free.pop() {
            self.reused_total.fetch_add(1, Ordering::SeqCst);
            return Some(PooledBuf {
                slot: Some(slot),
                len: 0,
                pool: Arc::clone(self),
            });
        }
        if inner.created < self.cap_slots {
            inner.created += 1;
            self.created_total.fetch_add(1, Ordering::SeqCst);
            drop(inner);
            return Some(PooledBuf {
                slot: Some(vec![0u8; self.slot_bytes].into_boxed_slice()),
                len: 0,
                pool: Arc::clone(self),
            });
        }
        None
    }

    fn release(&self, slot: Box<[u8]>) {
        if let Ok(mut inner) = self.inner.lock() {
            inner.free.push(slot);
            self.freed.notify_one();
        }
    }
}

/// A pool slot checked out for one chunk's lifetime.
///
/// Owns the slot exclusively while in flight; the `Arc` back to the
/// pool is the reference count that returns the slot on drop, so a
/// buffer can be moved freely across the producer → FIFO → core →
/// FIFO → consumer pipeline without copying. Derefs to the *valid
/// prefix* (`0..len`), not the whole slot.
#[derive(Debug)]
pub struct PooledBuf {
    slot: Option<Box<[u8]>>,
    len: usize,
    pool: Arc<BufferPool>,
}

impl PooledBuf {
    /// Valid payload length.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Full slot capacity.
    pub fn capacity(&self) -> usize {
        self.pool.slot_bytes
    }

    /// The whole slot, for filling in place.
    pub fn slot_mut(&mut self) -> &mut [u8] {
        self.slot.as_mut().expect("slot present until drop")
    }

    /// Declare the valid payload prefix after filling.
    ///
    /// # Panics
    /// If `len` exceeds the slot capacity.
    pub fn set_len(&mut self, len: usize) {
        assert!(
            len <= self.pool.slot_bytes,
            "set_len {len} exceeds slot capacity {}",
            self.pool.slot_bytes
        );
        self.len = len;
    }

    /// Copy `src` into the slot start and set the length in one step.
    ///
    /// # Panics
    /// If `src` exceeds the slot capacity.
    pub fn fill_from(&mut self, src: &[u8]) {
        self.slot_mut()[..src.len()].copy_from_slice(src);
        self.len = src.len();
    }
}

impl std::ops::Deref for PooledBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.slot.as_ref().expect("slot present until drop")[..self.len]
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(slot) = self.slot.take() {
            self.pool.release(slot);
        }
    }
}

// ==================================================== descriptor ring

/// One scatter-gather DMA descriptor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Descriptor {
    /// Buffer slot index this descriptor points at.
    pub slot: u32,
    /// Bytes covered by this descriptor.
    pub len: u32,
    /// [`DESC_SOF`] / [`DESC_EOF`] bits.
    pub flags: u8,
}

/// The descriptors one logical chunk occupies (returned by
/// [`DescriptorRing::post`], consumed by [`DescriptorRing::complete`]).
#[derive(Debug, Clone, Copy)]
pub struct SgSpan {
    /// Monotonic sequence number of the first descriptor.
    pub first: u64,
    /// Descriptor count (> 1 means the chunk scatter-gathers).
    pub descs: usize,
    /// Logical chunk bytes.
    pub bytes: u64,
}

/// Ring geometry and doorbell cadence.
#[derive(Debug, Clone, Copy)]
pub struct RingParams {
    /// Descriptor slots in the ring.
    pub slots: usize,
    /// Bytes covered by one descriptor.
    pub slot_bytes: usize,
    /// Descriptors posted per doorbell ring; the per-transfer
    /// protocol overhead is divided by this.
    pub doorbell_batch: usize,
}

impl Default for RingParams {
    fn default() -> RingParams {
        RingParams {
            slots: 64,
            slot_bytes: 64 * 1024,
            doorbell_batch: 8,
        }
    }
}

#[derive(Debug)]
struct RingState {
    /// Next descriptor sequence number to post.
    head: u64,
    /// First not-yet-completed descriptor sequence number.
    tail: u64,
    /// The fixed descriptor array, indexed by `seq % slots`.
    ring: Vec<Descriptor>,
    /// Descriptors posted since the last doorbell.
    since_doorbell: usize,
}

/// Counters snapshot for one ring (see [`DescriptorRing::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingStats {
    pub posted_chunks: u64,
    pub posted_descs: u64,
    pub completed_descs: u64,
    pub doorbells: u64,
    /// Chunks that needed more than one descriptor.
    pub sg_chunks: u64,
    /// Descriptors currently posted but not completed.
    pub occupancy: usize,
}

/// A fixed-slot DMA descriptor ring bound to one direction of the
/// PCIe link.
///
/// `post` writes scatter-gather descriptors at the head, `complete`
/// retires them at the tail, and `charge` converts a chunk's bytes
/// into the fair-share virtual-time cost with the doorbell batch
/// amortising the per-transfer overhead.
#[derive(Debug)]
pub struct DescriptorRing {
    name: String,
    params: RingParams,
    arbiter: Arc<BandwidthArbiter>,
    state: Mutex<RingState>,
    posted_chunks: AtomicU64,
    posted_descs: AtomicU64,
    completed_descs: AtomicU64,
    doorbells: AtomicU64,
    sg_chunks: AtomicU64,
}

impl DescriptorRing {
    pub fn new(
        name: &str,
        arbiter: Arc<BandwidthArbiter>,
        params: RingParams,
    ) -> DescriptorRing {
        assert!(params.slots > 0, "ring needs at least one slot");
        assert!(params.slot_bytes > 0, "ring slot size must be non-zero");
        assert!(params.doorbell_batch > 0, "doorbell batch must be >= 1");
        DescriptorRing {
            name: name.to_string(),
            params,
            arbiter,
            state: Mutex::new(RingState {
                head: 0,
                tail: 0,
                ring: vec![
                    Descriptor {
                        slot: 0,
                        len: 0,
                        flags: 0,
                    };
                    params.slots
                ],
                since_doorbell: 0,
            }),
            posted_chunks: AtomicU64::new(0),
            posted_descs: AtomicU64::new(0),
            completed_descs: AtomicU64::new(0),
            doorbells: AtomicU64::new(0),
            sg_chunks: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn params(&self) -> RingParams {
        self.params
    }

    /// Largest chunk the ring can ever carry.
    pub fn max_chunk_bytes(&self) -> u64 {
        self.params.slots as u64 * self.params.slot_bytes as u64
    }

    /// Post one logical chunk as a scatter-gather descriptor span.
    pub fn post(&self, bytes: u64) -> Result<SgSpan, RingError> {
        let slot_bytes = self.params.slot_bytes as u64;
        let need = bytes.div_ceil(slot_bytes).max(1) as usize;
        if need > self.params.slots {
            return Err(RingError::TooLarge {
                bytes,
                max: self.max_chunk_bytes(),
            });
        }
        let mut state = self.state.lock().unwrap();
        let free = self.params.slots - (state.head - state.tail) as usize;
        if need > free {
            return Err(RingError::Full { need, free });
        }
        let first = state.head;
        let mut remaining = bytes;
        for i in 0..need {
            let seq = first + i as u64;
            let len = remaining.min(slot_bytes);
            remaining -= len;
            let mut flags = 0u8;
            if i == 0 {
                flags |= DESC_SOF;
            }
            if i + 1 == need {
                flags |= DESC_EOF;
            }
            let idx = (seq % self.params.slots as u64) as usize;
            state.ring[idx] = Descriptor {
                slot: idx as u32,
                len: len as u32,
                flags,
            };
        }
        state.head += need as u64;
        state.since_doorbell += need;
        while state.since_doorbell >= self.params.doorbell_batch {
            state.since_doorbell -= self.params.doorbell_batch;
            self.doorbells.fetch_add(1, Ordering::SeqCst);
        }
        self.posted_chunks.fetch_add(1, Ordering::SeqCst);
        self.posted_descs.fetch_add(need as u64, Ordering::SeqCst);
        if need > 1 {
            self.sg_chunks.fetch_add(1, Ordering::SeqCst);
        }
        Ok(SgSpan {
            first,
            descs: need,
            bytes,
        })
    }

    /// Retire a posted span. Spans complete in post order (the device
    /// consumes the ring sequentially).
    ///
    /// # Panics
    /// If spans are completed out of order — a driver bug.
    pub fn complete(&self, span: SgSpan) {
        let mut state = self.state.lock().unwrap();
        assert_eq!(
            span.first, state.tail,
            "descriptor ring '{}' completed out of order",
            self.name
        );
        state.tail += span.descs as u64;
        self.completed_descs
            .fetch_add(span.descs as u64, Ordering::SeqCst);
    }

    /// Ring the doorbell for any partial batch (end of stream, so the
    /// device sees the tail descriptors).
    pub fn flush_doorbell(&self) {
        let mut state = self.state.lock().unwrap();
        if state.since_doorbell > 0 {
            state.since_doorbell = 0;
            self.doorbells.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Fair-share virtual-time cost of moving `bytes` through this
    /// ring's link direction, with the per-transfer overhead
    /// amortised across the doorbell batch. Records the bytes on the
    /// arbiter; the caller folds the duration into its pipeline step
    /// (`max(d_in, d_out, compute)` in the stream runner).
    pub fn charge(&self, bytes: u64, contenders: Option<usize>) -> VirtualTime {
        let overhead_us =
            PER_TRANSFER_OVERHEAD_US / self.params.doorbell_batch as f64;
        let n = contenders.unwrap_or_else(|| self.arbiter.active_streams());
        let d = self
            .arbiter
            .share_duration_with_overhead(bytes, n, overhead_us);
        self.arbiter.note_bytes(bytes);
        d
    }

    /// Descriptor at `seq`, if still posted (tests / introspection).
    pub fn descriptor_at(&self, seq: u64) -> Option<Descriptor> {
        let state = self.state.lock().unwrap();
        if seq < state.tail || seq >= state.head {
            return None;
        }
        Some(state.ring[(seq % self.params.slots as u64) as usize])
    }

    pub fn stats(&self) -> RingStats {
        let state = self.state.lock().unwrap();
        RingStats {
            posted_chunks: self.posted_chunks.load(Ordering::SeqCst),
            posted_descs: self.posted_descs.load(Ordering::SeqCst),
            completed_descs: self.completed_descs.load(Ordering::SeqCst),
            doorbells: self.doorbells.load(Ordering::SeqCst),
            sg_chunks: self.sg_chunks.load(Ordering::SeqCst),
            occupancy: (state.head - state.tail) as usize,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::VirtualClock;

    fn ring(params: RingParams) -> DescriptorRing {
        let clock = VirtualClock::new();
        let arb = BandwidthArbiter::new(clock, 800.0);
        DescriptorRing::new("t", arb, params)
    }

    #[test]
    fn pool_reuses_slots_after_warmup() {
        let pool = BufferPool::new("p", 4096, 2);
        {
            let a = pool.acquire();
            let b = pool.acquire();
            assert_eq!(a.capacity(), 4096);
            assert_eq!(b.capacity(), 4096);
        }
        for _ in 0..10 {
            let buf = pool.acquire();
            drop(buf);
        }
        assert_eq!(pool.created_total(), 2);
        assert_eq!(pool.reused_total(), 10);
    }

    #[test]
    fn pool_blocks_at_cap_until_release() {
        let pool = BufferPool::new("p", 16, 1);
        let held = pool.acquire();
        assert!(pool.try_acquire().is_none());
        drop(held);
        assert!(pool.try_acquire().is_some());
    }

    #[test]
    fn pooled_buf_prefix_semantics() {
        let pool = BufferPool::new("p", 8, 1);
        let mut buf = pool.acquire();
        assert!(buf.is_empty());
        buf.fill_from(&[1, 2, 3]);
        assert_eq!(buf.len(), 3);
        assert_eq!(&buf[..], &[1, 2, 3]);
        buf.set_len(2);
        assert_eq!(&buf[..], &[1, 2]);
    }

    #[test]
    #[should_panic(expected = "exceeds slot capacity")]
    fn pooled_buf_set_len_bounds() {
        let pool = BufferPool::new("p", 8, 1);
        let mut buf = pool.acquire();
        buf.set_len(9);
    }

    #[test]
    fn single_slot_chunk_posts_sof_eof() {
        let r = ring(RingParams::default());
        let span = r.post(1000).unwrap();
        assert_eq!(span.descs, 1);
        let d = r.descriptor_at(span.first).unwrap();
        assert_eq!(d.len, 1000);
        assert_eq!(d.flags, DESC_SOF | DESC_EOF);
        r.complete(span);
        assert_eq!(r.stats().occupancy, 0);
    }

    #[test]
    fn large_chunk_scatter_gathers_across_slots() {
        let r = ring(RingParams {
            slots: 8,
            slot_bytes: 1024,
            doorbell_batch: 4,
        });
        // 2.5 slots -> 3 descriptors: SOF | .. | EOF.
        let span = r.post(2560).unwrap();
        assert_eq!(span.descs, 3);
        assert_eq!(r.descriptor_at(span.first).unwrap().flags, DESC_SOF);
        assert_eq!(r.descriptor_at(span.first + 1).unwrap().flags, 0);
        let last = r.descriptor_at(span.first + 2).unwrap();
        assert_eq!(last.flags, DESC_EOF);
        assert_eq!(last.len, 512);
        assert_eq!(r.stats().sg_chunks, 1);
        r.complete(span);
    }

    #[test]
    fn ring_rejects_when_full_and_recovers() {
        let r = ring(RingParams {
            slots: 4,
            slot_bytes: 1024,
            doorbell_batch: 4,
        });
        let a = r.post(3 * 1024).unwrap();
        let err = r.post(2 * 1024).unwrap_err();
        assert_eq!(err, RingError::Full { need: 2, free: 1 });
        r.complete(a);
        assert!(r.post(2 * 1024).is_ok());
    }

    #[test]
    fn oversized_chunk_rejected() {
        let r = ring(RingParams {
            slots: 4,
            slot_bytes: 1024,
            doorbell_batch: 4,
        });
        let err = r.post(5 * 1024).unwrap_err();
        assert_eq!(
            err,
            RingError::TooLarge {
                bytes: 5 * 1024,
                max: 4 * 1024
            }
        );
    }

    #[test]
    fn doorbells_ring_per_batch_plus_flush() {
        let r = ring(RingParams {
            slots: 64,
            slot_bytes: 1024,
            doorbell_batch: 8,
        });
        // 10 single-descriptor chunks: one doorbell at 8, 2 pending.
        for _ in 0..10 {
            let span = r.post(512).unwrap();
            r.complete(span);
        }
        assert_eq!(r.stats().doorbells, 1);
        r.flush_doorbell();
        assert_eq!(r.stats().doorbells, 2);
        r.flush_doorbell(); // idempotent when nothing pending
        assert_eq!(r.stats().doorbells, 2);
    }

    #[test]
    fn charge_amortises_doorbell_overhead() {
        let clock = VirtualClock::new();
        let arb = BandwidthArbiter::new(clock, 800.0);
        let r = DescriptorRing::new(
            "t",
            Arc::clone(&arb),
            RingParams {
                slots: 64,
                slot_bytes: 64 * 1024,
                doorbell_batch: 8,
            },
        );
        let bytes = 256 * 1024;
        let batched = r.charge(bytes, Some(1)).as_secs_f64();
        let unbatched = arb.share_duration_for(bytes, 1).as_secs_f64();
        let saved = unbatched - batched;
        // 7/8 of the 0.8 us per-transfer overhead disappears.
        assert!((saved - 0.7e-6).abs() < 1e-9, "saved {saved}");
        assert_eq!(arb.bytes_total(), bytes as usize);
    }

    #[test]
    fn wraparound_head_tail_accounting() {
        let r = ring(RingParams {
            slots: 4,
            slot_bytes: 1024,
            doorbell_batch: 2,
        });
        // Push the ring far past one lap.
        for _ in 0..100 {
            let span = r.post(2 * 1024).unwrap();
            r.complete(span);
        }
        let st = r.stats();
        assert_eq!(st.posted_descs, 200);
        assert_eq!(st.completed_descs, 200);
        assert_eq!(st.occupancy, 0);
        assert_eq!(st.doorbells, 100);
    }
}
