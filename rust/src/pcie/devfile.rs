//! Per-FIFO / per-memory device files with access rights.
//!
//! Section IV-D2: "On the host the FPGA is accessible by PCIe drivers
//! which provide separate device files for each FIFO and each memory.
//! ... For security reasons the device files are protected by access
//! rights. Because of this additional virtualization layer concurrent
//! users can interact with their allocated devices without
//! influencing each other."
//!
//! The registry is the host-side namespace: the hypervisor creates
//! the files when a vFPGA is allocated (chowning them to the lease
//! holder) and removes them on release. The RC2F host API opens files
//! through the registry, which enforces ownership.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::ids::{UserId, VfpgaId};

/// What a device file fronts on the FPGA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceFileKind {
    /// Host→FPGA streaming FIFO.
    FifoIn,
    /// FPGA→host streaming FIFO.
    FifoOut,
    /// User configuration space (dual-port memory) of a vFPGA.
    Ucs,
    /// Global configuration space of the RC2F controller.
    Gcs,
}

impl DeviceFileKind {
    pub fn name(self) -> &'static str {
        match self {
            DeviceFileKind::FifoIn => "fifo_in",
            DeviceFileKind::FifoOut => "fifo_out",
            DeviceFileKind::Ucs => "ucs",
            DeviceFileKind::Gcs => "gcs",
        }
    }
}

/// One registered device file.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceFile {
    pub path: String,
    pub kind: DeviceFileKind,
    pub vfpga: Option<VfpgaId>,
    /// Owner; None = root/hypervisor only.
    pub owner: Option<UserId>,
}

/// Access-control errors.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum DevFileError {
    #[error("no such device file: {0}")]
    NotFound(String),
    #[error("permission denied: {path} is owned by {owner:?}")]
    Denied {
        path: String,
        owner: Option<UserId>,
    },
    #[error("device file already exists: {0}")]
    Exists(String),
}

/// Host-side device file namespace for one node.
#[derive(Debug, Default)]
pub struct DeviceFileRegistry {
    files: Mutex<BTreeMap<String, DeviceFile>>,
}

impl DeviceFileRegistry {
    pub fn new() -> DeviceFileRegistry {
        DeviceFileRegistry::default()
    }

    /// Canonical path for a vFPGA-scoped file, mirroring the Xillybus
    /// naming convention (`/dev/xillybus_<name>`).
    pub fn vfpga_path(vfpga: VfpgaId, kind: DeviceFileKind, idx: usize) -> String {
        format!("/dev/xillybus_{}_{}_{}", vfpga, kind.name(), idx)
    }

    /// Create the standard file set for an allocated vFPGA: one FIFO
    /// pair + its ucs, owned by the lease holder.
    pub fn create_vfpga_files(
        &self,
        vfpga: VfpgaId,
        owner: UserId,
    ) -> Result<Vec<String>, DevFileError> {
        let specs = [
            (DeviceFileKind::FifoIn, 0),
            (DeviceFileKind::FifoOut, 0),
            (DeviceFileKind::Ucs, 0),
        ];
        let mut created = Vec::new();
        let mut files = self.files.lock().unwrap();
        for (kind, idx) in specs {
            let path = Self::vfpga_path(vfpga, kind, idx);
            if files.contains_key(&path) {
                return Err(DevFileError::Exists(path));
            }
            files.insert(
                path.clone(),
                DeviceFile {
                    path: path.clone(),
                    kind,
                    vfpga: Some(vfpga),
                    owner: Some(owner),
                },
            );
            created.push(path);
        }
        Ok(created)
    }

    /// Register the node-global gcs file (hypervisor-owned).
    pub fn create_gcs(&self, fpga: crate::util::ids::FpgaId) -> String {
        let path = format!("/dev/xillybus_{fpga}_gcs");
        self.files.lock().unwrap().insert(
            path.clone(),
            DeviceFile {
                path: path.clone(),
                kind: DeviceFileKind::Gcs,
                vfpga: None,
                owner: None,
            },
        );
        path
    }

    /// Open with access check. `user = None` means the hypervisor.
    pub fn open(
        &self,
        path: &str,
        user: Option<UserId>,
    ) -> Result<DeviceFile, DevFileError> {
        let files = self.files.lock().unwrap();
        let f = files
            .get(path)
            .ok_or_else(|| DevFileError::NotFound(path.to_string()))?;
        let allowed = match (f.owner, user) {
            (_, None) => true,               // hypervisor sees all
            (None, Some(_)) => false,        // root-only file
            (Some(o), Some(u)) => o == u,    // owner match
        };
        if !allowed {
            return Err(DevFileError::Denied {
                path: path.to_string(),
                owner: f.owner,
            });
        }
        Ok(f.clone())
    }

    /// Remove all files of a vFPGA (lease release).
    pub fn remove_vfpga_files(&self, vfpga: VfpgaId) -> usize {
        let mut files = self.files.lock().unwrap();
        let before = files.len();
        files.retain(|_, f| f.vfpga != Some(vfpga));
        before - files.len()
    }

    /// Re-own a vFPGA's files (lease transfer / migration).
    pub fn chown_vfpga(&self, vfpga: VfpgaId, new_owner: UserId) -> usize {
        let mut files = self.files.lock().unwrap();
        let mut n = 0;
        for f in files.values_mut() {
            if f.vfpga == Some(vfpga) {
                f.owner = Some(new_owner);
                n += 1;
            }
        }
        n
    }

    /// All paths (diagnostics).
    pub fn paths(&self) -> Vec<String> {
        self.files.lock().unwrap().keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::FpgaId;

    #[test]
    fn create_and_open_as_owner() {
        let reg = DeviceFileRegistry::new();
        let paths = reg.create_vfpga_files(VfpgaId(1), UserId(10)).unwrap();
        assert_eq!(paths.len(), 3);
        for p in &paths {
            let f = reg.open(p, Some(UserId(10))).unwrap();
            assert_eq!(f.vfpga, Some(VfpgaId(1)));
        }
    }

    #[test]
    fn other_user_is_denied() {
        let reg = DeviceFileRegistry::new();
        let paths = reg.create_vfpga_files(VfpgaId(1), UserId(10)).unwrap();
        let err = reg.open(&paths[0], Some(UserId(11))).unwrap_err();
        assert!(matches!(err, DevFileError::Denied { .. }));
    }

    #[test]
    fn hypervisor_sees_everything() {
        let reg = DeviceFileRegistry::new();
        let paths = reg.create_vfpga_files(VfpgaId(2), UserId(1)).unwrap();
        assert!(reg.open(&paths[0], None).is_ok());
        let gcs = reg.create_gcs(FpgaId(0));
        assert!(reg.open(&gcs, None).is_ok());
    }

    #[test]
    fn gcs_is_root_only() {
        let reg = DeviceFileRegistry::new();
        let gcs = reg.create_gcs(FpgaId(0));
        let err = reg.open(&gcs, Some(UserId(5))).unwrap_err();
        assert!(matches!(err, DevFileError::Denied { .. }));
    }

    #[test]
    fn double_create_is_error() {
        let reg = DeviceFileRegistry::new();
        reg.create_vfpga_files(VfpgaId(3), UserId(1)).unwrap();
        let err = reg.create_vfpga_files(VfpgaId(3), UserId(2)).unwrap_err();
        assert!(matches!(err, DevFileError::Exists(_)));
    }

    #[test]
    fn release_removes_files() {
        let reg = DeviceFileRegistry::new();
        let paths = reg.create_vfpga_files(VfpgaId(4), UserId(1)).unwrap();
        assert_eq!(reg.remove_vfpga_files(VfpgaId(4)), 3);
        assert!(matches!(
            reg.open(&paths[0], Some(UserId(1))),
            Err(DevFileError::NotFound(_))
        ));
    }

    #[test]
    fn chown_transfers_access() {
        let reg = DeviceFileRegistry::new();
        let paths = reg.create_vfpga_files(VfpgaId(5), UserId(1)).unwrap();
        assert_eq!(reg.chown_vfpga(VfpgaId(5), UserId(2)), 3);
        assert!(reg.open(&paths[0], Some(UserId(2))).is_ok());
        assert!(reg.open(&paths[0], Some(UserId(1))).is_err());
    }

    #[test]
    fn missing_path_not_found() {
        let reg = DeviceFileRegistry::new();
        assert!(matches!(
            reg.open("/dev/nope", None),
            Err(DevFileError::NotFound(_))
        ));
    }
}
