//! Shared-bandwidth fluid model for the PCIe link.
//!
//! The paper's Table III behaviour: one 16×16 core is compute-bound
//! at 509 MB/s; two cores share the 800 MB/s Xillybus link and drop
//! to ~398 MB/s each; four cores to ~198 MB/s. The arbiter reproduces
//! this with a processor-sharing model: every open stream gets an
//! equal share of the effective link capacity *while it is active*.
//!
//! Time accounting is virtual (see [`crate::util::clock`]): a
//! transfer of `bytes` with `n` streams active charges
//! `bytes * n / cap` to the calling stream's timeline. Each stream
//! owns a local cursor so concurrent cores accumulate *overlapping*
//! time (the device clock advances to the max cursor, not the sum).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::clock::{VirtualClock, VirtualTime};

/// Per-transfer protocol overhead (descriptor setup, interrupts) —
/// calibrated so chunked streaming lands ~1-2 % below the raw cap,
/// matching Table II's 798 MB/s observed vs 800 MB/s nominal.
///
/// Public so the descriptor-ring data plane ([`crate::pcie::ring`])
/// can amortise exactly this cost across a doorbell batch instead of
/// paying it per descriptor.
pub const PER_TRANSFER_OVERHEAD_US: f64 = 0.8;

/// The shared link. One per physical FPGA board.
#[derive(Debug)]
pub struct BandwidthArbiter {
    clock: Arc<VirtualClock>,
    cap_mbps: f64,
    active: AtomicUsize,
    /// Total bytes moved (metrics).
    bytes_total: AtomicUsize,
}

impl BandwidthArbiter {
    pub fn new(clock: Arc<VirtualClock>, cap_mbps: f64) -> Arc<Self> {
        Arc::new(BandwidthArbiter {
            clock,
            cap_mbps,
            active: AtomicUsize::new(0),
            bytes_total: AtomicUsize::new(0),
        })
    }

    /// Number of currently-open streams.
    pub fn active_streams(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Effective link capacity in MB/s.
    pub fn cap_mbps(&self) -> f64 {
        self.cap_mbps
    }

    /// Total bytes transferred through the link so far.
    pub fn bytes_total(&self) -> usize {
        self.bytes_total.load(Ordering::SeqCst)
    }

    /// Fair-share duration for `bytes` at an *explicit* stream count
    /// (used by run_concurrent so the model is deterministic even
    /// when wall-clock skew lets one stream outlive the others).
    pub fn share_duration_for(&self, bytes: u64, n: usize) -> VirtualTime {
        self.share_duration_with_overhead(bytes, n, PER_TRANSFER_OVERHEAD_US)
    }

    /// Fair-share duration for `bytes` at an explicit stream count
    /// with an explicit per-transfer overhead charge in microseconds.
    /// The descriptor-ring path passes the doorbell-amortised figure
    /// (`PER_TRANSFER_OVERHEAD_US / batch`); everything else pays the
    /// full per-transfer cost.
    pub fn share_duration_with_overhead(
        &self,
        bytes: u64,
        n: usize,
        overhead_us: f64,
    ) -> VirtualTime {
        let n = n.max(1) as f64;
        let share_mbps = self.cap_mbps / n;
        VirtualTime::from_secs_f64(
            bytes as f64 / (share_mbps * 1e6) + overhead_us * 1e-6,
        )
    }

    /// Fair-share duration for `bytes` at the current stream count,
    /// *without* charging it (used by the pipelined streaming path
    /// that overlaps link transfer with core compute).
    pub fn fair_share_duration(&self, bytes: u64) -> VirtualTime {
        let n = self.active_streams().max(1) as f64;
        let share_mbps = self.cap_mbps / n;
        VirtualTime::from_secs_f64(
            bytes as f64 / (share_mbps * 1e6) + PER_TRANSFER_OVERHEAD_US * 1e-6,
        )
    }

    /// Record bytes moved without time accounting (pipelined path).
    pub fn note_bytes(&self, bytes: u64) {
        self.bytes_total.fetch_add(bytes as usize, Ordering::SeqCst);
    }

    /// Open a stream (e.g. one vFPGA's FIFO pair going active).
    pub fn open_stream(self: &Arc<Self>) -> StreamHandle {
        self.active.fetch_add(1, Ordering::SeqCst);
        StreamHandle {
            arbiter: Arc::clone(self),
            cursor: self.clock.now(),
            bytes: 0,
        }
    }
}

/// One active stream's view of the link.
///
/// Holds a local virtual-time cursor: transfers extend the cursor by
/// the fair-share duration, and push the global clock with
/// `advance_max` so overlapping streams overlap in time.
#[derive(Debug)]
pub struct StreamHandle {
    arbiter: Arc<BandwidthArbiter>,
    cursor: VirtualTime,
    bytes: u64,
}

impl StreamHandle {
    /// Transfer `bytes` through the link; returns the virtual duration
    /// charged to *this stream*.
    pub fn transfer(&mut self, bytes: u64) -> VirtualTime {
        let n = self.arbiter.active_streams().max(1) as f64;
        let share_mbps = self.arbiter.cap_mbps / n;
        let secs = bytes as f64 / (share_mbps * 1e6)
            + PER_TRANSFER_OVERHEAD_US * 1e-6;
        let d = VirtualTime::from_secs_f64(secs);
        self.arbiter.clock.advance_max(self.cursor, d);
        self.cursor = self.cursor + d;
        self.bytes += bytes;
        self.arbiter
            .bytes_total
            .fetch_add(bytes as usize, Ordering::SeqCst);
        d
    }

    /// Extend this stream's cursor by a non-link duration (e.g. the
    /// core's compute time when it, not the link, is the bottleneck).
    pub fn occupy(&mut self, d: VirtualTime) {
        self.arbiter.clock.advance_max(self.cursor, d);
        self.cursor = self.cursor + d;
    }

    /// This stream's local elapsed time since `start`.
    pub fn elapsed_since(&self, start: VirtualTime) -> VirtualTime {
        self.cursor.saturating_sub(start)
    }

    /// Current cursor position.
    pub fn cursor(&self) -> VirtualTime {
        self.cursor
    }

    /// Bytes this stream moved.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

impl Drop for StreamHandle {
    fn drop(&mut self) {
        self.arbiter.active.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arbiter() -> (Arc<BandwidthArbiter>, Arc<VirtualClock>) {
        let clock = VirtualClock::new();
        (
            BandwidthArbiter::new(Arc::clone(&clock), 800.0),
            clock,
        )
    }

    #[test]
    fn single_stream_gets_full_link() {
        let (arb, _clock) = arbiter();
        let mut s = arb.open_stream();
        let start = s.cursor();
        // 80 MB at 800 MB/s = 100 ms.
        s.transfer(80_000_000);
        let ms = s.elapsed_since(start).as_millis_f64();
        assert!((ms - 100.0).abs() < 0.1, "ms {ms}");
    }

    #[test]
    fn two_streams_halve_throughput() {
        let (arb, _clock) = arbiter();
        let mut a = arb.open_stream();
        let mut b = arb.open_stream();
        let start = a.cursor();
        a.transfer(40_000_000);
        b.transfer(40_000_000);
        // 40 MB at 400 MB/s = 100 ms each.
        let ms = a.elapsed_since(start).as_millis_f64();
        assert!((ms - 100.0).abs() < 0.1, "ms {ms}");
        let ms_b = b.elapsed_since(start).as_millis_f64();
        assert!((ms_b - 100.0).abs() < 0.1);
    }

    #[test]
    fn overlapping_streams_overlap_in_device_time() {
        let (arb, clock) = arbiter();
        let mut a = arb.open_stream();
        let mut b = arb.open_stream();
        a.transfer(40_000_000);
        b.transfer(40_000_000);
        // Device clock is the max cursor (~100 ms), not the sum.
        let ms = clock.now().as_millis_f64();
        assert!(ms < 110.0, "device clock {ms} ms");
    }

    #[test]
    fn closing_a_stream_restores_share() {
        let (arb, _clock) = arbiter();
        let mut a = arb.open_stream();
        {
            let _b = arb.open_stream();
            assert_eq!(arb.active_streams(), 2);
        }
        assert_eq!(arb.active_streams(), 1);
        let start = a.cursor();
        a.transfer(80_000_000);
        let ms = a.elapsed_since(start).as_millis_f64();
        assert!((ms - 100.0).abs() < 0.1, "full share restored: {ms}");
    }

    #[test]
    fn occupy_extends_cursor_without_link_use() {
        let (arb, _clock) = arbiter();
        let mut s = arb.open_stream();
        let start = s.cursor();
        s.occupy(VirtualTime::from_millis_f64(5.0));
        assert!((s.elapsed_since(start).as_millis_f64() - 5.0).abs() < 1e-9);
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn chunked_transfer_hits_table2_798() {
        // Table II: 798 MB/s observed for one vFPGA on the 800 MB/s
        // link — chunking overhead accounts for the ~2 MB/s gap.
        let (arb, _clock) = arbiter();
        let mut s = arb.open_stream();
        let start = s.cursor();
        let chunk = 256 * 1024; // RC2F FIFO chunk
        let total: u64 = 200_000_000;
        for _ in 0..(total / chunk) {
            s.transfer(chunk);
        }
        let secs = s.elapsed_since(start).as_secs_f64();
        let mbps = total as f64 / 1e6 / secs;
        assert!(
            (mbps - crate::paper::FIFO_1V_MBPS).abs() < 3.0,
            "measured {mbps} MB/s"
        );
    }

    #[test]
    fn four_streams_quarter_share() {
        let (arb, _clock) = arbiter();
        let mut streams: Vec<_> = (0..4).map(|_| arb.open_stream()).collect();
        let start = streams[0].cursor();
        for s in &mut streams {
            s.transfer(20_000_000);
        }
        // 20 MB at 200 MB/s = 100 ms.
        for s in &streams {
            let ms = s.elapsed_since(start).as_millis_f64();
            assert!((ms - 100.0).abs() < 0.2, "ms {ms}");
        }
    }

    #[test]
    fn byte_accounting() {
        let (arb, _clock) = arbiter();
        let mut s = arb.open_stream();
        s.transfer(1000);
        s.transfer(234);
        assert_eq!(s.bytes(), 1234);
        assert_eq!(arb.bytes_total(), 1234);
    }
}
