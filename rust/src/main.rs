//! `rc3e` — the RC3E cloud CLI and daemon launcher.
//!
//! Subcommands:
//! * `serve`  — boot the cloud (management server + node agents) and
//!   print the management address; Ctrl-C to stop. `--state DIR`
//!   persists the device DB + scheduler accounting there (quotas and
//!   the usage ledger reload on restart).
//! * `cli <method> [--param value ...]` — one raw middleware call
//!   against a running server (`--addr host:port`); untyped params
//!   over the current envelope.
//! * `demo` — self-contained end-to-end demo on an in-process cloud:
//!   allocate → program → stream → report (no server needed).
//! * `status|alloc|program|stream|release|migrate|job|...` — typed
//!   calls; errors print their machine-readable code.
//! * `watch` — protocol-3 server-push subscription: print typed
//!   events (`job`, `placement`, `region`, `sched` topics) as they
//!   happen instead of polling. `job --follow` rides the same stream
//!   for one job's progress frames.
//! * `trace <job-N|trace-N>` — fetch a request trace from the
//!   server's flight recorder and render the span tree as an
//!   indented waterfall.
//! * `metrics [--watch]` — dump every instrument in the server's
//!   metrics registry (counters, gauges, histograms).

use std::sync::Arc;

use rc3e::config::{ClusterConfig, ServiceModel};
use rc3e::hypervisor::{Hypervisor, PlacementPolicy};
use rc3e::middleware::api::{
    Event, HistogramBody, MetricsExportResponse, QuotaSetRequest,
    ReserveRequest, SpanBody, SubscribeRequest, SubscriptionFilter,
    Topic, TraceGetRequest,
};
use rc3e::middleware::{Client, ManagementServer, NodeAgent};
use rc3e::sched::RequestClass;
use rc3e::util::cli::{Args, FlagSpec};
use rc3e::util::clock::VirtualClock;
use rc3e::util::ids::{
    AllocationId, FpgaId, JobId, LeaseToken, NodeId, TraceId, UserId,
};
use rc3e::util::json::Json;
use rc3e::util::table::Table;

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec {
            name: "addr",
            takes_value: true,
            help: "management server address (host:port)",
        },
        FlagSpec {
            name: "config",
            takes_value: true,
            help: "cluster config JSON (default: paper testbed)",
        },
        FlagSpec {
            name: "state",
            takes_value: true,
            help: "serve: directory for device DB + scheduler state",
        },
        FlagSpec {
            name: "user",
            takes_value: true,
            help: "user id (user-N)",
        },
        FlagSpec {
            name: "alloc",
            takes_value: true,
            help: "allocation id (alloc-N)",
        },
        FlagSpec {
            name: "fpga",
            takes_value: true,
            help: "device id (fpga-N)",
        },
        FlagSpec {
            name: "core",
            takes_value: true,
            help: "user core name (matmul16, matmul32, ...)",
        },
        FlagSpec {
            name: "mults",
            takes_value: true,
            help: "matrix multiplications to stream",
        },
        FlagSpec {
            name: "part",
            takes_value: true,
            help: "compile: target FPGA part (default: VC707's)",
        },
        FlagSpec {
            name: "digest",
            takes_value: true,
            help: "compile: poll this artifact digest instead of \
                   submitting",
        },
        FlagSpec {
            name: "name",
            takes_value: true,
            help: "user name",
        },
        FlagSpec {
            name: "model",
            takes_value: true,
            help: "alloc: service model (raaas, baaas)",
        },
        FlagSpec {
            name: "class",
            takes_value: true,
            help: "alloc: request class (interactive, normal, batch)",
        },
        FlagSpec {
            name: "lease",
            takes_value: true,
            help: "capability token (lt-...) from alloc; required by \
                   mutating calls on protocol 2",
        },
        FlagSpec {
            name: "co-located",
            takes_value: false,
            help: "alloc: place the whole gang on one device",
        },
        FlagSpec {
            name: "board",
            takes_value: true,
            help: "alloc: restrict to a board model (vc707, ml605)",
        },
        FlagSpec {
            name: "job",
            takes_value: true,
            help: "job id (job-N) for the job subcommand",
        },
        FlagSpec {
            name: "wait",
            takes_value: false,
            help: "job: block until the job is terminal",
        },
        FlagSpec {
            name: "cancel",
            takes_value: false,
            help: "job: cancel a running job",
        },
        FlagSpec {
            name: "follow",
            takes_value: false,
            help: "job: stream progress events until terminal",
        },
        FlagSpec {
            name: "topics",
            takes_value: true,
            help: "watch: comma-separated topics \
                   (job,placement,region,sched; default all)",
        },
        FlagSpec {
            name: "timeout-s",
            takes_value: true,
            help: "watch: server-side stream bound per round",
        },
        FlagSpec {
            name: "resume",
            takes_value: true,
            help: "watch: replay journaled events from this cursor \
                   before going live (gapless across restarts)",
        },
        FlagSpec {
            name: "max-events",
            takes_value: true,
            help: "watch: close the stream after N events",
        },
        FlagSpec {
            name: "limit",
            takes_value: true,
            help: "lifecycle: newest transition records to fetch",
        },
        FlagSpec {
            name: "policy",
            takes_value: true,
            help: "sched: set the preemption landing policy \
                   (spread|pack)",
        },
        FlagSpec {
            name: "timescale",
            takes_value: true,
            help: "virtual-clock wall divisor for serve (0 = no sleep)",
        },
        FlagSpec {
            name: "max-vfpgas",
            takes_value: true,
            help: "quota: max concurrent vFPGAs for --user (0 = unlimited)",
        },
        FlagSpec {
            name: "budget-s",
            takes_value: true,
            help: "quota: lifetime device-second budget (negative clears)",
        },
        FlagSpec {
            name: "weight",
            takes_value: true,
            help: "quota: fair-share weight for --user",
        },
        FlagSpec {
            name: "regions",
            takes_value: true,
            help: "alloc: gang size; reserve: vFPGA regions to reserve",
        },
        FlagSpec {
            name: "duration-s",
            takes_value: true,
            help: "reserve: reservation window length in virtual seconds",
        },
        FlagSpec {
            name: "watch",
            takes_value: false,
            help: "metrics: reprint the registry every 2 s",
        },
        FlagSpec {
            name: "verbose",
            takes_value: false,
            help: "debug logging",
        },
        FlagSpec {
            name: "federated",
            takes_value: false,
            help: "serve: federated management server (capacity \
                   arrives from node daemons, no local devices)",
        },
        FlagSpec {
            name: "mgmt",
            takes_value: true,
            help: "node: management server address to register with",
        },
        FlagSpec {
            name: "node-index",
            takes_value: true,
            help: "node: which config node this daemon serves",
        },
    ]
}

fn main() {
    rc3e::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = flag_specs();
    let args = match Args::parse(&argv, &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if args.has("verbose") {
        rc3e::util::logging::init_with_level(log::LevelFilter::Debug);
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "serve" => cmd_serve(&args),
        "node" => cmd_node(&args),
        "nodes" => cmd_nodes(&args),
        "demo" => cmd_demo(&args),
        "cli" => cmd_cli(&args),
        "status" => cmd_status(&args),
        "adduser" => cmd_adduser(&args),
        "alloc" => cmd_alloc(&args),
        "program" => cmd_program(&args),
        "compile" => cmd_compile(&args),
        "stream" => cmd_stream(&args),
        "release" => cmd_release(&args),
        "migrate" => cmd_migrate(&args),
        "energy" => cmd_energy(&args),
        "sched" => cmd_sched(&args),
        "usage" => cmd_usage(&args),
        "quota" => cmd_quota(&args),
        "reserve" => cmd_reserve(&args),
        "job" => cmd_job(&args),
        "watch" => cmd_watch(&args),
        "lifecycle" => cmd_lifecycle(&args),
        "trace" => cmd_trace(&args),
        "metrics" => cmd_metrics(&args),
        _ => {
            print!("{}", usage());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage() -> String {
    let mut out = String::from(
        "rc3e — Reconfigurable Common Cloud Computing Environment\n\n\
         Subcommands:\n\
         \x20 serve      boot management server + node agents \
         [--state DIR] [--federated]\n\
         \x20 node       federated node daemon: --node-index N \
         --mgmt host:port --state DIR\n\
         \x20 nodes      list cluster nodes (health, capacity, \
         heartbeat age)\n\
         \x20 demo       in-process end-to-end demo\n\
         \x20 cli        raw middleware call: rc3e cli <method> [--flags]\n\
         \x20 adduser    --name <s>\n\
         \x20 status     --fpga fpga-N\n\
         \x20 alloc      --user user-N [--model raaas --class batch \
         --regions N --co-located --board vc707]\n\
         \x20 program    --user user-N --alloc alloc-N --lease lt-... \
         --core matmul16\n\
         \x20 compile    --user user-N --core matmul16 [--part xc...] \
         [--wait] | --digest <sha>\n\
         \x20 stream     --user user-N --alloc alloc-N --lease lt-... \
         --core matmul16 --mults 100000\n\
         \x20 release    --alloc alloc-N --lease lt-...\n\
         \x20 migrate    --user user-N --alloc alloc-N --lease lt-...\n\
         \x20 energy\n\
         \x20 sched      scheduler status + admission-wait histogram \
         [--policy spread|pack]\n\
         \x20 quota      --user user-N [--max-vfpgas N --budget-s S \
         --weight W]\n\
         \x20 usage      per-tenant device-second + energy report\n\
         \x20 reserve    --user user-N --regions N [--model raaas \
         --duration-s S]\n\
         \x20 job        --job job-N [--lease lt-...] \
         [--wait | --cancel | --follow]\n\
         \x20 watch      server-push events [--topics job,sched,... \
         --lease lt-... --max-events N --timeout-s S]\n\
         \x20 lifecycle  --fpga fpga-N [--limit N] region transition \
         log\n\
         \x20 trace      rc3e trace <job-N|trace-N> — span waterfall \
         from the flight recorder\n\
         \x20 metrics    dump the server metrics registry [--watch]\n\n",
    );
    out.push_str(&rc3e::util::cli::usage("rc3e", "flags", &flag_specs()));
    out
}

fn load_config(args: &Args) -> Result<ClusterConfig, String> {
    match args.get("config") {
        Some(path) => ClusterConfig::load(std::path::Path::new(path)),
        None => Ok(ClusterConfig::paper_testbed()),
    }
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let federated = args.has("federated");
    let config = if federated {
        // A federated management node owns no boards; keep only the
        // RPC overhead from an explicit config.
        let mut c = ClusterConfig::management_only();
        if let Some(path) = args.get("config") {
            c.rpc_overhead_ms =
                ClusterConfig::load(std::path::Path::new(path))?
                    .rpc_overhead_ms;
        }
        c
    } else {
        load_config(args)?
    };
    let scale = args.get_u64("timescale", 0).map_err(|e| e.to_string())?;
    let clock = if scale > 0 {
        VirtualClock::with_scale(scale)
    } else {
        VirtualClock::new()
    };
    eprintln!(
        "booting cloud: {} nodes, {} FPGAs, {} vFPGAs...",
        config.nodes.len(),
        config.total_fpgas(),
        config.total_vfpgas()
    );
    let hv = Arc::new(
        Hypervisor::boot(&config, clock, PlacementPolicy::ConsolidateFirst)
            .map_err(|e| e.to_string())?,
    );
    let state_dir = match args.get("state") {
        Some(dir) => {
            let dir = std::path::PathBuf::from(dir);
            std::fs::create_dir_all(&dir)
                .map_err(|e| format!("--state {}: {e}", dir.display()))?;
            Some(dir)
        }
        None => None,
    };
    if let Some(dir) = &state_dir {
        // A restarted management node must mint the same UserIds for
        // the same tenants (lease recovery matches on tenant id) and
        // must never reuse a pre-crash AllocationId for a fresh
        // lease: restore the user table and the id-generator floors
        // from the previous life's device DB before re-saving it.
        let db_path = dir.join("devices.json");
        if db_path.exists() {
            let old = rc3e::hypervisor::DeviceDb::load(&db_path)?;
            let mut db = hv.db.lock().unwrap();
            for (id, name) in &old.users {
                db.users.insert(*id, name.clone());
                db.user_ids.bump_past(id.0);
            }
            for id in old.allocations.keys() {
                db.alloc_ids.bump_past(id.0);
            }
            for a in old.allocations.values() {
                if let rc3e::hypervisor::AllocKind::Vm(vm, _) = a.kind {
                    db.vm_ids.bump_past(vm.0);
                }
            }
            eprintln!(
                "restart: restored {} users from {}",
                old.users.len(),
                db_path.display()
            );
        }
    }
    let server = if federated {
        ManagementServer::spawn_federated(
            Arc::clone(&hv),
            config.rpc_overhead_ms,
            state_dir.as_deref(),
        )
    } else {
        ManagementServer::spawn_with_state(
            Arc::clone(&hv),
            config.rpc_overhead_ms,
            state_dir.as_deref(),
        )
    }
    .map_err(|e| e.to_string())?;
    if federated {
        eprintln!(
            "federated: waiting for node daemons to register \
             (rc3e node --node-index N --mgmt {} --state DIR)",
            server.addr()
        );
    }
    if let Some(dir) = &state_dir {
        // Persist the device DB, the event journal and the
        // scheduler's snapshot + WAL side by side; a restarted
        // management node reloads accounting AND re-adopts live
        // leases + queued admissions from the same directory.
        let db_path = dir.join("devices.json");
        hv.db.lock().unwrap().save(&db_path)?;
        server.scheduler().attach_persistence(&db_path)?;
        eprintln!(
            "state dir {} (device DB + event journal + scheduler \
             snapshot/WAL)",
            dir.display()
        );
    }
    let mut agents = Vec::new();
    for (i, node) in config.nodes.iter().enumerate() {
        let agent = NodeAgent::spawn(Arc::clone(&hv), NodeId(i as u64), None)
            .map_err(|e| e.to_string())?;
        eprintln!("node agent for {} at {}", node.name, agent.addr());
        server.register_agent(NodeId(i as u64), agent.addr());
        agents.push(agent);
    }
    println!("{}", server.addr());
    eprintln!(
        "management server ready at {} (Ctrl-C to stop)",
        server.addr()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_node(args: &Args) -> Result<(), String> {
    let config = load_config(args)?;
    let index = args
        .get("node-index")
        .ok_or("missing --node-index")?
        .parse::<usize>()
        .map_err(|e| format!("bad --node-index: {e}"))?;
    let mgmt: std::net::SocketAddr = args
        .get("mgmt")
        .ok_or("missing --mgmt (management server address)")?
        .parse()
        .map_err(|e| format!("bad --mgmt: {e}"))?;
    let state = args
        .get("state")
        .ok_or("missing --state (per-node WAL directory)")?;
    let scale = args.get_u64("timescale", 0).map_err(|e| e.to_string())?;
    let clock = if scale > 0 {
        VirtualClock::with_scale(scale)
    } else {
        VirtualClock::new()
    };
    let daemon = rc3e::cluster::NodeDaemon::spawn(
        &config,
        index,
        std::path::Path::new(state),
        clock,
    )?;
    // The daemon's address first, like serve: scripts read line one.
    println!("{}", daemon.addr());
    let resp = daemon.register(mgmt)?;
    eprintln!(
        "node daemon {} ({}) at {} registered with {} \
         ({} stale leases released)",
        daemon.node(),
        daemon.name(),
        daemon.addr(),
        mgmt,
        resp.release.len()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_nodes(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    let resp = client.node_list().map_err(|e| e.to_string())?;
    let mut t = Table::new(
        "cluster nodes",
        &[
            "node", "addr", "boards", "free", "active", "leases",
            "hb ms", "state",
        ],
    );
    for n in &resp.nodes {
        t.row(&[
            n.node.to_string(),
            n.addr.clone(),
            n.boards.join(","),
            n.regions_free.to_string(),
            n.regions_active.to_string(),
            n.leases.to_string(),
            format!("{:.0}", n.heartbeat_age_ms),
            n.state.clone(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn connect(args: &Args) -> Result<Client, String> {
    let addr = args
        .get("addr")
        .ok_or("missing --addr (management server)")?;
    let addr: std::net::SocketAddr =
        addr.parse().map_err(|e| format!("bad --addr: {e}"))?;
    Client::connect(addr)
}

// ------------------------------------------------ flag id parsing

fn user_flag(args: &Args) -> Result<UserId, String> {
    let s = args.get("user").ok_or("missing --user")?;
    UserId::parse(s).ok_or_else(|| format!("bad --user '{s}'"))
}

fn alloc_flag(args: &Args) -> Result<AllocationId, String> {
    let s = args.get("alloc").ok_or("missing --alloc")?;
    AllocationId::parse(s).ok_or_else(|| format!("bad --alloc '{s}'"))
}

fn fpga_flag(args: &Args) -> Result<FpgaId, String> {
    let s = args.get("fpga").ok_or("missing --fpga")?;
    FpgaId::parse(s).ok_or_else(|| format!("bad --fpga '{s}'"))
}

fn job_flag(args: &Args) -> Result<JobId, String> {
    let s = args.get("job").ok_or("missing --job")?;
    JobId::parse(s).ok_or_else(|| format!("bad --job '{s}'"))
}

fn lease_flag(args: &Args) -> Result<Option<LeaseToken>, String> {
    match args.get("lease") {
        None => Ok(None),
        Some(s) => LeaseToken::parse(s)
            .map(Some)
            .ok_or_else(|| format!("bad --lease '{s}'")),
    }
}

/// Feed `--lease` into the client's token cache for `alloc` so the
/// next mutating call carries it (each CLI invocation is a fresh
/// process; the token from `rc3e alloc` must be passed back in).
fn apply_lease_flag(
    client: &mut Client,
    args: &Args,
    alloc: AllocationId,
) -> Result<(), String> {
    if let Some(token) = lease_flag(args)? {
        client.set_lease_token(alloc, token);
    }
    Ok(())
}

// --------------------------------------------- typed subcommands

fn cmd_status(args: &Args) -> Result<(), String> {
    let fpga = fpga_flag(args)?;
    let mut client = connect(args)?;
    let resp = client.status(fpga).map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

fn cmd_adduser(args: &Args) -> Result<(), String> {
    let name = args.get("name").ok_or("missing --name")?.to_string();
    let mut client = connect(args)?;
    let resp = client.add_user(&name).map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

fn cmd_alloc(args: &Args) -> Result<(), String> {
    let user = user_flag(args)?;
    let model = match args.get("model") {
        Some(s) => Some(
            ServiceModel::parse(s)
                .ok_or_else(|| format!("bad --model '{s}'"))?,
        ),
        None => None,
    };
    let class = match args.get("class") {
        Some(s) => Some(
            RequestClass::parse(s)
                .ok_or_else(|| format!("bad --class '{s}'"))?,
        ),
        None => None,
    };
    let regions = match args.get("regions") {
        Some(v) => Some(
            v.parse::<u32>().map_err(|e| format!("--regions: {e}"))?,
        ),
        None => None,
    };
    let mut req =
        rc3e::middleware::api::AllocVfpgaRequest::single(user, model, class);
    req.regions = regions;
    if args.has("co-located") {
        req.co_located = Some(true);
    }
    req.board = args.get("board").map(String::from);
    let mut client = connect(args)?;
    let resp = client
        .alloc_vfpga_with(&req)
        .map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

fn cmd_program(args: &Args) -> Result<(), String> {
    let user = user_flag(args)?;
    let alloc = alloc_flag(args)?;
    let core = args.get("core").ok_or("missing --core")?.to_string();
    let mut client = connect(args)?;
    apply_lease_flag(&mut client, args, alloc)?;
    let resp = client
        .program_core(user, alloc, &core)
        .map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

/// `rc3e compile` — ahead-of-time compile of a core into the cluster
/// bitstream cache, so a later `program` hits the warm path. With
/// `--digest` it polls an earlier submission instead; with `--wait`
/// it blocks on the flow job until the artifact is cached.
fn cmd_compile(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    if let Some(d) = args.get("digest") {
        let resp =
            client.compile_status(d).map_err(|e| e.to_string())?;
        println!("{}", resp.to_json().to_pretty());
        return Ok(());
    }
    let user = user_flag(args)?;
    let core = args.get("core").ok_or("missing --core")?.to_string();
    let req = rc3e::middleware::api::CompileSubmitRequest {
        user,
        core,
        part: args.get("part").map(String::from),
    };
    let resp =
        client.compile_submit(&req).map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    if args.has("wait") {
        if let Some(job) = resp.job {
            eprintln!("waiting on {job}...");
            let result =
                client.job_wait_done(job).map_err(|e| e.to_string())?;
            println!("{}", result.to_pretty());
        }
    }
    Ok(())
}

fn cmd_stream(args: &Args) -> Result<(), String> {
    let user = user_flag(args)?;
    let alloc = alloc_flag(args)?;
    let core = args.get("core").ok_or("missing --core")?.to_string();
    let mults =
        args.get_u64("mults", 100_000).map_err(|e| e.to_string())?;
    let mut client = connect(args)?;
    apply_lease_flag(&mut client, args, alloc)?;
    // Submit as a job, then wait — the CLI shows the handle so the
    // run could also be watched from another terminal via `job`.
    let job = client
        .stream(user, alloc, &core, mults)
        .map_err(|e| e.to_string())?
        .job;
    eprintln!("submitted {job}; waiting...");
    let result =
        client.job_wait_done(job).map_err(|e| e.to_string())?;
    println!("{}", result.to_pretty());
    Ok(())
}

fn cmd_release(args: &Args) -> Result<(), String> {
    let alloc = alloc_flag(args)?;
    let mut client = connect(args)?;
    apply_lease_flag(&mut client, args, alloc)?;
    let resp = client.release(alloc).map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

fn cmd_migrate(args: &Args) -> Result<(), String> {
    let user = user_flag(args)?;
    let alloc = alloc_flag(args)?;
    let mut client = connect(args)?;
    apply_lease_flag(&mut client, args, alloc)?;
    let resp =
        client.migrate(user, alloc).map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    let resp = client.energy().map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

/// `rc3e sched` — queue snapshot plus the admission-wait histogram,
/// queue-depth gauge and region-lifecycle telemetry served by the
/// `monitor` RPC. `--policy spread|pack` sets the preemption landing
/// policy first.
fn cmd_sched(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    if let Some(p) = args.get("policy") {
        let set = client
            .sched_policy_set(p)
            .map_err(|e| e.to_string())?;
        println!("preempt policy set to {}", set.policy);
    } else {
        let pol =
            client.sched_policy_get().map_err(|e| e.to_string())?;
        println!("preempt policy: {}", pol.policy);
    }
    let status = client.sched_status().map_err(|e| e.to_string())?;
    let mon = client.monitor().map_err(|e| e.to_string())?;
    println!("{}", status.status.to_pretty());
    let t = &mon.sched;
    println!(
        "queue depth {}, active grants {}",
        t.queue_depth, t.active_grants
    );
    println!(
        "admission wait (virtual): n={} mean={:.1} ms p50<={:.1} ms \
         p99<={:.1} ms max={:.1} ms",
        t.wait.count,
        t.wait.mean_ms,
        t.wait.p50_ms,
        t.wait.p99_ms,
        t.wait.max_ms
    );
    println!(
        "quiesce wait (wall): n={} mean={:.1} ms p50<={:.1} ms \
         p99<={:.1} ms max={:.1} ms; preempt races absorbed: {}",
        t.quiesce_wait.count,
        t.quiesce_wait.mean_ms,
        t.quiesce_wait.p50_ms,
        t.quiesce_wait.p99_ms,
        t.quiesce_wait.max_ms,
        t.preempt_raced
    );
    let l = &t.lifecycle;
    println!(
        "regions: free {} reserved {} programming {} active {} \
         draining {} migrating {}",
        l.free,
        l.reserved,
        l.programming,
        l.active,
        l.draining,
        l.migrating
    );
    Ok(())
}

/// `rc3e quota --user user-N [--max-vfpgas N --budget-s S --weight W]`
/// — with any limit flag present this sets the quota, otherwise it
/// reads it.
fn cmd_quota(args: &Args) -> Result<(), String> {
    let user = user_flag(args)?;
    let mut client = connect(args)?;
    let max_vfpgas = match args.get("max-vfpgas") {
        Some(v) => {
            Some(v.parse::<u64>().map_err(|e| format!("--max-vfpgas: {e}"))?)
        }
        None => None,
    };
    let budget_s = match args.get("budget-s") {
        Some(v) => {
            Some(v.parse::<f64>().map_err(|e| format!("--budget-s: {e}"))?)
        }
        None => None,
    };
    let weight = match args.get("weight") {
        Some(v) => {
            Some(v.parse::<u64>().map_err(|e| format!("--weight: {e}"))?)
        }
        None => None,
    };
    let resp = if max_vfpgas.is_some() || budget_s.is_some() || weight.is_some()
    {
        client.quota_set(&QuotaSetRequest {
            user,
            max_vfpgas,
            budget_s,
            weight,
        })
    } else {
        client.quota_get(user)
    }
    .map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

/// `rc3e usage` — print the per-tenant accounting table.
fn cmd_usage(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    let resp = client.usage_report().map_err(|e| e.to_string())?;
    print!("{}", resp.table);
    Ok(())
}

/// `rc3e reserve --user user-N --regions N [--duration-s S]`.
fn cmd_reserve(args: &Args) -> Result<(), String> {
    let user = user_flag(args)?;
    let regions = args
        .get("regions")
        .ok_or("missing --regions")?
        .parse::<u64>()
        .map_err(|e| format!("--regions: {e}"))?;
    let duration_s = match args.get("duration-s") {
        Some(v) => Some(
            v.parse::<f64>().map_err(|e| format!("--duration-s: {e}"))?,
        ),
        None => None,
    };
    let model = match args.get("model") {
        Some(s) => Some(
            ServiceModel::parse(s)
                .ok_or_else(|| format!("bad --model '{s}'"))?,
        ),
        None => None,
    };
    let mut client = connect(args)?;
    let resp = client
        .reserve(&ReserveRequest {
            user,
            regions,
            model,
            start_s: None,
            duration_s,
        })
        .map_err(|e| e.to_string())?;
    println!("{}", resp.to_json().to_pretty());
    Ok(())
}

/// `rc3e job --job job-N [--wait | --cancel | --follow]`.
fn cmd_job(args: &Args) -> Result<(), String> {
    let job = job_flag(args)?;
    let mut client = connect(args)?;
    let token = lease_flag(args)?;
    if let Some(token) = token {
        client.set_job_token(job, token);
    }
    if args.has("follow") {
        return follow_job(&mut client, job, token);
    }
    let body = if args.has("cancel") {
        client.job_cancel(job)
    } else if args.has("wait") {
        client.job_wait(job, None)
    } else {
        client.job_status(job)
    }
    .map_err(|e| e.to_string())?;
    println!("{}", body.to_json().to_pretty());
    Ok(())
}

/// `rc3e job --follow`: ride the protocol-3 event stream for one
/// job's progress frames (short subscription rounds so each round's
/// terminal frame arrives promptly), then print the job body.
fn follow_job(
    client: &mut Client,
    job: rc3e::util::ids::JobId,
    token: Option<LeaseToken>,
) -> Result<(), String> {
    let mut filter = SubscriptionFilter::topic(Topic::Job);
    filter.job_ids = vec![job];
    loop {
        let mut terminal = false;
        let stream = client
            .subscribe(&SubscribeRequest {
                filter: filter.clone(),
                lease: token,
                max_events: None,
                timeout_s: Some(5.0),
                from_cursor: None,
            })
            .map_err(|e| e.to_string())?;
        for frame in stream {
            let frame = frame.map_err(|e| e.to_string())?;
            if let Event::JobProgress {
                phase, pct, state, ..
            } = &frame.event
            {
                eprintln!("{state:>9} {pct:5.1}%  {phase}");
                if state != "running" {
                    terminal = true;
                }
            }
        }
        if terminal {
            break;
        }
        // The job may have finished before (or between) rounds — the
        // stream only carries live events.
        let body =
            client.job_status(job).map_err(|e| e.to_string())?;
        if body.is_terminal() {
            break;
        }
    }
    let body = client.job_status(job).map_err(|e| e.to_string())?;
    println!("{}", body.to_json().to_pretty());
    Ok(())
}

/// `rc3e watch` — print server-push events as they happen.
fn cmd_watch(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    let mut filter = SubscriptionFilter::all();
    if let Some(t) = args.get("topics") {
        for part in t.split(',') {
            let part = part.trim();
            filter.topics.push(Topic::parse(part).ok_or_else(
                || format!("bad --topics entry '{part}'"),
            )?);
        }
    }
    if let Some(f) = args.get("fpga") {
        filter.fpga_ids.push(
            FpgaId::parse(f).ok_or_else(|| format!("bad --fpga '{f}'"))?,
        );
    }
    if let Some(j) = args.get("job") {
        filter.job_ids.push(
            JobId::parse(j).ok_or_else(|| format!("bad --job '{j}'"))?,
        );
    }
    let timeout_s = match args.get("timeout-s") {
        Some(v) => Some(
            v.parse::<f64>().map_err(|e| format!("--timeout-s: {e}"))?,
        ),
        None => None,
    };
    let max_events = match args.get("max-events") {
        Some(v) => Some(
            v.parse::<u64>().map_err(|e| format!("--max-events: {e}"))?,
        ),
        None => None,
    };
    let lease = lease_flag(args)?;
    // Resume position: replay journaled events from this cursor
    // before going live (survives server restarts — cursors are
    // journal sequence numbers). The last cursor seen is carried into
    // every re-subscription, so a long watch never sees a gap or a
    // duplicate across rounds. Delivery is at-least-once on the wire;
    // the `c <= last` skip below is the client-side dedup that makes
    // it exactly-once (docs/PROTOCOL.md, docs/DURABILITY.md).
    let mut last_cursor: Option<u64> = match args.get("resume") {
        Some(v) => {
            let from =
                v.parse::<u64>().map_err(|e| format!("--resume: {e}"))?;
            from.checked_sub(1)
        }
        None => None,
    };
    // Long watch: one server-side window per round, re-subscribing
    // when the terminal frame arrives (see docs/PROTOCOL.md). An
    // explicit --max-events bounds the watch to a single round.
    loop {
        let stream = client
            .subscribe(&SubscribeRequest {
                filter: filter.clone(),
                lease,
                max_events,
                timeout_s,
                from_cursor: last_cursor.map(|c| c + 1),
            })
            .map_err(|e| e.to_string())?;
        eprintln!(
            "subscription {} open ({:.0} s window; Ctrl-C to stop)",
            stream.header().subscription,
            stream.header().timeout_s
        );
        for frame in stream {
            let frame = frame.map_err(|e| e.to_string())?;
            if let Some(c) = frame.cursor {
                if last_cursor.map_or(false, |last| c <= last) {
                    continue;
                }
                last_cursor = Some(c);
                println!("@{:<6} {}", c, frame.event.to_json());
            } else {
                println!("#{:<5} {}", frame.seq, frame.event.to_json());
            }
        }
        if max_events.is_some() {
            return Ok(());
        }
    }
}

/// `rc3e lifecycle --fpga fpga-N [--limit N]` — the device's region
/// transition log (how regions got into their current states).
fn cmd_lifecycle(args: &Args) -> Result<(), String> {
    let fpga = fpga_flag(args)?;
    let limit = match args.get("limit") {
        Some(v) => Some(
            v.parse::<u64>().map_err(|e| format!("--limit: {e}"))?,
        ),
        None => None,
    };
    let mut client = connect(args)?;
    let resp = client
        .lifecycle_log(fpga, limit)
        .map_err(|e| e.to_string())?;
    for r in &resp.records {
        println!(
            "{:>10.3}s  {:<9} {} -> {}",
            r.at_s, r.region, r.from, r.to
        );
    }
    println!(
        "{} records ({} aged out of the bounded log)",
        resp.records.len(),
        resp.dropped
    );
    Ok(())
}

/// `rc3e trace <job-N | trace-N>` — fetch a request trace from the
/// server's flight recorder and render it as a waterfall: one row
/// per span, indented by tree depth, offsets in virtual ms from the
/// earliest span start.
fn cmd_trace(args: &Args) -> Result<(), String> {
    let id = args
        .positional()
        .get(1)
        .ok_or("usage: rc3e trace <job-N | trace-N> --addr host:port")?;
    let req = if let Some(job) = JobId::parse(id) {
        TraceGetRequest::by_job(job)
    } else if let Some(trace) = TraceId::parse(id) {
        TraceGetRequest::by_trace(trace)
    } else {
        return Err(format!(
            "'{id}' is neither a job-N nor a trace-N id"
        ));
    };
    let mut client = connect(args)?;
    let resp = client.trace_get(&req).map_err(|e| e.to_string())?;
    print!(
        "{}",
        render_waterfall(&resp.trace.to_string(), &resp.spans)
    );
    if resp.truncated > 0 {
        println!(
            "({} spans dropped past the per-trace cap)",
            resp.truncated
        );
    }
    Ok(())
}

/// Render a span tree as an indented waterfall table. Spans whose
/// parent is missing (evicted or foreign) render at the root level
/// rather than being dropped.
fn render_waterfall(trace: &str, spans: &[SpanBody]) -> String {
    use std::collections::{HashMap, HashSet};
    let t0 = spans.iter().map(|s| s.start_ns).min().unwrap_or(0);
    let ids: HashSet<_> = spans.iter().map(|s| s.span).collect();
    let mut children: HashMap<_, Vec<usize>> = HashMap::new();
    let mut roots = Vec::new();
    for (i, s) in spans.iter().enumerate() {
        match s.parent {
            Some(p) if ids.contains(&p) => {
                children.entry(p).or_default().push(i)
            }
            _ => roots.push(i),
        }
    }
    let mut table = Table::new(
        &format!("trace {trace}"),
        &["span", "start ms", "dur ms", "outcome", "detail"],
    );
    // Depth-first in recorded (start) order.
    let mut stack: Vec<(usize, usize)> =
        roots.iter().rev().map(|&i| (i, 0)).collect();
    while let Some((i, depth)) = stack.pop() {
        let s = &spans[i];
        let mut detail: Vec<String> = s
            .attrs
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        if let Some(e) = &s.error {
            detail.push(format!("error: {e}"));
        }
        table.row(&[
            format!("{}{}", "  ".repeat(depth), s.name),
            format!(
                "{:.3}",
                s.start_ns.saturating_sub(t0) as f64 / 1e6
            ),
            if s.end_ns.is_some() {
                format!("{:.3}", s.duration_ms())
            } else {
                "open".into()
            },
            s.outcome.clone(),
            detail.join(" "),
        ]);
        if let Some(kids) = children.get(&s.span) {
            for &k in kids.iter().rev() {
                stack.push((k, depth + 1));
            }
        }
    }
    table.render()
}

/// `rc3e metrics [--watch]` — dump every instrument in the server's
/// metrics registry. `--watch` reprints the registry every 2 s until
/// interrupted.
fn cmd_metrics(args: &Args) -> Result<(), String> {
    let mut client = connect(args)?;
    loop {
        let resp =
            client.metrics_export().map_err(|e| e.to_string())?;
        print!("{}", render_metrics(&resp));
        if !args.has("watch") {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs(2));
        println!();
    }
}

fn render_metrics(resp: &MetricsExportResponse) -> String {
    let mut out = String::new();
    let mut t = Table::new("counters", &["name", "value"]);
    for (n, v) in &resp.counters {
        t.row(&[n.clone(), v.to_string()]);
    }
    out.push_str(&t.render());
    let mut t = Table::new("gauges", &["name", "value"]);
    for (n, v) in &resp.gauges {
        t.row(&[n.clone(), v.to_string()]);
    }
    out.push_str(&t.render());
    let mut t = Table::new(
        "histograms (us)",
        &["name", "n", "mean", "p50<=", "p99<=", "max"],
    );
    for (n, h) in &resp.histograms {
        let mean = if h.count > 0 {
            h.sum_us as f64 / h.count as f64
        } else {
            0.0
        };
        t.row(&[
            n.clone(),
            h.count.to_string(),
            format!("{mean:.1}"),
            quantile_bound(h, 0.50),
            quantile_bound(h, 0.99),
            h.max_us.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Upper-bound estimate of a quantile from exported bucket counts:
/// the bound of the first bucket whose cumulative count reaches
/// `q * count` (`overflow` when it lands past the last finite bound).
fn quantile_bound(h: &HistogramBody, q: f64) -> String {
    if h.count == 0 {
        return "-".into();
    }
    let target = (q * h.count as f64).ceil() as u64;
    let mut cum = 0u64;
    for (i, c) in h.buckets.iter().enumerate() {
        cum += c;
        if cum >= target {
            return h
                .bounds_us
                .get(i)
                .map(|b| b.to_string())
                .unwrap_or_else(|| "?".into());
        }
    }
    "overflow".into()
}

fn cmd_cli(args: &Args) -> Result<(), String> {
    let method = args
        .positional()
        .get(1)
        .ok_or("usage: rc3e cli <method> [--user ... --alloc ...]")?;
    let mut client = connect(args)?;
    let mut params = Json::obj(vec![]);
    for flag in ["user", "alloc", "fpga", "core", "name", "job", "lease"] {
        if let Some(v) = args.get(flag) {
            params.set(flag, Json::from(v));
        }
    }
    if let Some(m) = args.get("mults") {
        params.set(
            "mults",
            Json::from(m.parse::<u64>().map_err(|e| e.to_string())?),
        );
    }
    let body = client
        .call_v2(method, params)
        .map_err(|e| e.to_string())?;
    println!("{}", body.to_pretty());
    Ok(())
}

/// In-process demo: the full RAaaS path without a server.
fn cmd_demo(args: &Args) -> Result<(), String> {
    let config = load_config(args)?;
    let clock = VirtualClock::new();
    eprintln!("booting in-process cloud...");
    let hv = Arc::new(
        Hypervisor::boot(&config, clock, PlacementPolicy::ConsolidateFirst)
            .map_err(|e| e.to_string())?,
    );
    let svc = rc3e::service::RaaasService::new(Arc::clone(&hv));
    let user = hv.add_user("demo");
    let lease = svc.alloc(user).map_err(|e| e.to_string())?;
    let vfpga = lease.vfpga().ok_or("fresh lease has no placement")?;
    eprintln!(
        "allocated {vfpga} (lease {}, token {})",
        lease.alloc(),
        lease.token()
    );
    let synth = rc3e::hls::Synthesizer::new();
    let spec = rc3e::hls::CoreSpec::matmul(16, "xc7vx485t");
    let report = synth.synthesize(&spec);
    let bitfile = rc3e::bitstream::BitstreamBuilder::partial(
        "xc7vx485t",
        "matmul16",
    )
    .resources(report.total_for(1))
    .frames(rc3e::hls::flow::region_window(0, 1))
    .artifact("matmul16_b256")
    .build();
    lease.program(&bitfile).map_err(|e| e.to_string())?;
    eprintln!("programmed matmul16 (PR done)");
    let mults = args.get_u64("mults", 20_000).map_err(|e| e.to_string())?;
    let out = lease
        .stream(&rc3e::rc2f::StreamConfig::matmul16(mults))
        .map_err(|e| e.to_string())?;
    println!(
        "streamed {} mults: modeled {:.3} s ({:.0} MB/s), wall {:.3} s \
         ({:.0} MB/s), checksum {:.3e}, validation failures {}",
        out.mults,
        out.virtual_stream.as_secs_f64(),
        out.virtual_mbps(),
        out.wall_secs,
        out.wall_mbps(),
        out.checksum,
        out.validation_failures
    );
    lease.release().map_err(|e| e.to_string())?;
    eprintln!("released {vfpga}");
    Ok(())
}
