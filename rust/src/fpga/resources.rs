//! FPGA resource vectors (LUT / FF / BRAM / DSP).
//!
//! The unit of accounting for Table II (framework utilization) and
//! Table III (user-core area), and the quantity the placement engine
//! packs into PR regions.

use crate::util::json::Json;

/// A resource vector. BRAM counts RAMB36 blocks like Xilinx reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub dsp: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources {
        lut: 0,
        ff: 0,
        bram: 0,
        dsp: 0,
    };

    pub fn new(lut: u64, ff: u64, bram: u64, dsp: u64) -> Resources {
        Resources { lut, ff, bram, dsp }
    }

    /// Component-wise sum.
    pub fn plus(self, other: Resources) -> Resources {
        Resources {
            lut: self.lut + other.lut,
            ff: self.ff + other.ff,
            bram: self.bram + other.bram,
            dsp: self.dsp + other.dsp,
        }
    }

    /// Component-wise saturating difference.
    pub fn minus(self, other: Resources) -> Resources {
        Resources {
            lut: self.lut.saturating_sub(other.lut),
            ff: self.ff.saturating_sub(other.ff),
            bram: self.bram.saturating_sub(other.bram),
            dsp: self.dsp.saturating_sub(other.dsp),
        }
    }

    /// Scale by an integer factor (n identical cores).
    pub fn times(self, n: u64) -> Resources {
        Resources {
            lut: self.lut * n,
            ff: self.ff * n,
            bram: self.bram * n,
            dsp: self.dsp * n,
        }
    }

    /// Does `self` fit inside `capacity` on every axis?
    pub fn fits_in(self, capacity: Resources) -> bool {
        self.lut <= capacity.lut
            && self.ff <= capacity.ff
            && self.bram <= capacity.bram
            && self.dsp <= capacity.dsp
    }

    /// Largest per-axis utilization fraction (0.0–1.0+) — the number
    /// the paper quotes as "<3 % of the device".
    pub fn utilization_of(self, capacity: Resources) -> f64 {
        let frac = |a: u64, b: u64| {
            if b == 0 {
                0.0
            } else {
                a as f64 / b as f64
            }
        };
        frac(self.lut, capacity.lut)
            .max(frac(self.ff, capacity.ff))
            .max(frac(self.bram, capacity.bram))
            .max(frac(self.dsp, capacity.dsp))
    }

    /// Per-axis utilization percentages `(lut, ff, bram, dsp)`.
    pub fn utilization_pct(self, capacity: Resources) -> (f64, f64, f64, f64) {
        let pct = |a: u64, b: u64| {
            if b == 0 {
                0.0
            } else {
                100.0 * a as f64 / b as f64
            }
        };
        (
            pct(self.lut, capacity.lut),
            pct(self.ff, capacity.ff),
            pct(self.bram, capacity.bram),
            pct(self.dsp, capacity.dsp),
        )
    }

    pub fn to_json(self) -> Json {
        Json::obj(vec![
            ("lut", Json::from(self.lut)),
            ("ff", Json::from(self.ff)),
            ("bram", Json::from(self.bram)),
            ("dsp", Json::from(self.dsp)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Resources> {
        Some(Resources {
            lut: v.get("lut").as_u64()?,
            ff: v.get("ff").as_u64()?,
            bram: v.get("bram").as_u64()?,
            dsp: v.get("dsp").as_u64()?,
        })
    }
}

impl std::fmt::Display for Resources {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "LUT {} / FF {} / BRAM {} / DSP {}",
            self.lut, self.ff, self.bram, self.dsp
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Resources::new(100, 200, 4, 8);
        let b = Resources::new(50, 25, 1, 2);
        assert_eq!(a.plus(b), Resources::new(150, 225, 5, 10));
        assert_eq!(a.minus(b), Resources::new(50, 175, 3, 6));
        assert_eq!(b.times(4), Resources::new(200, 100, 4, 8));
    }

    #[test]
    fn minus_saturates() {
        let a = Resources::new(1, 1, 1, 1);
        let b = Resources::new(5, 5, 5, 5);
        assert_eq!(a.minus(b), Resources::ZERO);
    }

    #[test]
    fn fits_requires_every_axis() {
        let cap = Resources::new(100, 100, 10, 10);
        assert!(Resources::new(100, 100, 10, 10).fits_in(cap));
        assert!(!Resources::new(101, 1, 1, 1).fits_in(cap));
        assert!(!Resources::new(1, 1, 11, 1).fits_in(cap));
    }

    #[test]
    fn utilization_is_max_axis() {
        let cap = Resources::new(1000, 1000, 100, 100);
        let used = Resources::new(10, 500, 3, 0);
        assert!((used.utilization_of(cap) - 0.5).abs() < 1e-12);
        let (l, f, b, d) = used.utilization_pct(cap);
        assert!((l - 1.0).abs() < 1e-12);
        assert!((f - 50.0).abs() < 1e-12);
        assert!((b - 3.0).abs() < 1e-12);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn paper_table2_utilization_reproduced() {
        // Table II: 4-vFPGA total 8,532 LUT / 8,318 FF / 25 BRAM on a
        // XC7VX485T is quoted as 2.8 % / 1.4 % / 2.3 %.
        let cap = crate::fpga::board::BoardSpec::vc707().resources;
        let total = Resources::new(8_532, 8_318, 25, 0);
        let (l, f, b, _) = total.utilization_pct(cap);
        assert!((l - 2.8).abs() < 0.1, "lut {l}");
        assert!((f - 1.4).abs() < 0.1, "ff {f}");
        assert!((b - 2.3).abs() < 0.2, "bram {b}");
    }

    #[test]
    fn zero_capacity_is_zero_utilization() {
        assert_eq!(
            Resources::new(5, 5, 5, 5).utilization_of(Resources::ZERO),
            0.0
        );
    }

    #[test]
    fn json_roundtrip() {
        let r = Resources::new(3268, 3592, 8, 0);
        assert_eq!(Resources::from_json(&r.to_json()), Some(r));
    }
}
