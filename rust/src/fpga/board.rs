//! Board specifications for the paper's testbed.
//!
//! Section IV-A: "Our current architecture consists of two nodes using
//! Xilinx ML605 and VC707 development boards." The VC707 carries a
//! Virtex-7 XC7VX485T (Table II's utilization denominator); the ML605
//! a Virtex-6 LX240T. Device capacities come from the Xilinx data
//! sheets; configuration timing is calibrated to Table I.

use super::resources::Resources;
use crate::util::json::Json;

/// Supported development boards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoardKind {
    /// Xilinx VC707 (Virtex-7 XC7VX485T).
    Vc707,
    /// Xilinx ML605 (Virtex-6 LX240T).
    Ml605,
}

impl BoardKind {
    pub fn parse(s: &str) -> Option<BoardKind> {
        match s.to_ascii_lowercase().as_str() {
            "vc707" => Some(BoardKind::Vc707),
            "ml605" => Some(BoardKind::Ml605),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BoardKind::Vc707 => "vc707",
            BoardKind::Ml605 => "ml605",
        }
    }
}

/// Full board data: part, capacity, configuration timing, power.
#[derive(Debug, Clone, PartialEq)]
pub struct BoardSpec {
    pub kind: BoardKind,
    /// FPGA part marking (for bitstream target checks).
    pub part: &'static str,
    /// Total device resources.
    pub resources: Resources,
    /// Full-bitstream size in bytes (Virtex config frames).
    pub full_bitstream_bytes: u64,
    /// Full configuration time via JTAG+USB — Table I: 28.370 s.
    pub jtag_config_s: f64,
    /// Partial reconfiguration time for a quarter-device region —
    /// Table I: 732 ms. Scaled by actual region size at PR time.
    pub pr_quarter_region_ms: f64,
    /// Static design power with clocks running, in watts.
    pub static_power_w: f64,
    /// Fully-idle floor (no allocation, clocks gated), in watts.
    pub idle_power_w: f64,
    /// Additional power per active vFPGA region in watts.
    pub active_region_power_w: f64,
}

impl BoardSpec {
    /// VC707 / XC7VX485T — Table II's reference device.
    pub fn vc707() -> BoardSpec {
        BoardSpec {
            kind: BoardKind::Vc707,
            part: "xc7vx485t",
            // XC7VX485T: 303,600 LUTs; 607,200 FFs; 1,030 RAMB36;
            // 2,800 DSP48E1 (Xilinx DS180).
            resources: Resources::new(303_600, 607_200, 1_030, 2_800),
            // 485T config image ≈ 19.3 MB.
            full_bitstream_bytes: 19_300_000,
            jtag_config_s: crate::paper::CONFIG_LOCAL_S,
            pr_quarter_region_ms: crate::paper::PR_LOCAL_MS,
            static_power_w: 7.5,
            idle_power_w: 2.5,
            active_region_power_w: 4.0,
        }
    }

    /// ML605 / Virtex-6 LX240T — the second testbed board.
    pub fn ml605() -> BoardSpec {
        BoardSpec {
            kind: BoardKind::Ml605,
            part: "xc6vlx240t",
            // LX240T: 150,720 LUTs; 301,440 FFs; 416 RAMB36; 768 DSP48E1.
            resources: Resources::new(150_720, 301_440, 416, 768),
            // LX240T config image ≈ 9.2 MB; JTAG time scales with size.
            full_bitstream_bytes: 9_200_000,
            jtag_config_s: crate::paper::CONFIG_LOCAL_S * 9.2 / 19.3,
            pr_quarter_region_ms: crate::paper::PR_LOCAL_MS * 9.2 / 19.3,
            static_power_w: 6.0,
            idle_power_w: 2.0,
            active_region_power_w: 3.5,
        }
    }

    pub fn of(kind: BoardKind) -> BoardSpec {
        match kind {
            BoardKind::Vc707 => BoardSpec::vc707(),
            BoardKind::Ml605 => BoardSpec::ml605(),
        }
    }

    /// PR bitstream size for a region covering `frac` of the device.
    pub fn partial_bitstream_bytes(&self, frac: f64) -> u64 {
        (self.full_bitstream_bytes as f64 * frac) as u64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::from(self.kind.name())),
            ("part", Json::from(self.part)),
            ("resources", self.resources.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(BoardKind::parse("VC707"), Some(BoardKind::Vc707));
        assert_eq!(BoardKind::parse("ml605"), Some(BoardKind::Ml605));
        assert_eq!(BoardKind::parse("zcu102"), None);
    }

    #[test]
    fn vc707_is_table2_device() {
        let b = BoardSpec::vc707();
        assert_eq!(b.part, "xc7vx485t");
        assert_eq!(b.resources.lut, 303_600);
        assert!((b.jtag_config_s - 28.370).abs() < 1e-9);
    }

    #[test]
    fn ml605_scales_config_time_with_image() {
        let b = BoardSpec::ml605();
        assert!(b.jtag_config_s < BoardSpec::vc707().jtag_config_s);
        assert!(b.jtag_config_s > 10.0);
    }

    #[test]
    fn partial_bitstream_fraction() {
        let b = BoardSpec::vc707();
        let q = b.partial_bitstream_bytes(0.25);
        assert_eq!(q, 19_300_000 / 4);
    }

    #[test]
    fn of_matches_constructor() {
        assert_eq!(BoardSpec::of(BoardKind::Vc707), BoardSpec::vc707());
        assert_eq!(BoardSpec::of(BoardKind::Ml605), BoardSpec::ml605());
    }
}
