//! Power and energy model.
//!
//! Section IV-B: "If no vFPGA is allocated and the device is not
//! allocated, most of the clocks in this design are disabled to reduce
//! power consumption. The resource manager always tries to minimize
//! the number of active vFPGAs and to maximize the utilization of
//! physical FPGAs to thereby reduce energy consumption."
//!
//! The meter integrates power over *virtual* time: every power-state
//! change records energy for the elapsed span at the previous draw.
//! The placement ablation bench uses this to show consolidation-first
//! placement beats round-robin on energy.

use crate::util::clock::{VirtualClock, VirtualTime};
use std::sync::Arc;

/// Instantaneous power state of one device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerState {
    /// Static design powered, clocks running (device in use).
    pub base_w: f64,
    /// Fully idle floor: no vFPGA allocated, "most of the clocks in
    /// this design are disabled" (Section IV-B).
    pub idle_w: f64,
    /// Number of vFPGA regions with enabled clocks.
    pub active_regions: usize,
    /// Per-active-region dynamic draw.
    pub region_w: f64,
}

impl PowerState {
    pub fn draw_w(&self) -> f64 {
        if self.active_regions == 0 {
            self.idle_w
        } else {
            self.base_w + self.active_regions as f64 * self.region_w
        }
    }
}

/// Energy integrator over virtual time.
#[derive(Debug)]
pub struct EnergyMeter {
    clock: Arc<VirtualClock>,
    last_change: VirtualTime,
    state: PowerState,
    joules: f64,
}

impl EnergyMeter {
    pub fn new(clock: Arc<VirtualClock>, state: PowerState) -> EnergyMeter {
        let last_change = clock.now();
        EnergyMeter {
            clock,
            last_change,
            state,
            joules: 0.0,
        }
    }

    /// Record the span since the last change at the previous draw,
    /// then switch to `active_regions` enabled clocks.
    pub fn set_active_regions(&mut self, active_regions: usize) {
        self.settle();
        self.state.active_regions = active_regions;
    }

    /// Integrate up to "now" without changing state.
    pub fn settle(&mut self) {
        let now = self.clock.now();
        let span = now.saturating_sub(self.last_change).as_secs_f64();
        self.joules += self.state.draw_w() * span;
        self.last_change = now;
    }

    /// Total integrated energy including the open span.
    pub fn joules(&mut self) -> f64 {
        self.settle();
        self.joules
    }

    /// Current instantaneous draw.
    pub fn draw_w(&self) -> f64 {
        self.state.draw_w()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state() -> PowerState {
        PowerState {
            base_w: 7.5,
            idle_w: 2.5,
            active_regions: 0,
            region_w: 4.0,
        }
    }

    #[test]
    fn idle_draw_is_gated_floor() {
        let c = VirtualClock::new();
        let m = EnergyMeter::new(c, state());
        assert_eq!(m.draw_w(), 2.5);
    }

    #[test]
    fn draw_scales_with_active_regions() {
        let c = VirtualClock::new();
        let mut m = EnergyMeter::new(c, state());
        m.set_active_regions(4);
        assert_eq!(m.draw_w(), 7.5 + 16.0);
    }

    #[test]
    fn energy_integrates_over_virtual_time() {
        let c = VirtualClock::new();
        let mut m = EnergyMeter::new(Arc::clone(&c), state());
        c.advance(VirtualTime::from_secs_f64(10.0)); // 10 s idle
        m.set_active_regions(2);
        c.advance(VirtualTime::from_secs_f64(5.0)); // 5 s at 2 regions
        let j = m.joules();
        // 10*2.5 (gated idle) + 5*(7.5+8) = 25 + 77.5
        assert!((j - 102.5).abs() < 1e-9, "joules {j}");
    }

    #[test]
    fn settle_is_idempotent() {
        let c = VirtualClock::new();
        let mut m = EnergyMeter::new(Arc::clone(&c), state());
        c.advance(VirtualTime::from_secs_f64(1.0));
        let a = m.joules();
        let b = m.joules();
        assert_eq!(a, b);
    }

    #[test]
    fn consolidation_uses_less_energy_than_spreading() {
        // Two 1-region workloads for 10 s: consolidated on one device
        // (other device stays idle-with-clocks-gated... represented
        // here as powered-off, i.e. not metered) vs spread across two.
        let c = VirtualClock::new();
        let mut one = EnergyMeter::new(Arc::clone(&c), state());
        one.set_active_regions(2);
        let mut spread_a = EnergyMeter::new(Arc::clone(&c), state());
        let mut spread_b = EnergyMeter::new(Arc::clone(&c), state());
        spread_a.set_active_regions(1);
        spread_b.set_active_regions(1);
        c.advance(VirtualTime::from_secs_f64(10.0));
        let consolidated = one.joules();
        let spread = spread_a.joules() + spread_b.joules();
        assert!(consolidated < spread, "{consolidated} !< {spread}");
    }
}
